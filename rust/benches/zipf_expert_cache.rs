//! Zipf-workload expert-cache bench (ROADMAP "expert-cache policy"):
//! replay a synthetic zipfian routing trace through the byte-budgeted
//! expert cache across an `expert_budget_bytes` sweep, printing hit-rate
//! and decode-stall per budget — the data behind the default-budget
//! choice. Two skews: a mild one (broad reuse) and a heavy one (a few
//! hot experts dominate, the regime QMoE-style traffic reports).
//!
//! Run: `cargo bench --bench zipf_expert_cache` (host-side, no
//! artifacts needed). `TQM_ZIPF_TOKENS` overrides the trace length.

use tiny_qmoe::tables;

fn main() -> anyhow::Result<()> {
    let tokens = std::env::var("TQM_ZIPF_TOKENS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4000usize);
    for alpha in [0.8f64, 1.3] {
        let rows = tables::zipf_table(alpha, tokens)?;
        tables::render_zipf(&rows, alpha).print();
    }
    Ok(())
}
