//! Zipf-workload expert-cache bench (ROADMAP "expert-cache policy"):
//! replay a synthetic zipfian routing trace through the byte-budgeted
//! expert cache across an `expert_budget_bytes` sweep, printing hit-rate
//! and decode-stall per budget — the data behind the default-budget
//! choice. Two skews: a mild one (broad reuse) and a heavy one (a few
//! hot experts dominate, the regime QMoE-style traffic reports).
//!
//! Run: `cargo bench --bench zipf_expert_cache` (host-side, no
//! artifacts needed). `TQM_ZIPF_TOKENS` overrides the trace length;
//! `TQM_BENCH_DIR` additionally records the sweep as `BENCH_zipf.json`
//! for `tqm bench-report` (per-token stall as the timed quantity,
//! hit-rate as the throughput column).

use tiny_qmoe::barometer::{self, BenchRecord, BenchSet};
use tiny_qmoe::tables;
use tiny_qmoe::util::env_parse;

fn main() -> anyhow::Result<()> {
    let tokens: usize = env_parse("TQM_ZIPF_TOKENS", 4000)?;
    let mut set = BenchSet::new("zipf");
    for alpha in [0.8f64, 1.3] {
        let rows = tables::zipf_table(alpha, tokens)?;
        tables::render_zipf(&rows, alpha).print();
        for r in &rows {
            let name = format!("zipf/a{alpha}/e{}", r.budget_experts);
            let stall_s = r.stall_ms / 1e3;
            set.push(
                BenchRecord::single(&name, tokens, stall_s)
                    .with_throughput(r.hit_rate * 100.0, "%hit"),
            );
        }
    }
    barometer::emit(&set)?;
    Ok(())
}
