//! E1 — regenerates the paper's Table 1 (model sizes: fp32 / quantized /
//! quantized+compressed) for the trained e2e model and both LLaMA-3.2
//! proxies, with the per-stream entropy bound and the clustered-regime
//! companion that explains where the paper's 11.7x can and cannot come from.
use tiny_qmoe::tables;
use tiny_qmoe::util::bench::Table;

fn main() -> anyhow::Result<()> {
    for codec in [tables::paper_codec(), tables::default_codec()] {
        let rows = tables::table1(&["e2e", "proxy-1b", "proxy-3b"], codec)?;
        tables::render_table1(&rows, codec).print();
    }
    let codec = tables::default_codec();
    let crows = tables::table1_clustered(codec)?;
    let mut ct = Table::new(
        "Table 1 companion — ratio vs weight-entropy regime (freqseq-packed)",
        &["regime", "entropy bits/B", "ratio vs quantized", "entropy bound"],
    );
    for r in &crows {
        ct.row(vec![
            r.regime.clone(),
            format!("{:.2}", r.entropy_bits),
            format!("{:.2}x", r.ratio_quant),
            format!("{:.2}x", 8.0 / r.entropy_bits.max(1e-9)),
        ]);
    }
    ct.print();
    Ok(())
}
