//! E5 — the paper's §3 bit-width ablation: ternary/2/4/6/8-bit naive
//! quantization plus 4/8-bit GPTQ, reported as weight-MSE and SQNR (the
//! paper's qualitative finding: <6 bits destroys the model; GPTQ helps
//! but cannot rescue 4-bit to 8-bit quality).
use tiny_qmoe::tables;

fn main() -> anyhow::Result<()> {
    let rows = tables::ablation_bits("e2e", true, tables::eval_limit()?)?;
    tables::render_bits(&rows).print();
    // monotonicity: more bits, less error (within each quantizer)
    let naive: Vec<&tiny_qmoe::tables::BitsRow> =
        rows.iter().filter(|r| r.quantizer == "naive").collect();
    for w in naive.windows(2) {
        assert!(w[0].weight_mse >= w[1].weight_mse, "MSE not monotone in bits");
    }
    Ok(())
}
