//! E7 — the paper's §5 network-vs-local comparison: simulated hosted-LLM
//! round trips (anchored to the paper's 697 ms Safari measurement) against
//! the measured on-device per-question latency of the compressed model.
use tiny_qmoe::tables;

fn main() -> anyhow::Result<()> {
    tables::network_table("e2e", tables::default_codec(), tables::eval_limit()?)?.print();
    Ok(())
}
