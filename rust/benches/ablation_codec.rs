//! E6 — the §4 codec design space on real quantized weights: every codec,
//! freqseq sequence-length sweep, and the entropy bound. Shows the paper's
//! faithful escape encoding *expanding* on high-entropy streams and the
//! packed fix recovering it.
use tiny_qmoe::tables;

fn main() -> anyhow::Result<()> {
    let rows = tables::ablation_codec("e2e")?;
    tables::render_codec(&rows).print();
    Ok(())
}
