//! Packed-vs-decoded GEMV throughput (the qGEMV kernel bench): for each
//! bit width, time `out = x · W` computed (a) the decoded way — weights
//! pre-expanded to f32, plain matmul — and (b) the quantized-domain way —
//! fused qGEMV straight off the bit-packed codes. Threads scale by
//! running independent matrices per worker (the expert-parallel shape:
//! different experts decode/execute on different cores, they do not
//! split one GEMV).
//!
//! Throughput is reported as decoded-equivalent MB/s (rows*cols*4 bytes
//! of weight touched per GEMV), so the two paths are directly
//! comparable; the last column is the resident-bytes ratio — the cache
//! capacity multiplier packed residency buys at that width.
//!
//! Run: `cargo bench --bench qgemv` (host-side, no artifacts needed).
//! `TQM_QGEMV_REPS` overrides the per-thread repetition count;
//! `TQM_BENCH_DIR` additionally records the run as `BENCH_qgemv.json`
//! for `tqm bench-report`.
//!
//! For native-ISA numbers run
//! `RUSTFLAGS="-C target-cpu=native" cargo bench --bench qgemv`:
//! the blocked/batched kernels decode each packed run into a stack
//! block once and then run tight f32 FMA loops, which only vectorize
//! fully when the compiler may assume the host's SIMD width.
//!
//! Three tables:
//!   1. packed qGEMV vs decoded GEMV (bits x threads) — the original
//!      capacity-vs-speed tradeoff;
//!   2. blocked (exact) and relaxed qGEMV vs the scalar kernel
//!      (widths 1-8, single thread) — blocked is bit-exact by
//!      construction, relaxed is tolerance-checked only;
//!   3. batched qGEMM vs B independent qGEMVs (widths 1-8 x batch
//!      1/2/4/8 x 1/2/4/8 threads) — one packed-stream traversal
//!      amortized over the whole token group. Reps scale down with
//!      batch so every cell touches the same total weight bytes.

use tiny_qmoe::barometer::{self, BenchRecord, BenchSet};
use tiny_qmoe::quant::packing;
use tiny_qmoe::util::bench::Table;
use tiny_qmoe::util::{env_parse, Rng};

const ROWS: usize = 512;
const COLS: usize = 512;

struct Fixture {
    packed: Vec<u8>,
    decoded: Vec<f32>,
    x: Vec<f32>,
}

fn fixture(bits: u32, seed: u64) -> Fixture {
    let mut rng = Rng::seed_from_u64(seed);
    let n = ROWS * COLS;
    let codes: Vec<u8> = (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
    let packed = packing::pack(&codes, bits);
    let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
    let mut decoded = vec![0.0f32; n];
    packing::unpack_dequant_into(&packed, bits, scale, zero, &mut decoded);
    let x = (0..ROWS).map(|_| rng.normal_f32()).collect();
    Fixture { packed, decoded, x }
}

/// The decoded baseline: the expert FFN's matmul shape (rows ascending,
/// zero activations skipped).
fn f32_gemv(w: &[f32], x: &[f32], out: &mut [f32]) {
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * COLS..(i + 1) * COLS];
        for (o, &wij) in out.iter_mut().zip(row) {
            *o += xi * wij;
        }
    }
}

/// Run `reps` GEMVs on each of `threads` workers (independent fixtures)
/// and return aggregate decoded-equivalent MB/s.
fn throughput(fixtures: &[Fixture], reps: usize, packed: bool, bits: u32) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for f in fixtures {
            scope.spawn(move || {
                let mut out = vec![0.0f32; COLS];
                let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
                for _ in 0..reps {
                    if packed {
                        packing::qgemv(&f.packed, bits, COLS, scale, zero, &f.x, &mut out);
                    } else {
                        f32_gemv(&f.decoded, &f.x, &mut out);
                    }
                    std::hint::black_box(&mut out);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    (ROWS * COLS * 4 * reps * fixtures.len()) as f64 / 1e6 / secs
}

/// Single-thread throughput of one qGEMV kernel variant, decoded-equivalent
/// MB/s. `kind`: 0 = scalar `qgemv`, 1 = `qgemv_blocked`, 2 = blocked with
/// relaxed accumulation.
fn variant_throughput(f: &Fixture, reps: usize, bits: u32, kind: u8) -> f64 {
    let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
    let mut out = vec![0.0f32; COLS];
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        match kind {
            0 => packing::qgemv(&f.packed, bits, COLS, scale, zero, &f.x, &mut out),
            1 => packing::qgemv_blocked(
                &f.packed,
                bits,
                COLS,
                scale,
                zero,
                &f.x,
                &mut out,
                packing::Accumulation::Exact,
            ),
            _ => packing::qgemv_blocked(
                &f.packed,
                bits,
                COLS,
                scale,
                zero,
                &f.x,
                &mut out,
                packing::Accumulation::Relaxed,
            ),
        }
        std::hint::black_box(&mut out);
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    (ROWS * COLS * 4 * reps) as f64 / 1e6 / secs
}

/// One worker per fixture; each rep forwards `b` tokens through one
/// expert matrix — either as ONE batched qGEMM (single traversal of the
/// packed stream) or as `b` independent scalar qGEMVs (B traversals).
fn batch_throughput(
    fixtures: &[Fixture],
    xbs: &[Vec<f32>],
    reps: usize,
    bits: u32,
    b: usize,
    batched: bool,
) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for (f, xb) in fixtures.iter().zip(xbs) {
            scope.spawn(move || {
                let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
                let mut out = vec![0.0f32; b * COLS];
                for _ in 0..reps {
                    if batched {
                        packing::qgemm(
                            &f.packed,
                            bits,
                            COLS,
                            scale,
                            zero,
                            xb,
                            b,
                            &mut out,
                            packing::Accumulation::Exact,
                        );
                    } else {
                        for (xs, os) in xb.chunks(ROWS).zip(out.chunks_mut(COLS)) {
                            packing::qgemv(&f.packed, bits, COLS, scale, zero, xs, os);
                        }
                    }
                    std::hint::black_box(&mut out);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    // decoded-equivalent weight bytes touched per token forwarded
    (ROWS * COLS * 4 * reps * b * fixtures.len()) as f64 / 1e6 / secs
}

/// Record one aggregate-timed cell in the barometer set: the throughput
/// functions report decoded-equivalent MB/s over `total_mb` of weight
/// bytes, so the elapsed seconds are recoverable exactly.
fn rec(set: &mut BenchSet, name: &str, iters: usize, mbps: f64, total_mb: f64) {
    let secs = total_mb / mbps.max(1e-9);
    set.push(BenchRecord::single(name, iters, secs).with_throughput(mbps, "MB/s"));
}

fn main() -> anyhow::Result<()> {
    let reps: usize = env_parse("TQM_QGEMV_REPS", 64)?;
    let mut set = BenchSet::new("qgemv");
    let cell_mb = (ROWS * COLS * 4) as f64 / 1e6;
    let mut t = Table::new(
        &format!(
            "qGEMV — packed vs decoded GEMV throughput ({ROWS}x{COLS}, per-tensor params, \
             {reps} reps/thread, decoded-equivalent MB/s)"
        ),
        &["bits", "threads", "decoded MB/s", "qgemv MB/s", "qgemv/decoded", "capacity x"],
    );
    for bits in [2u32, 4, 6, 8] {
        for threads in [1usize, 2, 4, 8] {
            let fixtures: Vec<Fixture> =
                (0..threads).map(|i| fixture(bits, 100 + i as u64)).collect();
            // correctness guard: the two paths must agree bit for bit
            {
                let f = &fixtures[0];
                let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
                let mut a = vec![0.0f32; COLS];
                let mut b = vec![0.0f32; COLS];
                packing::qgemv(&f.packed, bits, COLS, scale, zero, &f.x, &mut a);
                f32_gemv(&f.decoded, &f.x, &mut b);
                assert_eq!(a, b, "qgemv diverged from the decoded path at {bits} bits");
            }
            // warm-up, then measure
            let _ = throughput(&fixtures, reps.div_ceil(8).max(1), true, bits);
            let dec = throughput(&fixtures, reps, false, bits);
            let pkd = throughput(&fixtures, reps, true, bits);
            let total_mb = cell_mb * (reps * threads) as f64;
            rec(&mut set, &format!("gemv/b{bits}/t{threads}/decoded"), reps, dec, total_mb);
            rec(&mut set, &format!("gemv/b{bits}/t{threads}/packed"), reps, pkd, total_mb);
            let resident_packed = fixtures[0].packed.len() + 8; // + scale/zero
            let resident_decoded = ROWS * COLS * 4;
            t.row(vec![
                format!("{bits}"),
                format!("{threads}"),
                format!("{dec:.0}"),
                format!("{pkd:.0}"),
                format!("{:.2}x", pkd / dec.max(1e-9)),
                format!("{:.2}x", resident_decoded as f64 / resident_packed as f64),
            ]);
        }
    }
    t.print();

    // ---- table 2: blocked / relaxed qGEMV (widths 1-8, one thread) ----
    let mut t2 = Table::new(
        &format!(
            "blocked qGEMV — scalar vs blocked(exact) vs blocked(relaxed) \
             ({ROWS}x{COLS}, {reps} reps, decoded-equivalent MB/s)"
        ),
        &["bits", "scalar MB/s", "blocked MB/s", "relaxed MB/s", "blocked x", "relaxed x"],
    );
    for bits in 1u32..=8 {
        let f = fixture(bits, 200 + bits as u64);
        let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
        // correctness guards: blocked-exact must match the scalar kernel
        // bit for bit; relaxed only has to land within tolerance
        {
            let mut a = vec![0.0f32; COLS];
            let mut b = vec![0.0f32; COLS];
            let mut r = vec![0.0f32; COLS];
            packing::qgemv(&f.packed, bits, COLS, scale, zero, &f.x, &mut a);
            packing::qgemv_blocked(
                &f.packed,
                bits,
                COLS,
                scale,
                zero,
                &f.x,
                &mut b,
                packing::Accumulation::Exact,
            );
            assert_eq!(a, b, "blocked qgemv diverged from scalar at {bits} bits");
            packing::qgemv_blocked(
                &f.packed,
                bits,
                COLS,
                scale,
                zero,
                &f.x,
                &mut r,
                packing::Accumulation::Relaxed,
            );
            for (e, g) in a.iter().zip(&r) {
                assert!(
                    (e - g).abs() <= 1e-3 * (1.0 + e.abs()),
                    "relaxed qgemv out of tolerance at {bits} bits: {e} vs {g}"
                );
            }
        }
        let _ = variant_throughput(&f, reps.div_ceil(8).max(1), bits, 1); // warm-up
        let scalar = variant_throughput(&f, reps, bits, 0);
        let blocked = variant_throughput(&f, reps, bits, 1);
        let relaxed = variant_throughput(&f, reps, bits, 2);
        let total_mb = cell_mb * reps as f64;
        rec(&mut set, &format!("blocked/b{bits}/scalar"), reps, scalar, total_mb);
        rec(&mut set, &format!("blocked/b{bits}/blocked"), reps, blocked, total_mb);
        rec(&mut set, &format!("blocked/b{bits}/relaxed"), reps, relaxed, total_mb);
        t2.row(vec![
            format!("{bits}"),
            format!("{scalar:.0}"),
            format!("{blocked:.0}"),
            format!("{relaxed:.0}"),
            format!("{:.2}x", blocked / scalar.max(1e-9)),
            format!("{:.2}x", relaxed / scalar.max(1e-9)),
        ]);
    }
    t2.print();

    // ---- table 3: batched qGEMM sweep (widths 1-8 x batch x threads) ----
    // one row per (bits, batch); one column per thread count, showing the
    // qGEMM throughput and its speedup over B independent qGEMVs on the
    // same workers
    let mut t3 = Table::new(
        &format!(
            "batched qGEMM — one traversal per token group vs B x qGEMV \
             ({ROWS}x{COLS}, per-cell reps scaled to constant weight-bytes)"
        ),
        &["bits", "batch", "1 thr", "2 thr", "4 thr", "8 thr"],
    );
    for bits in 1u32..=8 {
        for b in [1usize, 2, 4, 8] {
            let breps = (reps / b).max(1);
            let mut cells = Vec::new();
            for threads in [1usize, 2, 4, 8] {
                let fixtures: Vec<Fixture> =
                    (0..threads).map(|i| fixture(bits, 300 + i as u64)).collect();
                let xbs: Vec<Vec<f32>> = (0..threads)
                    .map(|i| {
                        let mut rng = Rng::seed_from_u64(400 + i as u64);
                        (0..b * ROWS).map(|_| rng.normal_f32()).collect()
                    })
                    .collect();
                // correctness guard: one qgemm == b stacked qgemvs, exactly
                {
                    let (f, xb) = (&fixtures[0], &xbs[0]);
                    let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
                    let mut got = vec![0.0f32; b * COLS];
                    let mut want = vec![0.0f32; b * COLS];
                    packing::qgemm(
                        &f.packed,
                        bits,
                        COLS,
                        scale,
                        zero,
                        xb,
                        b,
                        &mut got,
                        packing::Accumulation::Exact,
                    );
                    for (xs, os) in xb.chunks(ROWS).zip(want.chunks_mut(COLS)) {
                        packing::qgemv(&f.packed, bits, COLS, scale, zero, xs, os);
                    }
                    assert_eq!(got, want, "qgemm diverged from stacked qgemv at {bits} bits");
                }
                let _ = batch_throughput(&fixtures, &xbs, breps.div_ceil(8).max(1), bits, b, true);
                let scalar = batch_throughput(&fixtures, &xbs, breps, bits, b, false);
                let gemm = batch_throughput(&fixtures, &xbs, breps, bits, b, true);
                let total_mb = cell_mb * (breps * b * threads) as f64;
                rec(
                    &mut set,
                    &format!("gemm/b{bits}/batch{b}/t{threads}"),
                    breps,
                    gemm,
                    total_mb,
                );
                cells.push(format!("{gemm:.0} ({:.2}x)", gemm / scalar.max(1e-9)));
            }
            let mut row = vec![format!("{bits}"), format!("{b}")];
            row.extend(cells);
            t3.row(row);
        }
    }
    t3.print();
    barometer::emit(&set)?;
    Ok(())
}
