//! Packed-vs-decoded GEMV throughput (the qGEMV kernel bench): for each
//! bit width, time `out = x · W` computed (a) the decoded way — weights
//! pre-expanded to f32, plain matmul — and (b) the quantized-domain way —
//! fused qGEMV straight off the bit-packed codes. Threads scale by
//! running independent matrices per worker (the expert-parallel shape:
//! different experts decode/execute on different cores, they do not
//! split one GEMV).
//!
//! Throughput is reported as decoded-equivalent MB/s (rows*cols*4 bytes
//! of weight touched per GEMV), so the two paths are directly
//! comparable; the last column is the resident-bytes ratio — the cache
//! capacity multiplier packed residency buys at that width.
//!
//! Run: `cargo bench --bench qgemv` (host-side, no artifacts needed).
//! `TQM_QGEMV_REPS` overrides the per-thread repetition count.

use tiny_qmoe::quant::packing;
use tiny_qmoe::util::bench::Table;
use tiny_qmoe::util::Rng;

const ROWS: usize = 512;
const COLS: usize = 512;

struct Fixture {
    packed: Vec<u8>,
    decoded: Vec<f32>,
    x: Vec<f32>,
}

fn fixture(bits: u32, seed: u64) -> Fixture {
    let mut rng = Rng::seed_from_u64(seed);
    let n = ROWS * COLS;
    let codes: Vec<u8> = (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
    let packed = packing::pack(&codes, bits);
    let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
    let mut decoded = vec![0.0f32; n];
    packing::unpack_dequant_into(&packed, bits, scale, zero, &mut decoded);
    let x = (0..ROWS).map(|_| rng.normal_f32()).collect();
    Fixture { packed, decoded, x }
}

/// The decoded baseline: the expert FFN's matmul shape (rows ascending,
/// zero activations skipped).
fn f32_gemv(w: &[f32], x: &[f32], out: &mut [f32]) {
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * COLS..(i + 1) * COLS];
        for (o, &wij) in out.iter_mut().zip(row) {
            *o += xi * wij;
        }
    }
}

/// Run `reps` GEMVs on each of `threads` workers (independent fixtures)
/// and return aggregate decoded-equivalent MB/s.
fn throughput(fixtures: &[Fixture], reps: usize, packed: bool, bits: u32) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for f in fixtures {
            scope.spawn(move || {
                let mut out = vec![0.0f32; COLS];
                let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
                for _ in 0..reps {
                    if packed {
                        packing::qgemv(&f.packed, bits, COLS, scale, zero, &f.x, &mut out);
                    } else {
                        f32_gemv(&f.decoded, &f.x, &mut out);
                    }
                    std::hint::black_box(&mut out);
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    (ROWS * COLS * 4 * reps * fixtures.len()) as f64 / 1e6 / secs
}

fn main() {
    let reps: usize = std::env::var("TQM_QGEMV_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64);
    let mut t = Table::new(
        &format!(
            "qGEMV — packed vs decoded GEMV throughput ({ROWS}x{COLS}, per-tensor params, \
             {reps} reps/thread, decoded-equivalent MB/s)"
        ),
        &["bits", "threads", "decoded MB/s", "qgemv MB/s", "qgemv/decoded", "capacity x"],
    );
    for bits in [2u32, 4, 6, 8] {
        for threads in [1usize, 2, 4, 8] {
            let fixtures: Vec<Fixture> =
                (0..threads).map(|i| fixture(bits, 100 + i as u64)).collect();
            // correctness guard: the two paths must agree bit for bit
            {
                let f = &fixtures[0];
                let (scale, zero) = (0.0127f32, (1u32 << (bits - 1)) as f32);
                let mut a = vec![0.0f32; COLS];
                let mut b = vec![0.0f32; COLS];
                packing::qgemv(&f.packed, bits, COLS, scale, zero, &f.x, &mut a);
                f32_gemv(&f.decoded, &f.x, &mut b);
                assert_eq!(a, b, "qgemv diverged from the decoded path at {bits} bits");
            }
            // warm-up, then measure
            let _ = throughput(&fixtures, reps.div_ceil(8).max(1), true, bits);
            let dec = throughput(&fixtures, reps, false, bits);
            let pkd = throughput(&fixtures, reps, true, bits);
            let resident_packed = fixtures[0].packed.len() + 8; // + scale/zero
            let resident_decoded = ROWS * COLS * 4;
            t.row(vec![
                format!("{bits}"),
                format!("{threads}"),
                format!("{dec:.0}"),
                format!("{pkd:.0}"),
                format!("{:.2}x", pkd / dec.max(1e-9)),
                format!("{:.2}x", resident_decoded as f64 / resident_packed as f64),
            ]);
        }
    }
    t.print();
}
