//! E3 — regenerates the paper's Table 3: arc-challenge accuracy + per-question
//! latency for fp32 / quantized / compressed variants of the trained
//! e2e model. Question budget: TQM_EVAL_LIMIT (default 60; paper used 200).
use tiny_qmoe::tables::{self, Variant};

fn main() -> anyhow::Result<()> {
    let limit = tables::eval_limit()?;
    let reps = tables::eval_table("e2e", "arc-challenge", &Variant::ALL, tables::default_codec(), limit)?;
    tables::render_eval_table("arc-challenge (paper Table 3) — e2e", &reps).print();
    // shape assertions from the paper: lossless compression => identical
    // accuracy; both within noise of fp32
    assert_eq!(reps[1].n_correct, reps[2].n_correct, "compressed != quantized accuracy");
    Ok(())
}
