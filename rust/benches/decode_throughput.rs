//! §Perf — end-to-end decode throughput (tokens/s) per variant and batch
//! size: the serving system's headline number.
use tiny_qmoe::gen::{generate, Sampler};
use tiny_qmoe::tables::{self, Variant};
use tiny_qmoe::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let model = "e2e";
    let codec = tables::default_codec();
    let mut t = Table::new(
        "decode throughput — e2e",
        &["variant", "prefill ms", "tok/s", "decompress share"],
    );
    for variant in [Variant::Fp32, Variant::Quantized, Variant::Compressed] {
        let engine = tables::build_engine(model, variant, codec)?;
        let prompt: Vec<u32> = vec![1, 2, 20, 3];
        // warm the executable cache before timing
        let mut s = Sampler::greedy();
        let _ = generate(&engine, &prompt, 4, &mut s, None)?;
        engine.metrics.reset_timers();
        // median of 5 generations (single-sample numbers were too noisy
        // for §Perf before/after comparisons)
        let mut tps = Vec::new();
        let mut prefills = Vec::new();
        for _ in 0..5 {
            let g = generate(&engine, &prompt, 48, &mut s, None)?;
            tps.push(g.tokens_per_s);
            prefills.push(g.prefill_s);
        }
        tps.sort_by(|a, b| a.partial_cmp(b).unwrap());
        prefills.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let d = engine.metrics.decompress_secs();
        let e = engine.metrics.exec_secs();
        t.row(vec![
            engine.variant(),
            format!("{:.1}", prefills[2] * 1e3),
            format!("{:.1}", tps[2]),
            format!("{:.0}%", 100.0 * d / (d + e).max(1e-12)),
        ]);
    }
    t.print();
    Ok(())
}
