//! §Perf — decompression throughput per codec on a realistic quantized
//! weight stream (the serving pipeline's hot auxiliary path).
use tiny_qmoe::compress::{self, stats};
use tiny_qmoe::util::bench::{bench, Table};
use tiny_qmoe::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::seed_from_u64(5);
    let data: Vec<u8> = (0..8 << 20)
        .map(|_| (128.0 + 22.0 * rng.normal_f32()).clamp(0.0, 255.0) as u8)
        .collect();
    let mut t = Table::new(
        "decompression throughput (8 MiB gaussian-code stream)",
        &["codec", "ratio", "decompress MB/s", "compress MB/s"],
    );
    for id in compress::all_codec_ids() {
        let c = compress::codec(id);
        let r = stats::measure(c.as_ref(), &data, None)?;
        let dict = c.train(&[&data]);
        let payload = c.compress(&dict, &data)?;
        let mut out = Vec::new();
        let m = bench(c.name(), 1.0, || {
            c.decompress(&dict, &payload, data.len(), &mut out).unwrap();
        });
        let mc = bench(c.name(), 1.0, || {
            let _ = c.compress(&dict, &data).unwrap();
        });
        t.row(vec![
            c.name().into(),
            format!("{:.3}x", r.ratio_with_dict()),
            format!("{:.0}", data.len() as f64 / 1e6 / m.mean_s),
            format!("{:.0}", data.len() as f64 / 1e6 / mc.mean_s),
        ]);
    }
    t.print();
    Ok(())
}
