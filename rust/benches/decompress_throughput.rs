//! §Perf — decompression throughput on a realistic quantized weight
//! stream (the serving pipeline's hot path), three angles:
//!
//! 1. flat per-codec decompress/compress MB/s (the original table);
//! 2. chunk-parallel decode scaling: `Chunked::decompress_parallel` at
//!    1/2/4/8 threads — the primitive the streaming engine fans layer
//!    decode out over (acceptance: ≥2x at 4 threads on multicore);
//! 3. the fused unpack+dequantize kernel vs the two-pass
//!    unpack-then-dequantize it replaced, at 2/4/6/8 bits.
//!
//! Knobs: `TQM_DECOMP_MB` (stream size, default 8 MiB) and
//! `TQM_BENCH_BUDGET_S` (per-cell time budget, default 1.0 s) shrink the
//! run for CI smoke; `TQM_BENCH_DIR` additionally records the run as
//! `BENCH_decompress.json` for `tqm bench-report`.
use tiny_qmoe::barometer::{self, BenchRecord, BenchSet};
use tiny_qmoe::compress::stream::Chunked;
use tiny_qmoe::compress::{self, stats};
use tiny_qmoe::quant::packing;
use tiny_qmoe::util::bench::{bench, Table};
use tiny_qmoe::util::{env_parse, Rng};

fn gaussian_stream(n: usize) -> Vec<u8> {
    let mut rng = Rng::seed_from_u64(5);
    (0..n).map(|_| (128.0 + 22.0 * rng.normal_f32()).clamp(0.0, 255.0) as u8).collect()
}

fn flat_table(data: &[u8], budget_s: f64, set: &mut BenchSet) -> anyhow::Result<()> {
    let mut t = Table::new(
        "decompression throughput (gaussian-code stream)",
        &["codec", "ratio", "decompress MB/s", "compress MB/s"],
    );
    let mb = data.len() as f64 / 1e6;
    for id in compress::all_codec_ids() {
        let c = compress::codec(id);
        let r = stats::measure(c.as_ref(), data, None)?;
        let dict = c.train(&[data]);
        let payload = c.compress(&dict, data)?;
        let mut out = Vec::new();
        let m = bench(&format!("flat/{}/decompress", c.name()), budget_s, || {
            c.decompress(&dict, &payload, data.len(), &mut out).unwrap();
        });
        let mc = bench(&format!("flat/{}/compress", c.name()), budget_s, || {
            let _ = c.compress(&dict, data).unwrap();
        });
        set.push(BenchRecord::from_measurement(&m).with_throughput(mb / m.mean_s, "MB/s"));
        set.push(BenchRecord::from_measurement(&mc).with_throughput(mb / mc.mean_s, "MB/s"));
        t.row(vec![
            c.name().into(),
            format!("{:.3}x", r.ratio_with_dict()),
            format!("{:.0}", mb / m.mean_s),
            format!("{:.0}", mb / mc.mean_s),
        ]);
    }
    t.print();
    Ok(())
}

fn parallel_table(data: &[u8], budget_s: f64, set: &mut BenchSet) -> anyhow::Result<()> {
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let mut t = Table::new(
        &format!(
            "chunk-parallel decode (256 KiB chunks, {cores} cores) — MB/s and speedup vs 1 thread"
        ),
        &["codec", "1 thread", "2 threads", "4 threads", "8 threads", "4T speedup"],
    );
    let mb = data.len() as f64 / 1e6;
    for id in compress::all_codec_ids() {
        let c = compress::codec(id);
        let ch = Chunked::new(c.as_ref());
        let dict = c.train(&[data]);
        let payload = ch.compress(&dict, data)?;
        let mut mbps = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let m = bench(&format!("parallel/{}/t{threads}", c.name()), budget_s, || {
                let out = ch.decompress_parallel(&dict, &payload, data.len(), threads).unwrap();
                assert_eq!(out.len(), data.len());
            });
            set.push(BenchRecord::from_measurement(&m).with_throughput(mb / m.mean_s, "MB/s"));
            mbps.push(mb / m.mean_s);
        }
        t.row(vec![
            c.name().into(),
            format!("{:.0}", mbps[0]),
            format!("{:.0}", mbps[1]),
            format!("{:.0}", mbps[2]),
            format!("{:.0}", mbps[3]),
            format!("{:.2}x", mbps[2] / mbps[0]),
        ]);
    }
    t.print();
    Ok(())
}

fn fused_table(data: &[u8], budget_s: f64, set: &mut BenchSet) -> anyhow::Result<()> {
    let mut t = Table::new(
        "fused unpack+dequant vs two-pass (Melem/s, per-tensor params)",
        &["bits", "two-pass", "fused", "speedup"],
    );
    let n = data.len();
    for bits in [2u32, 4, 6, 8] {
        let mask = ((1u16 << bits) - 1) as u8;
        let codes: Vec<u8> = data.iter().map(|&b| b & mask).collect();
        let packed = packing::pack(&codes, bits);
        let (scale, zero) = (0.0123f32, 3.0f32);
        let mut f32_out = vec![0.0f32; n];
        let two = bench(&format!("fused/b{bits}/two-pass"), budget_s, || {
            let unpacked = packing::unpack(&packed, bits, n);
            for (o, &c) in f32_out.iter_mut().zip(&unpacked) {
                *o = (c as f32 - zero) * scale;
            }
        });
        let fused = bench(&format!("fused/b{bits}/fused"), budget_s, || {
            packing::unpack_dequant_into(&packed, bits, scale, zero, &mut f32_out);
        });
        let melems = |m: &tiny_qmoe::util::bench::Measurement| n as f64 / 1e6 / m.mean_s;
        set.push(BenchRecord::from_measurement(&two).with_throughput(melems(&two), "Melem/s"));
        set.push(BenchRecord::from_measurement(&fused).with_throughput(melems(&fused), "Melem/s"));
        t.row(vec![
            format!("{bits}"),
            format!("{:.0}", melems(&two)),
            format!("{:.0}", melems(&fused)),
            format!("{:.2}x", two.mean_s / fused.mean_s),
        ]);
    }
    t.print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mb: usize = env_parse("TQM_DECOMP_MB", 8)?;
    let budget_s: f64 = env_parse("TQM_BENCH_BUDGET_S", 1.0)?;
    let data = gaussian_stream(mb.max(1) << 20);
    let mut set = BenchSet::new("decompress");
    flat_table(&data, budget_s, &mut set)?;
    parallel_table(&data, budget_s, &mut set)?;
    fused_table(&data, budget_s, &mut set)?;
    barometer::emit(&set)?;
    Ok(())
}
