//! §Perf — decompression throughput on a realistic quantized weight
//! stream (the serving pipeline's hot path), three angles:
//!
//! 1. flat per-codec decompress/compress MB/s (the original table);
//! 2. chunk-parallel decode scaling: `Chunked::decompress_parallel` at
//!    1/2/4/8 threads — the primitive the streaming engine fans layer
//!    decode out over (acceptance: ≥2x at 4 threads on multicore);
//! 3. the fused unpack+dequantize kernel vs the two-pass
//!    unpack-then-dequantize it replaced, at 2/4/6/8 bits.
use tiny_qmoe::compress::stream::Chunked;
use tiny_qmoe::compress::{self, stats};
use tiny_qmoe::quant::packing;
use tiny_qmoe::util::bench::{bench, Table};
use tiny_qmoe::util::Rng;

fn gaussian_stream(n: usize) -> Vec<u8> {
    let mut rng = Rng::seed_from_u64(5);
    (0..n).map(|_| (128.0 + 22.0 * rng.normal_f32()).clamp(0.0, 255.0) as u8).collect()
}

fn flat_table(data: &[u8]) -> anyhow::Result<()> {
    let mut t = Table::new(
        "decompression throughput (8 MiB gaussian-code stream)",
        &["codec", "ratio", "decompress MB/s", "compress MB/s"],
    );
    for id in compress::all_codec_ids() {
        let c = compress::codec(id);
        let r = stats::measure(c.as_ref(), data, None)?;
        let dict = c.train(&[data]);
        let payload = c.compress(&dict, data)?;
        let mut out = Vec::new();
        let m = bench(c.name(), 1.0, || {
            c.decompress(&dict, &payload, data.len(), &mut out).unwrap();
        });
        let mc = bench(c.name(), 1.0, || {
            let _ = c.compress(&dict, data).unwrap();
        });
        t.row(vec![
            c.name().into(),
            format!("{:.3}x", r.ratio_with_dict()),
            format!("{:.0}", data.len() as f64 / 1e6 / m.mean_s),
            format!("{:.0}", data.len() as f64 / 1e6 / mc.mean_s),
        ]);
    }
    t.print();
    Ok(())
}

fn parallel_table(data: &[u8]) -> anyhow::Result<()> {
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let mut t = Table::new(
        &format!(
            "chunk-parallel decode (256 KiB chunks, {cores} cores) — MB/s and speedup vs 1 thread"
        ),
        &["codec", "1 thread", "2 threads", "4 threads", "8 threads", "4T speedup"],
    );
    for id in compress::all_codec_ids() {
        let c = compress::codec(id);
        let ch = Chunked::new(c.as_ref());
        let dict = c.train(&[data]);
        let payload = ch.compress(&dict, data)?;
        let mut mbps = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let m = bench(c.name(), 1.0, || {
                let out = ch.decompress_parallel(&dict, &payload, data.len(), threads).unwrap();
                assert_eq!(out.len(), data.len());
            });
            mbps.push(data.len() as f64 / 1e6 / m.mean_s);
        }
        t.row(vec![
            c.name().into(),
            format!("{:.0}", mbps[0]),
            format!("{:.0}", mbps[1]),
            format!("{:.0}", mbps[2]),
            format!("{:.0}", mbps[3]),
            format!("{:.2}x", mbps[2] / mbps[0]),
        ]);
    }
    t.print();
    Ok(())
}

fn fused_table(data: &[u8]) -> anyhow::Result<()> {
    let mut t = Table::new(
        "fused unpack+dequant vs two-pass (Melem/s, per-tensor params)",
        &["bits", "two-pass", "fused", "speedup"],
    );
    let n = data.len();
    for bits in [2u32, 4, 6, 8] {
        let mask = ((1u16 << bits) - 1) as u8;
        let codes: Vec<u8> = data.iter().map(|&b| b & mask).collect();
        let packed = packing::pack(&codes, bits);
        let (scale, zero) = (0.0123f32, 3.0f32);
        let mut f32_out = vec![0.0f32; n];
        let two = bench("two-pass", 1.0, || {
            let unpacked = packing::unpack(&packed, bits, n);
            for (o, &c) in f32_out.iter_mut().zip(&unpacked) {
                *o = (c as f32 - zero) * scale;
            }
        });
        let fused = bench("fused", 1.0, || {
            packing::unpack_dequant_into(&packed, bits, scale, zero, &mut f32_out);
        });
        t.row(vec![
            format!("{bits}"),
            format!("{:.0}", n as f64 / 1e6 / two.mean_s),
            format!("{:.0}", n as f64 / 1e6 / fused.mean_s),
            format!("{:.2}x", two.mean_s / fused.mean_s),
        ]);
    }
    t.print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let data = gaussian_stream(8 << 20);
    flat_table(&data)?;
    parallel_table(&data)?;
    fused_table(&data)?;
    Ok(())
}
