//! E8 — residency policy sweep (the paper's §6 per-layer decompression
//! claim): resident vs stream vs stream+prefetch vs LRU, reporting peak
//! weight memory, per-question latency and the decompression share.
use tiny_qmoe::tables;

fn main() -> anyhow::Result<()> {
    let rows = tables::residency_table("e2e", tables::default_codec(), 10)?;
    tables::render_residency(&rows).print();
    Ok(())
}
