//! End-to-end MoE serving-loop bench: real requests through
//! [`tiny_qmoe::coordinator::MoeHost`] (mpsc queue -> batcher -> expert
//! scheduler -> fused qGEMV), the path every envelope and chaos number
//! in the repo ultimately flows through. Cells: expert residency
//! (decoded vs packed cache) x concurrent batch (1 vs 4); the timed unit
//! is "submit the whole batch, wait for every response".
//!
//! Run: `cargo bench --bench moe_serving` (host-side synthetic MoE
//! container, no artifacts needed). `TQM_SERVE_TOKENS` sets the trace
//! length per request (default 32), `TQM_BENCH_BUDGET_S` the per-cell
//! time budget (default 0.5 s); `TQM_BENCH_DIR` additionally records the
//! run as `BENCH_serving.json` for `tqm bench-report`.
//!
//! A final cell pair re-runs the packed/batch-4 cell with the flight
//! recorder force-enabled vs force-disabled
//! (`serve/packed/batch4/trace-{on,off}`), so the tracing overhead is a
//! measured barometer row instead of a promise.

use std::sync::Arc;

use tiny_qmoe::barometer::{self, BenchRecord, BenchSet};
use tiny_qmoe::compress::CodecId;
use tiny_qmoe::config::{ExpertResidency, QuantizeOptions, ServeOptions};
use tiny_qmoe::coordinator::{MoeHost, MoeHostSpec, MoeTraceRequest};
use tiny_qmoe::model::moe;
use tiny_qmoe::util::bench::{bench, fmt_secs, Table};
use tiny_qmoe::util::env_parse;

fn main() -> anyhow::Result<()> {
    let tokens: usize = env_parse::<usize>("TQM_SERVE_TOKENS", 32)?.max(1);
    let budget_s: f64 = env_parse("TQM_BENCH_BUDGET_S", 0.5)?;
    tiny_qmoe::trace::init_from_env()?;

    let cfg = moe::moe_demo_config();
    let spec = cfg.moe.clone().expect("demo config is MoE");
    let ckpt = moe::synth_moe_checkpoint(&cfg, 77)?;
    let qopts = QuantizeOptions { per_channel: true, ..Default::default() };
    let w = moe::quantize_moe_checkpoint(&cfg, &ckpt, &qopts, CodecId::FreqSeqPacked, "synthetic")?;
    let dir = tiny_qmoe::util::TempDir::new()?;
    let path = dir.join("moe.tqm");
    w.write(&path)?;

    let base = moe::clustered_trace(cfg.d_model, 4, 8, tokens, 5);
    // phase-shift per concurrent request so batch cells exercise routing
    // diversity, not four copies of one trace
    let trace_for = |r: usize| -> Vec<Vec<f32>> {
        (0..tokens).map(|t| base[(t + 3 * r) % base.len()].clone()).collect()
    };

    let mut set = BenchSet::new("serving");
    let mut t = Table::new(
        &format!("MoE serving loop — MoeHost end to end ({tokens} steps/request)"),
        &["residency", "batch", "mean/batch", "p99/batch", "tok/s"],
    );
    for residency in [ExpertResidency::Decoded, ExpertResidency::Packed] {
        for batch in [1usize, 4] {
            let reader = Arc::new(tiny_qmoe::format::TqmReader::open(&path)?);
            let serve = ServeOptions {
                expert_residency: residency,
                max_batch: batch,
                max_wait_ms: 1,
                n_threads: 2,
                ..Default::default()
            };
            let host = MoeHost::start(MoeHostSpec {
                reader,
                n_layers: cfg.n_layers,
                moe: spec.clone(),
                serve,
                sched: None,
            })?;
            let name = format!("serve/{}/batch{batch}", residency.label());
            let m = bench(&name, budget_s, || {
                let rxs: Vec<_> = (0..batch)
                    .map(|r| host.submit(MoeTraceRequest::new(trace_for(r))).unwrap())
                    .collect();
                for rx in rxs {
                    rx.recv().unwrap().unwrap();
                }
            });
            host.shutdown();
            let tok_s = (tokens * batch) as f64 / m.mean_s.max(1e-9);
            set.push(BenchRecord::from_measurement(&m).with_throughput(tok_s, "tok/s"));
            t.row(vec![
                residency.label().to_string(),
                format!("{batch}"),
                fmt_secs(m.mean_s),
                fmt_secs(m.p99_s),
                format!("{tok_s:.0}"),
            ]);
        }
    }
    // tracing-overhead pair: identical packed/batch-4 cells, recorder
    // force-enabled vs force-disabled (prior state restored after)
    let prev = tiny_qmoe::trace::enabled();
    for tracing_on in [false, true] {
        tiny_qmoe::trace::set_enabled(tracing_on);
        let batch = 4usize;
        let reader = Arc::new(tiny_qmoe::format::TqmReader::open(&path)?);
        let serve = ServeOptions {
            expert_residency: ExpertResidency::Packed,
            max_batch: batch,
            max_wait_ms: 1,
            n_threads: 2,
            ..Default::default()
        };
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: spec.clone(),
            serve,
            sched: None,
        })?;
        let state = if tracing_on { "on" } else { "off" };
        let name = format!("serve/packed/batch{batch}/trace-{state}");
        let m = bench(&name, budget_s, || {
            let rxs: Vec<_> = (0..batch)
                .map(|r| host.submit(MoeTraceRequest::new(trace_for(r))).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        });
        host.shutdown();
        // discard whatever the cell recorded so ring-buffer contents
        // never leak into a later run's drain
        let _ = tiny_qmoe::trace::drain();
        let tok_s = (tokens * batch) as f64 / m.mean_s.max(1e-9);
        set.push(BenchRecord::from_measurement(&m).with_throughput(tok_s, "tok/s"));
        t.row(vec![
            "packed".to_string(),
            format!("{batch} (trace {state})"),
            fmt_secs(m.mean_s),
            fmt_secs(m.p99_s),
            format!("{tok_s:.0}"),
        ]);
    }
    tiny_qmoe::trace::set_enabled(prev);

    t.print();
    barometer::emit(&set)?;
    Ok(())
}
