//! Byte-budgeted LRU cache of decoded experts — the MoE counterpart of
//! the layer-streaming pipeline.
//!
//! The serving premise is the same as for dense layers (weights live
//! compressed; decoding is the cost), but the access pattern is sparser:
//! a token touches only its routed `top_k` experts, and real traffic
//! reuses experts heavily across consecutive tokens. The cache exploits
//! that:
//!
//! * **hits** return an `Arc<ExpertWeights>` without touching the decoder
//!   at all;
//! * **misses** decode the expert's three matrices through the fused
//!   decompress→dequantize kernel, fanning the per-matrix decodes out
//!   over scoped threads when `n_threads > 1` (each matrix is its own
//!   chunk-framed record, so the decodes are independent);
//! * **eviction is planned, not reactive**: the expert index knows each
//!   expert's decoded f32 size before any decode happens, so the cache
//!   evicts LRU entries *ahead* of the miss, and the decoded-expert
//!   high-water mark (tracked through
//!   [`PipelineMetrics::expert_peak_resident_bytes`], including
//!   in-flight decode bytes) stays under the budget whenever enough
//!   unpinned bytes are evictable to admit the routed expert — the two
//!   documented exceptions are an expert larger than the entire budget
//!   (pure streaming: the miss still decodes, uncached) and pinned
//!   bytes crowding the budget, in both of which the peak metric
//!   honestly reports the overshoot;
//! * **buffers recycle** (the PR-1 machinery): evicted experts donate
//!   their f32 arenas back to a pool the next miss draws from, and the
//!   packed-stream scratch per decode worker is grow-only, so the
//!   steady-state miss path allocates nothing new.
//!
//! Pinning exempts hot experts (e.g. a shared expert, or the top experts
//! of a known-hot tenant) from eviction; pinned bytes still count toward
//! the budget.
//!
//! **Residency modes.** What a cache slot *holds* is the
//! [`crate::config::ExpertResidency`] knob:
//!
//! * `Decoded` — dequantized f32 arenas (the classic mode above);
//! * `Packed` — the container's bit-packed code streams plus quant
//!   params, served through the quantized-domain qGEMV kernels
//!   ([`crate::quant::packing::qgemv`]). A resident expert then costs
//!   its *packed* size (~`bits/32` of decoded), so the same byte budget
//!   keeps ~`32/bits`× more experts warm — and a miss skips the
//!   unpack→dequantize pass entirely (the payload decompress is the
//!   whole decode). Outputs are bit-identical in both modes; only the
//!   residency economics change. Sizing still happens *ahead* of every
//!   decode: the expert index precomputes
//!   [`crate::format::ExpertEntry::packed_resident_bytes`] next to
//!   `decoded_f32_bytes`.
//!
//! **Demand-side reservations.** A demand miss follows the same
//! reserve → decode-outside-lock → commit shape the prefetch workers
//! use: [`ExpertCache::begin_get`] either returns the cached expert or
//! evicts ahead, charges the expert's bytes to an in-flight demand
//! reservation, and hands back a [`DemandReservation`]; the caller
//! decodes **without holding the cache lock** and lands the result with
//! [`ExpertCache::commit_demand`] (or releases it with
//! [`ExpertCache::cancel_demand`]). Residency accounting therefore
//! covers demand-resident + demand-in-flight + speculative bytes at
//! every instant, and a slow miss no longer serializes prefetch commits
//! against the cache lock. [`ExpertCache::get`] keeps the one-call
//! synchronous form (reserve, decode through the pooled-arena fast
//! path, commit) for single-threaded callers.
//!
//! **Speculative (prefetch) entries.** The expert scheduler's prefetch
//! workers land experts *ahead* of a demand through a reserve→commit
//! protocol ([`ExpertCache::begin_speculative`] before the decode,
//! [`ExpertCache::commit_speculative`] /
//! [`ExpertCache::cancel_speculative`] after). Speculative bytes are
//! charged to a separate prefetch slice (`prefetch_budget_bytes`),
//! never to the demand budget, and admission is size-aware *and paid up
//! front*: a prefetch that cannot fit the remaining slice is rejected
//! before any decode allocation exists (older *unused* prefetches may
//! be dropped to make room, demand-resident experts never). A demand
//! `get` that lands on a speculative entry counts as a hit, and the
//! entry is promoted into the demand budget (evicting demand LRU ahead,
//! exactly like a miss admission). Cache-charged residency — demand +
//! speculative, including in-flight prefetch reservations — is
//! therefore bounded by `budget_bytes + prefetch_budget_bytes` at every
//! instant.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ExpertResidency, ServeOptions};
use crate::format::{expert_record_name, TqmReader};
use crate::model::moe::{ExpertBody, ExpertWeights, PackedExpert, EXPERT_MATRIX_NAMES};
use crate::pipeline::PipelineMetrics;
use crate::trace::{self, Category};

/// Upper bound on recycled arenas held per pool. The synchronous miss
/// path drains the pools, but the scheduler's out-of-lock demand decodes
/// never do — without a cap, a budget-constrained long run would push
/// one evicted expert's buffers per eviction forever. Beyond the cap,
/// freed buffers are simply dropped.
const ARENA_POOL_CAP: usize = 12;

/// A cached decoded expert plus its last-use stamp (monotonic clock —
/// exact LRU with O(1) hits; eviction scans for the minimum stamp, so
/// only misses that actually evict pay O(entries)).
struct Slot {
    w: Arc<ExpertWeights>,
    last_used: u64,
    /// Inserted by a prefetch worker and not yet demanded: charged to the
    /// prefetch slice instead of the demand budget, invisible to demand
    /// eviction, dropped (LRU) to admit newer prefetches.
    speculative: bool,
}

pub struct ExpertCache {
    reader: Arc<TqmReader>,
    metrics: Arc<PipelineMetrics>,
    budget_bytes: usize,
    n_threads: usize,
    /// What a resident slot holds: decoded f32 arenas or packed codes.
    residency: ExpertResidency,
    /// (layer, expert) -> resident weights + LRU stamp.
    map: HashMap<(usize, usize), Slot>,
    /// Monotonic use counter backing the LRU stamps.
    clock: u64,
    pinned: HashSet<(usize, usize)>,
    /// Demand-resident bytes (excludes the speculative slice).
    resident_bytes: usize,
    /// Bytes reserved by in-flight demand decodes
    /// ([`ExpertCache::begin_get`] charged them, no commit/cancel yet) —
    /// part of the budget bound, so concurrent misses cannot overshoot.
    demand_inflight_bytes: usize,
    /// Speculative (prefetched, not yet demanded) bytes.
    speculative_bytes: usize,
    /// Recycled f32 weight arenas from evicted *decoded* experts,
    /// capped at [`ARENA_POOL_CAP`]. (Packed experts' col LUTs are
    /// dropped on eviction, not pooled — they are rebuilt fresh per
    /// admission.)
    pool: Vec<Vec<f32>>,
    /// Recycled packed-code arenas from evicted packed experts, capped
    /// at [`ARENA_POOL_CAP`].
    pool_u8: Vec<Vec<u8>>,
    /// Grow-only packed-stream scratch, one per decode worker.
    scratch: Vec<Vec<u8>>,
}

/// Outcome of [`ExpertCache::begin_get`]: either the resident expert, or
/// a charged reservation the caller must decode against and then
/// [`ExpertCache::commit_demand`] / [`ExpertCache::cancel_demand`].
pub enum DemandFetch {
    Hit(Arc<ExpertWeights>),
    Miss(DemandReservation),
}

/// An in-flight demand decode's byte reservation (see the module docs):
/// created by [`ExpertCache::begin_get`] on a miss, consumed by exactly
/// one [`ExpertCache::commit_demand`] or [`ExpertCache::cancel_demand`].
/// It deliberately holds no back-reference to the cache, so merely
/// dropping it leaks the reserved bytes — a caller whose decode can
/// unwind must cancel on the panic path (as
/// [`crate::pipeline::ExpertScheduler::get`] does) before re-raising.
#[derive(Debug)]
pub struct DemandReservation {
    key: (usize, usize),
    bytes: usize,
}

impl DemandReservation {
    /// Reserved byte count (the expert's resident size in this cache's
    /// residency mode).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn key(&self) -> (usize, usize) {
        self.key
    }
}

impl ExpertCache {
    /// `budget_bytes` bounds the decoded-expert residency; `n_threads > 1`
    /// fans an expert's three matrix decodes out over scoped threads.
    pub fn new(
        reader: Arc<TqmReader>,
        metrics: Arc<PipelineMetrics>,
        budget_bytes: usize,
        n_threads: usize,
    ) -> Self {
        Self {
            reader,
            metrics,
            budget_bytes,
            n_threads: n_threads.max(1),
            residency: ExpertResidency::Decoded,
            map: HashMap::new(),
            clock: 0,
            pinned: HashSet::new(),
            resident_bytes: 0,
            demand_inflight_bytes: 0,
            speculative_bytes: 0,
            pool: Vec::new(),
            pool_u8: Vec::new(),
            scratch: vec![Vec::new(); EXPERT_MATRIX_NAMES.len()],
        }
    }

    /// Select what a resident slot holds (builder form; the cache must
    /// be empty, so call it at construction time).
    pub fn with_residency(mut self, residency: ExpertResidency) -> Self {
        assert!(self.map.is_empty(), "cannot switch residency of a populated cache");
        self.residency = residency;
        self
    }

    /// Build from the serving options: budget from
    /// [`ServeOptions::expert_budget_bytes`], residency mode from
    /// [`ServeOptions::expert_residency`], decode fan-out from the
    /// resolved thread count — the constructor the serving paths
    /// ([`crate::pipeline::Engine::expert_cache`], the MoE eval
    /// scenario) go through, so the knobs are honored everywhere.
    pub fn from_options(
        reader: Arc<TqmReader>,
        metrics: Arc<PipelineMetrics>,
        opts: &ServeOptions,
    ) -> Self {
        Self::new(reader, metrics, opts.expert_budget_bytes, opts.resolved_threads())
            .with_residency(opts.expert_residency)
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn residency(&self) -> ExpertResidency {
        self.residency
    }

    /// Switch what *future* admissions hold — the brown-out path
    /// (decoded → packed under cache pressure). Unlike
    /// [`ExpertCache::with_residency`] this is legal on a populated
    /// cache: already-resident entries keep their representation (both
    /// modes are bit-exact, and every byte-accounting path charges each
    /// slot its own `w.bytes()`), so nothing is flushed — old-mode
    /// entries simply age out through normal LRU eviction while new
    /// admissions are sized and decoded in the new mode. Callers that
    /// decode outside the cache lock must capture the residency in the
    /// same critical section as their `begin_get`/`begin_speculative`
    /// so the decoded representation matches the reserved size.
    pub fn set_residency(&mut self, residency: ExpertResidency) {
        self.residency = residency;
    }

    /// What one resident slot for `(layer, expert)` costs this cache's
    /// budget — decoded f32 bytes or packed bytes, both known from the
    /// expert index before any decode happens.
    pub fn need_bytes(&self, layer: usize, expert: usize) -> Result<usize> {
        let e = self.reader.expert_entry(layer, expert)?;
        Ok(match self.residency {
            ExpertResidency::Decoded => e.decoded_f32_bytes,
            ExpertResidency::Packed => e.packed_resident_bytes,
        })
    }

    /// Bytes currently reserved by in-flight demand decodes.
    pub fn demand_inflight_bytes(&self) -> usize {
        self.demand_inflight_bytes
    }

    /// Demand-resident decoded bytes (the part charged to
    /// `budget_bytes`; speculative bytes are reported separately).
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// Speculative (prefetched, not yet demanded) decoded bytes — the
    /// part charged to the scheduler's prefetch slice.
    pub fn speculative_bytes(&self) -> usize {
        self.speculative_bytes
    }

    /// Demand + speculative decoded bytes held right now (bounded by
    /// `budget_bytes + prefetch_budget_bytes`).
    pub fn total_resident_bytes(&self) -> usize {
        self.resident_bytes + self.speculative_bytes
    }

    /// Cached speculative-entry count.
    pub fn speculative_len(&self) -> usize {
        self.map.values().filter(|s| s.speculative).count()
    }

    /// Cached expert count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, layer: usize, expert: usize) -> bool {
        self.map.contains_key(&(layer, expert))
    }

    /// Fetch an expert synchronously: cached -> LRU bump + hit (promoting
    /// speculative entries into the demand budget); missing -> reserve,
    /// decode through the pooled-arena fast path, commit (unless the
    /// expert alone exceeds the budget, in which case it is returned
    /// uncached — pure streaming). The reserve/commit split is also
    /// available directly ([`ExpertCache::begin_get`]) for callers that
    /// want the decode to happen outside the cache lock.
    pub fn get(&mut self, layer: usize, expert: usize) -> Result<Arc<ExpertWeights>> {
        match self.begin_get(layer, expert)? {
            DemandFetch::Hit(w) => Ok(w),
            DemandFetch::Miss(res) => {
                let t0 = Instant::now();
                match self.decode_expert(layer, expert) {
                    Ok(w) => Ok(self.commit_demand(res, Arc::new(w), t0.elapsed())),
                    Err(e) => {
                        self.cancel_demand(res);
                        Err(e)
                    }
                }
            }
        }
    }

    /// First half of a demand fetch: a hit returns the resident expert
    /// (bumping LRU, promoting a speculative entry); a miss evicts ahead
    /// using the index's known size, charges the bytes to an in-flight
    /// demand reservation, and returns it — the caller decodes *without
    /// the cache lock* and must follow up with exactly one
    /// [`ExpertCache::commit_demand`] or [`ExpertCache::cancel_demand`].
    /// Because the reservation is charged before any decode allocation
    /// exists, demand-resident + demand-in-flight + speculative bytes
    /// stay bounded by `budget + prefetch_budget` at every instant
    /// (oversized and pinned-crowded experts overshoot honestly, exactly
    /// as before, and the peak metric reports it).
    pub fn begin_get(&mut self, layer: usize, expert: usize) -> Result<DemandFetch> {
        let key = (layer, expert);
        self.clock += 1;
        if let Some(slot) = self.map.get_mut(&key) {
            slot.last_used = self.clock;
            let w = slot.w.clone();
            let promote = slot.speculative;
            self.metrics.expert_hit(self.residency == ExpertResidency::Packed);
            if promote {
                // a prefetch landed before the demand — no decode stall
                // (promote() records the prefetch hit)
                self.promote(key);
            }
            return Ok(DemandFetch::Hit(w));
        }
        // size known from the expert index — make room before decoding so
        // resident + in-flight bytes never exceed the budget (when a
        // single expert fits it at all)
        let need = self.need_bytes(layer, expert)?;
        self.evict_until_fits(need, None);
        self.demand_inflight_bytes += need;
        self.metrics.observe_expert_transient(
            self.resident_bytes + self.demand_inflight_bytes + self.speculative_bytes,
        );
        Ok(DemandFetch::Miss(DemandReservation { key, bytes: need }))
    }

    /// Land a demand decode on its reservation, returning the canonical
    /// `Arc` for the expert: normally the one passed in (admitted into
    /// the budget when it fits even alongside other in-flight
    /// reservations); if another path landed the same expert while this
    /// decode ran outside the lock, the already-resident one (a racing
    /// prefetch gets promoted). `decode_time` is charged to the demand
    /// stall metric.
    pub fn commit_demand(
        &mut self,
        res: DemandReservation,
        w: Arc<ExpertWeights>,
        decode_time: Duration,
    ) -> Arc<ExpertWeights> {
        let DemandReservation { key, bytes: need } = res;
        self.demand_inflight_bytes -= need;
        debug_assert_eq!(w.bytes(), need, "expert index size disagrees with decode");
        self.metrics.record_expert_miss(
            decode_time,
            need,
            self.residency == ExpertResidency::Packed,
        );
        self.clock += 1;
        if self.map.contains_key(&key) {
            let (existing, promote) = {
                let slot = self.map.get_mut(&key).expect("checked above");
                slot.last_used = self.clock;
                (slot.w.clone(), slot.speculative)
            };
            if promote {
                self.promote(key);
            }
            self.publish_residency();
            return existing;
        }
        if self.resident_bytes + self.demand_inflight_bytes + need <= self.budget_bytes {
            self.map
                .insert(key, Slot { w: w.clone(), last_used: self.clock, speculative: false });
            self.resident_bytes += need;
        }
        self.publish_residency();
        w
    }

    /// Release a demand reservation without landing anything (the decode
    /// failed).
    pub fn cancel_demand(&mut self, res: DemandReservation) {
        self.demand_inflight_bytes -= res.bytes;
    }

    /// Move a just-demanded speculative entry from the prefetch slice
    /// into the demand budget, evicting demand LRU entries ahead exactly
    /// like a miss admission. If the demand budget cannot hold it even
    /// after eviction (pinned bytes or in-flight reservations crowding
    /// it), the entry is dropped — the caller already holds the `Arc`,
    /// so this degrades to the same pure-streaming semantics an
    /// oversized miss has.
    fn promote(&mut self, key: (usize, usize)) {
        // every promotion is a demand consuming a speculative entry —
        // recording the hit HERE (not at the begin_get call site) makes
        // the commit_demand race path (prefetch landed while the demand
        // decode ran outside the lock) count too, which is what lets
        // `issued == hits + wasted` reconcile exactly
        self.metrics.prefetch_hit();
        trace::mark(Category::Cache, "prefetch_hit").layer(key.0).expert(key.1);
        let need = self.map[&key].w.bytes();
        self.speculative_bytes -= need;
        self.evict_until_fits(need, Some(key));
        if self.resident_bytes + self.demand_inflight_bytes + need <= self.budget_bytes {
            self.map.get_mut(&key).expect("promoted entry vanished").speculative = false;
            self.resident_bytes += need;
        } else {
            self.map.remove(&key);
        }
        self.publish_residency();
        self.metrics.set_expert_speculative(self.speculative_bytes);
    }

    /// Push the residency gauges (bytes + entry count) to the shared
    /// metrics — paired with every mutation of `map`/`resident_bytes`.
    fn publish_residency(&self) {
        self.metrics.set_expert_resident(self.resident_bytes);
        self.metrics.set_expert_resident_count(self.map.len());
    }

    /// Size-aware admission gate for a speculative decode, called
    /// **before** the decode happens: reserve the expert's resident size
    /// (mode-aware, from the expert index) out of the prefetch slice
    /// (`prefetch_budget_bytes`) for `(layer, expert)`.
    /// LRU *speculative* entries may be dropped to make room (an unused
    /// prefetch displacing an older unused prefetch); demand-resident
    /// experts are never evicted for a prefetch, and an expert that
    /// could never fit the slice is rejected up front — without
    /// disturbing anything. Because the reservation is charged before
    /// any decode allocation exists, demand + speculative bytes
    /// (including in-flight prefetch decodes) stay bounded by
    /// `budget_bytes + prefetch_budget_bytes` at every instant.
    ///
    /// Returns the reserved byte count; the caller must follow up with
    /// exactly one [`ExpertCache::commit_speculative`] or
    /// [`ExpertCache::cancel_speculative`]. `None` = rejected (already
    /// cached, unknown expert, or cannot fit the slice).
    pub fn begin_speculative(
        &mut self,
        layer: usize,
        expert: usize,
        prefetch_budget_bytes: usize,
    ) -> Option<usize> {
        let key = (layer, expert);
        if self.map.contains_key(&key) {
            return None; // already resident (demand or an earlier prefetch)
        }
        let need = self.need_bytes(layer, expert).ok()?;
        if need > prefetch_budget_bytes {
            return None; // could never fit: reject before evicting anything
        }
        while self.speculative_bytes + need > prefetch_budget_bytes {
            let victim = self
                .map
                .iter()
                .filter(|(_, s)| s.speculative)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| *k);
            let Some(vk) = victim else {
                // remaining slice bytes are in-flight reservations of
                // other workers — nothing evictable, reject
                return None;
            };
            self.drop_slot(vk);
            self.metrics.record_prefetch_evicted_unused();
            trace::mark(Category::Cache, "evict_speculative_unused").layer(vk.0).expert(vk.1);
        }
        self.speculative_bytes += need;
        self.metrics.set_expert_speculative(self.speculative_bytes);
        self.metrics.observe_expert_transient(
            self.resident_bytes + self.demand_inflight_bytes + self.speculative_bytes,
        );
        Some(need)
    }

    /// Land a decoded expert on its reservation. Returns `false` (and
    /// releases the reservation) when the demand path decoded the same
    /// expert while the prefetch was in flight.
    pub fn commit_speculative(
        &mut self,
        layer: usize,
        expert: usize,
        w: Arc<ExpertWeights>,
    ) -> bool {
        let key = (layer, expert);
        if self.map.contains_key(&key) {
            self.cancel_speculative(w.bytes());
            return false;
        }
        self.clock += 1;
        self.map.insert(key, Slot { w, last_used: self.clock, speculative: true });
        self.metrics.record_prefetch_insert();
        self.publish_residency();
        true
    }

    /// Release an unfulfilled reservation (decode failed, or the demand
    /// path won the race).
    pub fn cancel_speculative(&mut self, reserved_bytes: usize) {
        self.speculative_bytes -= reserved_bytes;
        self.metrics.set_expert_speculative(self.speculative_bytes);
    }

    /// One-shot reserve + commit for callers that already hold a decoded
    /// expert (tests, synchronous paths). Returns `false` when admission
    /// rejects it.
    pub fn insert_speculative(
        &mut self,
        layer: usize,
        expert: usize,
        w: Arc<ExpertWeights>,
        prefetch_budget_bytes: usize,
    ) -> bool {
        match self.begin_speculative(layer, expert, prefetch_budget_bytes) {
            Some(reserved) => {
                debug_assert_eq!(reserved, w.bytes(), "index size disagrees with decode");
                self.commit_speculative(layer, expert, w)
            }
            None => false,
        }
    }

    /// Remove one entry, fixing whichever byte pool it was charged to and
    /// recycling its arenas when this cache held the only reference.
    fn drop_slot(&mut self, key: (usize, usize)) {
        if let Some(slot) = self.map.remove(&key) {
            if slot.speculative {
                self.speculative_bytes -= slot.w.bytes();
            } else {
                self.resident_bytes -= slot.w.bytes();
            }
            if let Ok(owned) = Arc::try_unwrap(slot.w) {
                match owned.body {
                    ExpertBody::Decoded { w1, w3, w2 } => {
                        for v in [w1, w3, w2] {
                            if self.pool.len() < ARENA_POOL_CAP {
                                self.pool.push(v);
                            }
                        }
                    }
                    ExpertBody::Packed(p) => {
                        // only the code arenas recycle; the col LUT is
                        // rebuilt fresh per admission, so pooling it
                        // would hoard f32 buffers nothing ever reuses
                        let PackedExpert { w1, w3, w2 } = *p;
                        for m in [w1, w3, w2] {
                            if self.pool_u8.len() < ARENA_POOL_CAP {
                                self.pool_u8.push(m.codes);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Decode (if needed) and exempt an expert from eviction. Errors if
    /// the expert cannot be retained within the budget.
    pub fn pin(&mut self, layer: usize, expert: usize) -> Result<()> {
        let _ = self.get(layer, expert)?;
        anyhow::ensure!(
            self.contains(layer, expert),
            "expert ({layer}, {expert}) does not fit the cache budget; cannot pin"
        );
        self.pinned.insert((layer, expert));
        Ok(())
    }

    pub fn unpin(&mut self, layer: usize, expert: usize) {
        self.pinned.remove(&(layer, expert));
    }

    pub fn is_pinned(&self, layer: usize, expert: usize) -> bool {
        self.pinned.contains(&(layer, expert))
    }

    /// Evict least-recently-used *demand* entries (skipping pinned and
    /// speculative ones — speculative bytes are not charged to this
    /// budget, so evicting them could never help) until `need` more bytes
    /// fit in the budget alongside the in-flight demand reservations, or
    /// nothing evictable remains. `protect` shields a key mid-promotion
    /// from being chosen as its own victim.
    fn evict_until_fits(&mut self, need: usize, protect: Option<(usize, usize)>) {
        while self.resident_bytes + self.demand_inflight_bytes + need > self.budget_bytes {
            let victim = self
                .map
                .iter()
                .filter(|(k, s)| {
                    !s.speculative && !self.pinned.contains(*k) && Some(**k) != protect
                })
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| *k);
            let Some(key) = victim else { break };
            self.drop_slot(key);
            self.metrics.record_expert_eviction();
            trace::mark(Category::Cache, "evict").layer(key.0).expert(key.1);
        }
        self.publish_residency();
    }

    /// Decode one expert into pooled arenas in this cache's residency
    /// mode, fanning the three matrix decodes out over scoped threads
    /// when configured. Produces exactly the bytes
    /// [`ExpertWeights::load`] / [`ExpertWeights::load_packed`] would
    /// (same kernels), which the bit-exactness tests rely on.
    fn decode_expert(&mut self, layer: usize, expert: usize) -> Result<ExpertWeights> {
        match self.residency {
            ExpertResidency::Decoded => self.decode_expert_decoded(layer, expert),
            ExpertResidency::Packed => self.decode_expert_packed(layer, expert),
        }
    }

    fn decode_expert_decoded(&mut self, layer: usize, expert: usize) -> Result<ExpertWeights> {
        let names = [
            expert_record_name(layer, expert, EXPERT_MATRIX_NAMES[0]),
            expert_record_name(layer, expert, EXPERT_MATRIX_NAMES[1]),
            expert_record_name(layer, expert, EXPERT_MATRIX_NAMES[2]),
        ];
        let mut w1 = self.pool.pop().unwrap_or_default();
        let mut w3 = self.pool.pop().unwrap_or_default();
        let mut w2 = self.pool.pop().unwrap_or_default();
        {
            let reader = &*self.reader;
            let parallel = self.n_threads > 1;
            let outs: [&mut Vec<f32>; 3] = [&mut w1, &mut w3, &mut w2];
            let jobs: Vec<(&String, &mut Vec<u8>, &mut Vec<f32>)> = names
                .iter()
                .zip(self.scratch.iter_mut())
                .zip(outs)
                .map(|((n, s), o)| (n, s, o))
                .collect();
            if parallel {
                let results: Vec<Result<()>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = jobs
                        .into_iter()
                        .map(|(name, scratch, out)| {
                            scope.spawn(move || {
                                reader.load_dequantized_into(name, scratch, out)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("expert decode worker panicked"))
                        .collect()
                });
                for r in results {
                    r?;
                }
            } else {
                for (name, scratch, out) in jobs {
                    reader.load_dequantized_into(name, scratch, out)?;
                }
            }
        }
        let r1 = self.reader.record(&names[0])?;
        let (d_model, d_expert) = (r1.shape[0], r1.shape[1]);
        let w = ExpertWeights::decoded(layer, expert, d_model, d_expert, w1, w3, w2);
        w.validate()?;
        Ok(w)
    }

    /// The packed-residency miss path: decompress the three payloads into
    /// pooled u8 arenas, **leaving the codes bit-packed** — no unpack, no
    /// dequantize, no f32 weight allocation. The per-column dequant LUTs
    /// (when profitable) are the only f32 built, once per admission.
    fn decode_expert_packed(&mut self, layer: usize, expert: usize) -> Result<ExpertWeights> {
        let names = [
            expert_record_name(layer, expert, EXPERT_MATRIX_NAMES[0]),
            expert_record_name(layer, expert, EXPERT_MATRIX_NAMES[1]),
            expert_record_name(layer, expert, EXPERT_MATRIX_NAMES[2]),
        ];
        let mut bufs: [Vec<u8>; 3] = [
            self.pool_u8.pop().unwrap_or_default(),
            self.pool_u8.pop().unwrap_or_default(),
            self.pool_u8.pop().unwrap_or_default(),
        ];
        {
            let reader = &*self.reader;
            let jobs: Vec<(&String, &mut Vec<u8>)> = names.iter().zip(bufs.iter_mut()).collect();
            if self.n_threads > 1 {
                let results: Vec<Result<()>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = jobs
                        .into_iter()
                        .map(|(name, out)| {
                            scope.spawn(move || reader.load_packed_into(name, out))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("expert decode worker panicked"))
                        .collect()
                });
                for r in results {
                    r?;
                }
            } else {
                for (name, out) in jobs {
                    reader.load_packed_into(name, out)?;
                }
            }
        }
        ExpertWeights::assemble_packed(&self.reader, layer, expert, bufs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::QuantizeOptions;
    use crate::model::moe::{
        moe_demo_config, quantize_moe_checkpoint, synth_moe_checkpoint,
    };
    use crate::util::TempDir;

    fn demo_reader(chunk_len: usize) -> (crate::config::ModelConfig, TempDir, Arc<TqmReader>) {
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, 17).unwrap();
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, "unit")
            .unwrap()
            .with_chunk_len(chunk_len);
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        (cfg, dir, Arc::new(TqmReader::open(&p).unwrap()))
    }

    fn expert_bytes(reader: &TqmReader) -> usize {
        reader.expert_entry(0, 0).unwrap().decoded_f32_bytes
    }

    #[test]
    fn hit_miss_and_budget_eviction() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        // room for exactly two experts
        let mut cache = ExpertCache::new(reader, metrics.clone(), 2 * one, 1);
        let a = cache.get(0, 0).unwrap();
        let b = cache.get(0, 1).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.resident_bytes(), 2 * one);
        // hits do not decode
        let a2 = cache.get(0, 0).unwrap();
        assert!(Arc::ptr_eq(&a, &a2));
        assert_eq!(metrics.expert_hits_count(), 1);
        assert_eq!(metrics.expert_misses_count(), 2);
        // third expert evicts the LRU one — which is (0,1): (0,0) was
        // touched more recently by the hit
        let _c = cache.get(0, 2).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(0, 0));
        assert!(!cache.contains(0, 1));
        assert!(cache.contains(0, 2));
        assert_eq!(metrics.expert_evictions_count(), 1);
        // the peak never exceeded the budget
        assert!(metrics.expert_peak_resident_bytes() <= 2 * one);
        drop(b);
    }

    #[test]
    fn from_options_honors_the_serving_knobs() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let opts = ServeOptions {
            expert_budget_bytes: 2 * one,
            n_threads: 1,
            ..Default::default()
        };
        let mut cache = ExpertCache::from_options(reader, metrics, &opts);
        assert_eq!(cache.budget_bytes(), 2 * one);
        // the budget really bounds retention: a third expert evicts
        let _ = cache.get(0, 0).unwrap();
        let _ = cache.get(0, 1).unwrap();
        let _ = cache.get(0, 2).unwrap();
        assert_eq!(cache.len(), 2, "expert_budget_bytes knob not applied");
    }

    #[test]
    fn parallel_and_serial_decode_identical() {
        let (_cfg, _dir, reader) = demo_reader(256); // multi-chunk payloads
        let m1 = Arc::new(PipelineMetrics::default());
        let m2 = Arc::new(PipelineMetrics::default());
        let mut serial = ExpertCache::new(reader.clone(), m1, usize::MAX, 1);
        let mut parallel = ExpertCache::new(reader.clone(), m2, usize::MAX, 4);
        for layer in 0..2 {
            for e in 0..3 {
                let a = serial.get(layer, e).unwrap();
                let b = parallel.get(layer, e).unwrap();
                assert_eq!(a.w1(), b.w1(), "layer {layer} expert {e}");
                assert_eq!(a.w3(), b.w3(), "layer {layer} expert {e}");
                assert_eq!(a.w2(), b.w2(), "layer {layer} expert {e}");
                // and both match the fresh-buffer reference decode
                let r = ExpertWeights::load(&reader, layer, e).unwrap();
                assert_eq!(a.w1(), r.w1());
                assert_eq!(a.w2(), r.w2());
            }
        }
    }

    #[test]
    fn packed_parallel_and_serial_decode_identical() {
        let (_cfg, _dir, reader) = demo_reader(256); // multi-chunk payloads
        let m1 = Arc::new(PipelineMetrics::default());
        let m2 = Arc::new(PipelineMetrics::default());
        let mut serial = ExpertCache::new(reader.clone(), m1, usize::MAX, 1)
            .with_residency(ExpertResidency::Packed);
        let mut parallel = ExpertCache::new(reader.clone(), m2, usize::MAX, 4)
            .with_residency(ExpertResidency::Packed);
        let mut rng = crate::util::Rng::seed_from_u64(3);
        for layer in 0..2 {
            for e in 0..3 {
                let a = serial.get(layer, e).unwrap();
                let b = parallel.get(layer, e).unwrap();
                assert!(a.is_packed() && b.is_packed());
                // fresh-buffer packed reference + the decoded reference:
                // all four must agree bit for bit on the ffn output
                let r = ExpertWeights::load_packed(&reader, layer, e).unwrap();
                let dec = ExpertWeights::load(&reader, layer, e).unwrap();
                let x = rng.normal_vec(a.d_model, 1.0);
                let want = dec.ffn(&x);
                assert_eq!(a.ffn(&x), want, "layer {layer} expert {e}");
                assert_eq!(b.ffn(&x), want, "layer {layer} expert {e}");
                assert_eq!(r.ffn(&x), want, "layer {layer} expert {e}");
                assert_eq!(a.bytes(), r.bytes(), "pooled and fresh sizes differ");
            }
        }
    }

    #[test]
    fn packed_residency_multiplies_cache_capacity() {
        // SAME byte budget, both modes: the packed cache must retain
        // strictly more experts and hit strictly more often on a
        // replayed round-robin of 6 experts
        let (_cfg, _dir, reader) = demo_reader(512);
        let one_decoded = expert_bytes(&reader);
        let one_packed = reader.expert_entry(0, 0).unwrap().packed_resident_bytes;
        assert!(
            one_packed * 2 < one_decoded,
            "8-bit per-col demo expert should pack to well under half its f32 size"
        );
        let budget = 2 * one_decoded;
        let mut lens = Vec::new();
        let mut hits = Vec::new();
        for residency in [ExpertResidency::Decoded, ExpertResidency::Packed] {
            let metrics = Arc::new(PipelineMetrics::default());
            let mut cache = ExpertCache::new(reader.clone(), metrics.clone(), budget, 1)
                .with_residency(residency);
            for round in 0..4 {
                for e in 0..6 {
                    let w = cache.get(0, e).unwrap();
                    assert_eq!(
                        w.is_packed(),
                        residency == ExpertResidency::Packed,
                        "round {round}: wrong body for {residency:?}"
                    );
                }
            }
            assert!(
                metrics.expert_peak_resident_bytes() <= budget,
                "{residency:?}: peak {} over budget {budget}",
                metrics.expert_peak_resident_bytes()
            );
            assert_eq!(metrics.expert_resident_count(), cache.len());
            lens.push(cache.len());
            hits.push(metrics.expert_hits_count());
            // per-mode split: packed lookups tallied as packed
            if residency == ExpertResidency::Packed {
                assert_eq!(metrics.expert_packed_hits_count(), metrics.expert_hits_count());
                assert_eq!(metrics.expert_packed_misses_count(), metrics.expert_misses_count());
            } else {
                assert_eq!(metrics.expert_packed_hits_count(), 0);
            }
        }
        assert!(
            lens[1] > lens[0],
            "packed cache held {} experts, decoded {} — packing must multiply capacity",
            lens[1],
            lens[0]
        );
        assert!(hits[1] > hits[0], "packed hits {} not above decoded {}", hits[1], hits[0]);
    }

    #[test]
    fn demand_reservation_reserve_decode_commit() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader.clone(), metrics.clone(), 2 * one, 1);
        // miss -> a charged reservation
        let DemandFetch::Miss(res) = cache.begin_get(0, 0).unwrap() else {
            panic!("cold cache cannot hit");
        };
        assert_eq!(res.bytes(), one);
        assert_eq!(res.key(), (0, 0));
        assert_eq!(cache.demand_inflight_bytes(), one);
        // a second reservation while the first is in flight must leave
        // room for it: both fit a 2-expert budget with no eviction
        let DemandFetch::Miss(res1) = cache.begin_get(0, 1).unwrap() else {
            panic!("distinct expert cannot hit");
        };
        assert_eq!(cache.demand_inflight_bytes(), 2 * one);
        // decode happens outside any lock; commit lands both
        let w0 = Arc::new(ExpertWeights::load(&reader, 0, 0).unwrap());
        let w1 = Arc::new(ExpertWeights::load(&reader, 0, 1).unwrap());
        let got0 = cache.commit_demand(res, w0.clone(), std::time::Duration::from_micros(5));
        assert!(Arc::ptr_eq(&got0, &w0));
        let _ = cache.commit_demand(res1, w1, std::time::Duration::from_micros(5));
        assert_eq!(cache.demand_inflight_bytes(), 0);
        assert_eq!(cache.resident_bytes(), 2 * one);
        assert_eq!(metrics.expert_misses_count(), 2);
        assert!(metrics.expert_peak_resident_bytes() <= 2 * one, "reservations overshot");
        let DemandFetch::Hit(_) = cache.begin_get(0, 0).unwrap() else {
            panic!("committed expert must hit");
        };
        // the demand race: two reservations for the same cold key (the
        // second caller started before the first committed); the loser's
        // commit must hand back the winner's Arc and release its bytes
        let DemandFetch::Miss(ra) = cache.begin_get(1, 1).unwrap() else {
            panic!("cold key cannot hit");
        };
        let DemandFetch::Miss(rb) = cache.begin_get(1, 1).unwrap() else {
            panic!("duplicate in-flight demand still reserves");
        };
        let wa = Arc::new(ExpertWeights::load(&reader, 1, 1).unwrap());
        let wb = Arc::new(ExpertWeights::load(&reader, 1, 1).unwrap());
        let first = cache.commit_demand(ra, wa.clone(), std::time::Duration::from_micros(5));
        assert!(Arc::ptr_eq(&first, &wa));
        let second = cache.commit_demand(rb, wb.clone(), std::time::Duration::from_micros(5));
        assert!(Arc::ptr_eq(&second, &wa), "race loser must get the resident expert");
        assert!(!Arc::ptr_eq(&second, &wb));
        assert_eq!(cache.demand_inflight_bytes(), 0);
        // the duplicate reservation evicted LRU entries to stay in
        // budget, so only the raced expert is resident — charged once
        assert!(cache.contains(1, 1));
        assert_eq!(cache.resident_bytes(), one, "raced expert must be charged exactly once");
        // throughout: reservations + residents never overshot the budget
        assert!(metrics.expert_peak_resident_bytes() <= 2 * one);
        // cancel releases without landing
        let DemandFetch::Miss(res4) = cache.begin_get(1, 0).unwrap() else {
            panic!("cold key cannot hit");
        };
        cache.cancel_demand(res4);
        assert_eq!(cache.demand_inflight_bytes(), 0);
        assert!(!cache.contains(1, 0));
    }

    #[test]
    fn pinned_experts_survive_pressure() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader, metrics, 2 * one, 1);
        cache.pin(0, 5).unwrap();
        assert!(cache.is_pinned(0, 5));
        // churn through every other expert; (0,5) must never leave
        for e in [0usize, 1, 2, 3, 4, 6, 7, 0, 1, 2] {
            let _ = cache.get(0, e).unwrap();
            assert!(cache.contains(0, 5), "pinned expert evicted at {e}");
        }
        cache.unpin(0, 5);
        for e in [0usize, 1, 2] {
            let _ = cache.get(0, e).unwrap();
        }
        assert!(!cache.contains(0, 5), "unpinned expert should age out");
    }

    #[test]
    fn oversized_expert_streams_without_caching() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader, metrics.clone(), one / 2, 1);
        let w = cache.get(0, 0).unwrap();
        assert!(w.bytes() > 0);
        assert!(cache.is_empty(), "over-budget expert must not be retained");
        assert_eq!(cache.resident_bytes(), 0);
        // a second fetch is another miss (pure streaming)
        let _ = cache.get(0, 0).unwrap();
        assert_eq!(metrics.expert_misses_count(), 2);
        assert_eq!(metrics.expert_hits_count(), 0);
        // pinning something that cannot fit is an error
        assert!(cache.pin(0, 1).is_err());
    }

    #[test]
    fn speculative_inserts_respect_the_prefetch_slice() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader.clone(), metrics.clone(), 2 * one, 1);
        // slice holds exactly two experts
        let slice = 2 * one;
        let w0 = Arc::new(ExpertWeights::load(&reader, 0, 0).unwrap());
        let w1 = Arc::new(ExpertWeights::load(&reader, 0, 1).unwrap());
        let w2 = Arc::new(ExpertWeights::load(&reader, 0, 2).unwrap());
        assert!(cache.insert_speculative(0, 0, w0, slice));
        assert!(cache.insert_speculative(0, 1, w1, slice));
        assert_eq!(cache.speculative_bytes(), 2 * one);
        assert_eq!(cache.resident_bytes(), 0, "slice never charges the demand budget");
        // a third prefetch displaces the LRU *speculative* entry
        assert!(cache.insert_speculative(0, 2, w2, slice));
        assert_eq!(cache.speculative_len(), 2);
        assert!(!cache.contains(0, 0), "oldest unused prefetch dropped");
        assert_eq!(metrics.prefetch_wasted_count(), 1, "displaced prefetch counted as waste");
        // an expert bigger than the whole slice is rejected outright
        let big = Arc::new(ExpertWeights::load(&reader, 1, 0).unwrap());
        assert!(!cache.insert_speculative(1, 0, big, one / 2));
        // duplicate of a cached entry is rejected
        let dup = Arc::new(ExpertWeights::load(&reader, 0, 1).unwrap());
        assert!(!cache.insert_speculative(0, 1, dup, slice));
        assert_eq!(metrics.prefetch_inserted_count(), 3);
    }

    #[test]
    fn demanded_speculative_entry_promotes_into_the_budget() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader.clone(), metrics.clone(), 2 * one, 1);
        // fill the demand budget, then prefetch a third expert
        let _ = cache.get(0, 0).unwrap();
        let _ = cache.get(0, 1).unwrap();
        let w2 = Arc::new(ExpertWeights::load(&reader, 0, 2).unwrap());
        assert!(cache.insert_speculative(0, 2, w2, one));
        assert_eq!(cache.total_resident_bytes(), 3 * one);
        // demand for the prefetched expert: a hit (no decode), promoted
        // into the demand budget by evicting the demand LRU (0,0)
        let misses_before = metrics.expert_misses_count();
        let got = cache.get(0, 2).unwrap();
        assert!(got.bytes() > 0);
        assert_eq!(metrics.expert_misses_count(), misses_before, "promotion decoded");
        assert_eq!(metrics.prefetch_hits_count(), 1);
        assert_eq!(cache.speculative_bytes(), 0);
        assert_eq!(cache.resident_bytes(), 2 * one);
        assert!(!cache.contains(0, 0), "demand LRU evicted to admit the promotion");
        assert!(cache.contains(0, 1));
        assert!(cache.contains(0, 2));
        // the combined peak never exceeded budget + slice
        assert!(metrics.expert_peak_resident_bytes() <= 3 * one);
    }

    #[test]
    fn prefetch_never_evicts_demand_and_pins_survive_storms() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader.clone(), metrics.clone(), 2 * one, 1);
        // pin of a not-yet-resident expert decodes it immediately
        assert!(!cache.contains(0, 7));
        cache.pin(0, 7).unwrap();
        assert!(cache.contains(0, 7), "pin must decode a cold expert");
        assert_eq!(metrics.expert_misses_count(), 1);
        let _ = cache.get(0, 6).unwrap(); // budget now full: {pinned 7, 6}
        // prefetch storm far beyond the slice: every layer-1 expert
        let slice = one; // room for a single speculative expert
        for e in 0..8 {
            let w = Arc::new(ExpertWeights::load(&reader, 1, e).unwrap());
            let _ = cache.insert_speculative(1, e, w, slice);
        }
        // demand residents untouched, pinned expert still there, and the
        // slice held at most one speculative expert throughout
        assert!(cache.contains(0, 7), "pinned expert lost to a prefetch storm");
        assert!(cache.contains(0, 6), "demand expert evicted by a prefetch");
        assert_eq!(cache.resident_bytes(), 2 * one);
        assert!(cache.speculative_bytes() <= slice);
        assert!(metrics.expert_peak_resident_bytes() <= 2 * one + slice);
    }

    #[test]
    fn eviction_recycles_buffers() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader, metrics, one, 1);
        // each get evicts the previous expert; its arenas go to the pool,
        // and the next decode drains the pool again
        let w0 = cache.get(0, 0).unwrap();
        drop(w0); // cache holds the only other Arc -> recyclable
        let _w1 = cache.get(0, 1).unwrap();
        let _w2 = cache.get(0, 2).unwrap();
        // pool never grows past one evicted expert's three arenas
        assert!(cache.pool.len() <= 3, "pool holds {} arenas", cache.pool.len());
    }
}
