//! Byte-budgeted LRU cache of decoded experts — the MoE counterpart of
//! the layer-streaming pipeline.
//!
//! The serving premise is the same as for dense layers (weights live
//! compressed; decoding is the cost), but the access pattern is sparser:
//! a token touches only its routed `top_k` experts, and real traffic
//! reuses experts heavily across consecutive tokens. The cache exploits
//! that:
//!
//! * **hits** return an `Arc<ExpertWeights>` without touching the decoder
//!   at all;
//! * **misses** decode the expert's three matrices through the fused
//!   decompress→dequantize kernel, fanning the per-matrix decodes out
//!   over scoped threads when `n_threads > 1` (each matrix is its own
//!   chunk-framed record, so the decodes are independent);
//! * **eviction is planned, not reactive**: the expert index knows each
//!   expert's decoded f32 size before any decode happens, so the cache
//!   evicts LRU entries *ahead* of the miss, and the decoded-expert
//!   high-water mark (tracked through
//!   [`PipelineMetrics::expert_peak_resident_bytes`], including
//!   in-flight decode bytes) stays under the budget whenever enough
//!   unpinned bytes are evictable to admit the routed expert — the two
//!   documented exceptions are an expert larger than the entire budget
//!   (pure streaming: the miss still decodes, uncached) and pinned
//!   bytes crowding the budget, in both of which the peak metric
//!   honestly reports the overshoot;
//! * **buffers recycle** (the PR-1 machinery): evicted experts donate
//!   their f32 arenas back to a pool the next miss draws from, and the
//!   packed-stream scratch per decode worker is grow-only, so the
//!   steady-state miss path allocates nothing new.
//!
//! Pinning exempts hot experts (e.g. a shared expert, or the top experts
//! of a known-hot tenant) from eviction; pinned bytes still count toward
//! the budget.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::ServeOptions;
use crate::format::{expert_record_name, TqmReader};
use crate::model::moe::{ExpertWeights, EXPERT_MATRIX_NAMES};
use crate::pipeline::PipelineMetrics;

/// A cached decoded expert plus its last-use stamp (monotonic clock —
/// exact LRU with O(1) hits; eviction scans for the minimum stamp, so
/// only misses that actually evict pay O(entries)).
struct Slot {
    w: Arc<ExpertWeights>,
    last_used: u64,
}

pub struct ExpertCache {
    reader: Arc<TqmReader>,
    metrics: Arc<PipelineMetrics>,
    budget_bytes: usize,
    n_threads: usize,
    /// (layer, expert) -> decoded weights + LRU stamp.
    map: HashMap<(usize, usize), Slot>,
    /// Monotonic use counter backing the LRU stamps.
    clock: u64,
    pinned: HashSet<(usize, usize)>,
    resident_bytes: usize,
    /// Recycled f32 arenas from evicted experts.
    pool: Vec<Vec<f32>>,
    /// Grow-only packed-stream scratch, one per decode worker.
    scratch: Vec<Vec<u8>>,
}

impl ExpertCache {
    /// `budget_bytes` bounds the decoded-expert residency; `n_threads > 1`
    /// fans an expert's three matrix decodes out over scoped threads.
    pub fn new(
        reader: Arc<TqmReader>,
        metrics: Arc<PipelineMetrics>,
        budget_bytes: usize,
        n_threads: usize,
    ) -> Self {
        Self {
            reader,
            metrics,
            budget_bytes,
            n_threads: n_threads.max(1),
            map: HashMap::new(),
            clock: 0,
            pinned: HashSet::new(),
            resident_bytes: 0,
            pool: Vec::new(),
            scratch: vec![Vec::new(); EXPERT_MATRIX_NAMES.len()],
        }
    }

    /// Build from the serving options: budget from
    /// [`ServeOptions::expert_budget_bytes`], decode fan-out from the
    /// resolved thread count — the constructor the serving paths
    /// ([`crate::pipeline::Engine::expert_cache`], the MoE eval
    /// scenario) go through, so the knobs are honored everywhere.
    pub fn from_options(
        reader: Arc<TqmReader>,
        metrics: Arc<PipelineMetrics>,
        opts: &ServeOptions,
    ) -> Self {
        Self::new(reader, metrics, opts.expert_budget_bytes, opts.resolved_threads())
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Decoded bytes currently cached.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// Cached expert count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, layer: usize, expert: usize) -> bool {
        self.map.contains_key(&(layer, expert))
    }

    /// Fetch an expert: cached -> LRU bump + hit; missing -> evict ahead,
    /// decode, and cache (unless it alone exceeds the budget, in which
    /// case it is returned uncached — pure streaming).
    pub fn get(&mut self, layer: usize, expert: usize) -> Result<Arc<ExpertWeights>> {
        let key = (layer, expert);
        self.clock += 1;
        if let Some(slot) = self.map.get_mut(&key) {
            slot.last_used = self.clock;
            let w = slot.w.clone();
            self.metrics.expert_hit();
            return Ok(w);
        }
        // size known from the expert index — make room before decoding so
        // cached + in-flight bytes never exceed the budget (when a single
        // expert fits it at all)
        let need = self.reader.expert_entry(layer, expert)?.decoded_f32_bytes;
        self.evict_until_fits(need);
        let t0 = Instant::now();
        let w = Arc::new(self.decode_expert(layer, expert)?);
        self.metrics.record_expert_miss(t0.elapsed(), need);
        self.metrics.observe_expert_transient(self.resident_bytes + need);
        debug_assert_eq!(w.bytes(), need, "expert index size disagrees with decode");
        if self.resident_bytes + need <= self.budget_bytes {
            self.map.insert(key, Slot { w: w.clone(), last_used: self.clock });
            self.resident_bytes += need;
            self.metrics.set_expert_resident(self.resident_bytes);
        }
        Ok(w)
    }

    /// Decode (if needed) and exempt an expert from eviction. Errors if
    /// the expert cannot be retained within the budget.
    pub fn pin(&mut self, layer: usize, expert: usize) -> Result<()> {
        let _ = self.get(layer, expert)?;
        anyhow::ensure!(
            self.contains(layer, expert),
            "expert ({layer}, {expert}) does not fit the cache budget; cannot pin"
        );
        self.pinned.insert((layer, expert));
        Ok(())
    }

    pub fn unpin(&mut self, layer: usize, expert: usize) {
        self.pinned.remove(&(layer, expert));
    }

    pub fn is_pinned(&self, layer: usize, expert: usize) -> bool {
        self.pinned.contains(&(layer, expert))
    }

    /// Evict least-recently-used entries (skipping pinned ones) until
    /// `need` more bytes fit in the budget, or nothing evictable remains.
    fn evict_until_fits(&mut self, need: usize) {
        while self.resident_bytes + need > self.budget_bytes {
            let victim = self
                .map
                .iter()
                .filter(|(k, _)| !self.pinned.contains(*k))
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| *k);
            let Some(key) = victim else { break };
            if let Some(slot) = self.map.remove(&key) {
                self.resident_bytes -= slot.w.bytes();
                self.metrics.record_expert_eviction();
                // sole owner -> recycle the arenas for the next miss
                if let Ok(mut owned) = Arc::try_unwrap(slot.w) {
                    self.pool.push(std::mem::take(&mut owned.w1));
                    self.pool.push(std::mem::take(&mut owned.w3));
                    self.pool.push(std::mem::take(&mut owned.w2));
                }
            }
        }
        self.metrics.set_expert_resident(self.resident_bytes);
    }

    /// Decode one expert into pooled arenas, fanning the three matrix
    /// decodes out over scoped threads when configured. Produces exactly
    /// the bytes [`ExpertWeights::load`] would (same fused kernel), which
    /// the bit-exactness tests rely on.
    fn decode_expert(&mut self, layer: usize, expert: usize) -> Result<ExpertWeights> {
        let names = [
            expert_record_name(layer, expert, EXPERT_MATRIX_NAMES[0]),
            expert_record_name(layer, expert, EXPERT_MATRIX_NAMES[1]),
            expert_record_name(layer, expert, EXPERT_MATRIX_NAMES[2]),
        ];
        let mut w1 = self.pool.pop().unwrap_or_default();
        let mut w3 = self.pool.pop().unwrap_or_default();
        let mut w2 = self.pool.pop().unwrap_or_default();
        {
            let reader = &*self.reader;
            let parallel = self.n_threads > 1;
            let outs: [&mut Vec<f32>; 3] = [&mut w1, &mut w3, &mut w2];
            let jobs: Vec<(&String, &mut Vec<u8>, &mut Vec<f32>)> = names
                .iter()
                .zip(self.scratch.iter_mut())
                .zip(outs)
                .map(|((n, s), o)| (n, s, o))
                .collect();
            if parallel {
                let results: Vec<Result<()>> = std::thread::scope(|scope| {
                    let handles: Vec<_> = jobs
                        .into_iter()
                        .map(|(name, scratch, out)| {
                            scope.spawn(move || {
                                reader.load_dequantized_into(name, scratch, out)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("expert decode worker panicked"))
                        .collect()
                });
                for r in results {
                    r?;
                }
            } else {
                for (name, scratch, out) in jobs {
                    reader.load_dequantized_into(name, scratch, out)?;
                }
            }
        }
        let r1 = self.reader.record(&names[0])?;
        let (d_model, d_expert) = (r1.shape[0], r1.shape[1]);
        let w = ExpertWeights { layer, expert, d_model, d_expert, w1, w3, w2 };
        w.validate()?;
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::QuantizeOptions;
    use crate::model::moe::{
        moe_demo_config, quantize_moe_checkpoint, synth_moe_checkpoint,
    };
    use crate::util::TempDir;

    fn demo_reader(chunk_len: usize) -> (crate::config::ModelConfig, TempDir, Arc<TqmReader>) {
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, 17).unwrap();
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, "unit")
            .unwrap()
            .with_chunk_len(chunk_len);
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        (cfg, dir, Arc::new(TqmReader::open(&p).unwrap()))
    }

    fn expert_bytes(reader: &TqmReader) -> usize {
        reader.expert_entry(0, 0).unwrap().decoded_f32_bytes
    }

    #[test]
    fn hit_miss_and_budget_eviction() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        // room for exactly two experts
        let mut cache = ExpertCache::new(reader, metrics.clone(), 2 * one, 1);
        let a = cache.get(0, 0).unwrap();
        let b = cache.get(0, 1).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.resident_bytes(), 2 * one);
        // hits do not decode
        let a2 = cache.get(0, 0).unwrap();
        assert!(Arc::ptr_eq(&a, &a2));
        assert_eq!(metrics.expert_hits_count(), 1);
        assert_eq!(metrics.expert_misses_count(), 2);
        // third expert evicts the LRU one — which is (0,1): (0,0) was
        // touched more recently by the hit
        let _c = cache.get(0, 2).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(0, 0));
        assert!(!cache.contains(0, 1));
        assert!(cache.contains(0, 2));
        assert_eq!(metrics.expert_evictions_count(), 1);
        // the peak never exceeded the budget
        assert!(metrics.expert_peak_resident_bytes() <= 2 * one);
        drop(b);
    }

    #[test]
    fn from_options_honors_the_serving_knobs() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let opts = ServeOptions {
            expert_budget_bytes: 2 * one,
            n_threads: 1,
            ..Default::default()
        };
        let mut cache = ExpertCache::from_options(reader, metrics, &opts);
        assert_eq!(cache.budget_bytes(), 2 * one);
        // the budget really bounds retention: a third expert evicts
        let _ = cache.get(0, 0).unwrap();
        let _ = cache.get(0, 1).unwrap();
        let _ = cache.get(0, 2).unwrap();
        assert_eq!(cache.len(), 2, "expert_budget_bytes knob not applied");
    }

    #[test]
    fn parallel_and_serial_decode_identical() {
        let (_cfg, _dir, reader) = demo_reader(256); // multi-chunk payloads
        let m1 = Arc::new(PipelineMetrics::default());
        let m2 = Arc::new(PipelineMetrics::default());
        let mut serial = ExpertCache::new(reader.clone(), m1, usize::MAX, 1);
        let mut parallel = ExpertCache::new(reader.clone(), m2, usize::MAX, 4);
        for layer in 0..2 {
            for e in 0..3 {
                let a = serial.get(layer, e).unwrap();
                let b = parallel.get(layer, e).unwrap();
                assert_eq!(a.w1, b.w1, "layer {layer} expert {e}");
                assert_eq!(a.w3, b.w3, "layer {layer} expert {e}");
                assert_eq!(a.w2, b.w2, "layer {layer} expert {e}");
                // and both match the fresh-buffer reference decode
                let r = ExpertWeights::load(&reader, layer, e).unwrap();
                assert_eq!(a.w1, r.w1);
                assert_eq!(a.w2, r.w2);
            }
        }
    }

    #[test]
    fn pinned_experts_survive_pressure() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader, metrics, 2 * one, 1);
        cache.pin(0, 5).unwrap();
        assert!(cache.is_pinned(0, 5));
        // churn through every other expert; (0,5) must never leave
        for e in [0usize, 1, 2, 3, 4, 6, 7, 0, 1, 2] {
            let _ = cache.get(0, e).unwrap();
            assert!(cache.contains(0, 5), "pinned expert evicted at {e}");
        }
        cache.unpin(0, 5);
        for e in [0usize, 1, 2] {
            let _ = cache.get(0, e).unwrap();
        }
        assert!(!cache.contains(0, 5), "unpinned expert should age out");
    }

    #[test]
    fn oversized_expert_streams_without_caching() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader, metrics.clone(), one / 2, 1);
        let w = cache.get(0, 0).unwrap();
        assert!(w.bytes() > 0);
        assert!(cache.is_empty(), "over-budget expert must not be retained");
        assert_eq!(cache.resident_bytes(), 0);
        // a second fetch is another miss (pure streaming)
        let _ = cache.get(0, 0).unwrap();
        assert_eq!(metrics.expert_misses_count(), 2);
        assert_eq!(metrics.expert_hits_count(), 0);
        // pinning something that cannot fit is an error
        assert!(cache.pin(0, 1).is_err());
    }

    #[test]
    fn eviction_recycles_buffers() {
        let (_cfg, _dir, reader) = demo_reader(512);
        let metrics = Arc::new(PipelineMetrics::default());
        let one = expert_bytes(&reader);
        let mut cache = ExpertCache::new(reader, metrics, one, 1);
        // each get evicts the previous expert; its arenas go to the pool,
        // and the next decode drains the pool again
        let w0 = cache.get(0, 0).unwrap();
        drop(w0); // cache holds the only other Arc -> recyclable
        let _w1 = cache.get(0, 1).unwrap();
        let _w2 = cache.get(0, 2).unwrap();
        // pool never grows past one evicted expert's three arenas
        assert!(cache.pool.len() <= 3, "pool holds {} arenas", cache.pool.len());
    }
}
