//! Expert scheduler: the proactive half of MoE serving (the reactive
//! half being the byte-budgeted [`ExpertCache`]). It sits between the
//! coordinator's batcher and the cache and does three things per forward
//! step:
//!
//! 1. **Batch-aware decode dedup** — the routed top-k picks of *all*
//!    sequences in a batch are collected into one [`LayerPlan`] per
//!    layer, so an expert chosen by eight sequences is fetched (and, on a
//!    miss, decoded) exactly once and held for the whole step.
//! 2. **Router-logit prefetch** — while layer *l*'s math executes, a
//!    background [`PrefetchPool`] decodes layer *l+1*'s likeliest
//!    experts into the cache's speculative slice (kicked after layer
//!    *l*'s fetch, so fresh reservations can only displace *stale*
//!    prefetches, never entries this step is about to consume).
//!    Prediction blends the next router's gating probabilities on the
//!    batch's current hidden states with an [`EwmaPrior`] of expert
//!    popularity. The slice is bounded by `prefetch_budget_bytes`,
//!    charged by reservation *before* the background decode, and
//!    admission is size-aware, so prefetch can never evict what the
//!    current step needs. Demand misses use the same
//!    reserve → decode-outside-lock → commit shape
//!    ([`ExpertCache::begin_get`]), so a slow demand decode no longer
//!    serializes prefetch commits against the cache lock.
//! 3. **Scheduling counters** — dedup factor, prefetch hit/waste, and
//!    the decode stall the forward step actually paid, all through the
//!    shared [`PipelineMetrics`].
//!
//! Dataflow: `batcher -> ExpertScheduler::forward_batch -> LayerPlan ->
//! ExpertCache (demand) + PrefetchPool (speculative) -> MoE math`.

pub mod plan;
pub mod prefetch;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{ExpertResidency, MoeSpec, ServeOptions};
use crate::faults::{MoeError, Quarantine, QuarantineCheck};
use crate::format::TqmReader;
use crate::model::moe::{
    moe_layer_forward_batched, moe_layer_forward_grouped, ExpertWeights, Router,
};
use crate::pipeline::expert_cache::DemandFetch;
use crate::pipeline::{ExpertCache, PipelineMetrics};
use crate::trace::{self, Category};
use crate::util::lock_recover;

pub use plan::LayerPlan;
pub use prefetch::{EwmaPrior, PrefetchPool};

/// Weight of the EWMA popularity prior relative to the (mean) router
/// gating probability when ranking prefetch candidates.
const PRIOR_WEIGHT: f64 = 0.25;

/// Scheduler configuration, usually derived from [`ServeOptions`].
#[derive(Clone, Debug)]
pub struct SchedOptions {
    /// Master switch for the speculative half (dedup always applies).
    pub prefetch: bool,
    /// Byte bound of the cache's speculative slice.
    pub prefetch_budget_bytes: usize,
    /// Background decode workers.
    pub prefetch_workers: usize,
    /// Decay of the EWMA popularity prior.
    pub ewma_decay: f64,
    /// Deterministic mode: wait for queued prefetches to land before
    /// fetching each layer (tests/benches want reproducible hit counts;
    /// production leaves this off so decode overlaps compute). Fully
    /// reproducible slice contents additionally require
    /// `prefetch_workers == 1` — with more workers the commit order,
    /// and thus the slice's LRU stamps, still race.
    pub sync_prefetch: bool,
    /// Execute each (layer, expert)'s deduped token group as one batched
    /// qGEMM call ([`crate::model::moe::moe_layer_forward_grouped`]) —
    /// one traversal of the expert's packed streams per step — instead
    /// of one qGEMV per routed pick. Exact accumulation: outputs are
    /// bit-identical either way; the per-step batched-vs-scalar metrics
    /// are what differ.
    pub batched_qgemm: bool,
    /// Retries after a failed expert fetch/decode before the failure is
    /// surfaced (and counted against the expert). 0 = fail fast.
    pub retry_budget: u32,
    /// Base backoff between retries, doubling per attempt (bounded).
    pub retry_backoff_ms: u64,
    /// Consecutive decode/CRC failures before an expert is quarantined
    /// (dropped from routing with gates renormalized over survivors).
    /// 0 disables quarantine.
    pub quarantine_after: u32,
    /// Re-probe a quarantined expert every N forward steps (0 = never).
    pub quarantine_probe_every: u64,
}

impl Default for SchedOptions {
    fn default() -> Self {
        Self::from_serve(&ServeOptions::default())
    }
}

impl SchedOptions {
    pub fn from_serve(o: &ServeOptions) -> Self {
        Self {
            prefetch: o.prefetch_budget_bytes > 0,
            prefetch_budget_bytes: o.prefetch_budget_bytes,
            prefetch_workers: o.prefetch_workers,
            ewma_decay: o.prefetch_ewma_decay,
            sync_prefetch: false,
            batched_qgemm: o.batched_qgemm,
            retry_budget: o.retry_budget,
            retry_backoff_ms: o.retry_backoff_ms,
            quarantine_after: o.quarantine_after,
            quarantine_probe_every: o.quarantine_probe_every,
        }
    }
}

/// How an expert fetch failed — retry/quarantine policy only applies to
/// decode-class failures; structural ones (expert not in the container)
/// keep the old fail-fast semantics.
enum FetchError {
    /// The container has no such expert / the reservation itself failed.
    /// Retrying cannot help and quarantine bookkeeping must not trigger.
    Hard(anyhow::Error),
    /// The payload fetch or decode failed (IO fault, CRC mismatch) after
    /// exhausting the retry budget — quarantine bookkeeping applies.
    Decode(anyhow::Error),
}

impl FetchError {
    fn into_inner(self) -> anyhow::Error {
        match self {
            FetchError::Hard(e) | FetchError::Decode(e) => e,
        }
    }
}

/// The scheduling subsystem: owns the expert cache (behind a mutex so the
/// prefetch workers can feed its speculative slice) and the worker pool.
pub struct ExpertScheduler {
    cache: Arc<Mutex<ExpertCache>>,
    /// Container index — candidate selection caps a step's prefetch set
    /// to what the slice can hold, using the known resident sizes.
    reader: Arc<TqmReader>,
    metrics: Arc<PipelineMetrics>,
    /// Popularity prior, persisted across steps (and batches) — the
    /// workload-skew half of the prefetch score.
    prior: Mutex<EwmaPrior>,
    pool: Option<PrefetchPool>,
    /// Poisoned-expert bookkeeping: failure counts, routing exclusion,
    /// periodic recovery probes. Inactive when `quarantine_after == 0`.
    quarantine: Arc<Quarantine>,
    opts: SchedOptions,
}

impl ExpertScheduler {
    /// Wrap `cache` (built for the same container `reader` serves) into a
    /// scheduler for a model of `n_layers` MoE sublayers with `n_experts`
    /// experts each.
    pub fn new(
        reader: Arc<TqmReader>,
        metrics: Arc<PipelineMetrics>,
        cache: ExpertCache,
        n_layers: usize,
        n_experts: usize,
        opts: SchedOptions,
    ) -> Self {
        let cache = Arc::new(Mutex::new(cache));
        let pool = (opts.prefetch && opts.prefetch_budget_bytes > 0).then(|| {
            PrefetchPool::new(
                cache.clone(),
                reader.clone(),
                metrics.clone(),
                opts.prefetch_budget_bytes,
                opts.prefetch_workers,
                opts.retry_budget,
            )
        });
        let quarantine =
            Arc::new(Quarantine::new(opts.quarantine_after, opts.quarantine_probe_every));
        Self {
            cache,
            reader,
            metrics,
            prior: Mutex::new(EwmaPrior::new(n_layers, n_experts, opts.ewma_decay)),
            pool,
            quarantine,
            opts,
        }
    }

    pub fn metrics(&self) -> &Arc<PipelineMetrics> {
        &self.metrics
    }

    /// Shared handle to the underlying cache (pin management, tests).
    pub fn cache_handle(&self) -> Arc<Mutex<ExpertCache>> {
        self.cache.clone()
    }

    /// The scheduler's quarantine state (host reports, tests).
    pub fn quarantine(&self) -> &Arc<Quarantine> {
        &self.quarantine
    }

    /// Demand-fetch one expert through the cache (single-sequence paths
    /// that still want the scheduler's cache + prefetch machinery). A
    /// miss reserves under the lock, decodes **without** it — so
    /// prefetch workers keep committing while the demand decode runs —
    /// and commits the result (demand-side reservations). Decode-class
    /// failures (IO fault, CRC mismatch) are retried up to
    /// `retry_budget` times with bounded exponential backoff.
    pub fn get(&self, layer: usize, expert: usize) -> Result<Arc<ExpertWeights>> {
        self.get_classified(layer, expert).map_err(FetchError::into_inner)
    }

    /// One reservation + decode attempt, no retry.
    fn get_once(&self, layer: usize, expert: usize) -> Result<Arc<ExpertWeights>, FetchError> {
        // residency is captured in the SAME critical section as the
        // reservation: a brown-out flipping the cache to packed between
        // begin_get and the decode would otherwise land a body whose
        // size disagrees with what the reservation charged
        let (fetch, residency) = {
            let mut cache = lock_recover(&self.cache);
            let fetch = cache.begin_get(layer, expert).map_err(FetchError::Hard)?;
            (fetch, cache.residency())
        };
        match fetch {
            DemandFetch::Hit(w) => Ok(w),
            DemandFetch::Miss(res) => {
                let _stall =
                    trace::span(Category::Stall, "demand_decode").layer(layer).expert(expert);
                let t0 = Instant::now();
                // the decode runs with no cache lock held, so a panic in
                // it would otherwise drop the reservation uncancelled and
                // shrink the effective budget forever — catch, release,
                // re-raise
                let decoded = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    ExpertWeights::load_with(&self.reader, layer, expert, residency)
                }));
                match decoded {
                    Ok(Ok(w)) => Ok(lock_recover(&self.cache).commit_demand(
                        res,
                        Arc::new(w),
                        t0.elapsed(),
                    )),
                    Ok(Err(e)) => {
                        lock_recover(&self.cache).cancel_demand(res);
                        Err(FetchError::Decode(e))
                    }
                    Err(panic) => {
                        lock_recover(&self.cache).cancel_demand(res);
                        std::panic::resume_unwind(panic)
                    }
                }
            }
        }
    }

    /// The retry loop around [`Self::get_once`], keeping the hard/decode
    /// error classification for the batch path's degradation policy.
    fn get_classified(
        &self,
        layer: usize,
        expert: usize,
    ) -> Result<Arc<ExpertWeights>, FetchError> {
        let mut last: Option<FetchError> = None;
        for attempt in 0..=self.opts.retry_budget {
            if attempt > 0 {
                self.metrics.record_fetch_retry();
                trace::mark(Category::Retry, "retry").layer(layer).expert(expert);
                let backoff =
                    self.opts.retry_backoff_ms.saturating_mul(1u64 << (attempt - 1).min(6));
                if backoff > 0 {
                    let _backoff =
                        trace::span(Category::Retry, "backoff").layer(layer).expert(expert);
                    std::thread::sleep(Duration::from_millis(backoff.min(64)));
                }
            }
            match self.get_once(layer, expert) {
                Ok(w) => {
                    if attempt > 0 {
                        self.metrics.record_retry_success();
                    }
                    return Ok(w);
                }
                // structural failure: retrying cannot materialize a
                // missing container record — fail fast, old semantics
                Err(e @ FetchError::Hard(_)) => return Err(e),
                Err(e @ FetchError::Decode(_)) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            FetchError::Hard(anyhow::anyhow!("expert ({layer}, {expert}) fetch failed"))
        }))
    }

    /// Decode (if needed) and exempt an expert from eviction.
    pub fn pin(&self, layer: usize, expert: usize) -> Result<()> {
        lock_recover(&self.cache).pin(layer, expert)
    }

    pub fn unpin(&self, layer: usize, expert: usize) {
        lock_recover(&self.cache).unpin(layer, expert)
    }

    /// Wait until every queued prefetch job has been processed.
    pub fn quiesce(&self) {
        if let Some(pool) = &self.pool {
            pool.quiesce();
        }
    }

    /// Brown-out: switch the cache to packed residency for all future
    /// admissions (~`32/bits`× more experts per byte of budget, bit-exact
    /// outputs) — the host's answer to sustained demand-miss stall when
    /// shrinking the batch is not enough. Already-resident decoded
    /// entries age out through normal LRU; in-flight decodes finish in
    /// the mode their reservation captured, so byte accounting stays
    /// exact across the flip. Returns `false` (and records nothing) when
    /// the cache is already packed.
    pub fn brownout_to_packed(&self) -> bool {
        let mut cache = lock_recover(&self.cache);
        if cache.residency() == ExpertResidency::Packed {
            return false;
        }
        cache.set_residency(ExpertResidency::Packed);
        self.metrics.record_brownout();
        trace::mark(Category::Cache, "brownout_packed");
        true
    }

    /// One forward step for a whole batch through a stack of MoE
    /// sublayers with residual connections (`x <- x + moe_l(x)`):
    /// plan -> prefetch next layer -> fetch each unique expert once ->
    /// per-sequence gated math in router order. Bit-exact against running
    /// [`crate::model::moe::moe_stack_forward`] per sequence.
    pub fn forward_batch(
        &self,
        routers: &[Router],
        spec: &MoeSpec,
        xs0: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        if xs0.is_empty() {
            return Ok(Vec::new());
        }
        let _step = trace::span(Category::Step, "forward_batch");
        let t_wall = Instant::now();
        self.quarantine.tick_step();
        let mut xs: Vec<Vec<f32>> = xs0.to_vec();
        for (l, router) in routers.iter().enumerate() {
            let plan = {
                let _plan = trace::span(Category::Plan, "layer_plan").layer(l);
                LayerPlan::build(l, router, &xs, spec.top_k)
            };
            self.metrics
                .record_sched_plan(plan.routed_picks() as u64, plan.n_unique() as u64);
            lock_recover(&self.prior).observe(l, &plan.unique);
            // quarantine filter: drop experts currently out of rotation
            // from every sequence's picks and renormalize the surviving
            // gates. A probe-due expert stays in (its fetch below is the
            // recovery attempt). Faults off / nothing quarantined — no
            // pick changes and the step is bit-exact with the unfiltered
            // path.
            let mut picks = plan.picks;
            let mut unique = plan.unique;
            let mut excluded = Vec::new();
            for &e in &unique {
                match self.quarantine.check(l, e) {
                    QuarantineCheck::Quarantined => excluded.push(e),
                    QuarantineCheck::Probe => {
                        self.metrics.record_quarantine_probe();
                        trace::mark(Category::Fault, "quarantine_probe").layer(l).expert(e);
                    }
                    QuarantineCheck::Clear => {}
                }
            }
            for &e in &excluded {
                drop_expert_from_step(&mut picks, &mut unique, e, l, &self.metrics)?;
            }
            if self.opts.sync_prefetch {
                // deterministic mode: the jobs kicked at layer l-1 (for
                // this layer) must land before the fetch below
                let _wait = trace::span(Category::Stall, "sync_prefetch_wait").layer(l);
                self.quiesce();
            }
            // the dedup: each unique expert fetched once, held for the
            // whole step (a tight budget can no longer force two decodes
            // of one expert within a step). Fetching *before* kicking the
            // next layer's prefetch also promotes this layer's
            // speculative entries out of the slice, so the new
            // reservations below can only ever displace stale prefetches,
            // never the ones this step is about to consume. Each miss
            // decodes outside the cache lock (demand-side reservations),
            // so in-flight prefetch commits interleave with it.
            let mut fetched: HashMap<usize, Arc<ExpertWeights>> =
                HashMap::with_capacity(unique.len());
            for &e in &unique.clone() {
                match self.get_classified(l, e) {
                    Ok(w) => {
                        if self.quarantine.record_success(l, e) {
                            self.metrics.record_quarantine_recovery();
                        }
                        fetched.insert(e, w);
                    }
                    // structural failure (expert not in the container):
                    // not a media fault — fail the step like always
                    Err(FetchError::Hard(e)) => return Err(e),
                    // decode-class failure with the retry budget spent:
                    // degrade — drop this expert from the step, count the
                    // failure toward quarantine, keep serving
                    Err(FetchError::Decode(err)) => {
                        if self.quarantine.record_failure(l, e) {
                            self.metrics.record_quarantined();
                            trace::mark(Category::Fault, "quarantined").layer(l).expert(e);
                        }
                        self.metrics.record_expert_drop();
                        trace::mark(Category::Fault, "expert_drop").layer(l).expert(e);
                        drop_expert_from_step(&mut picks, &mut unique, e, l, &self.metrics)
                            .map_err(|gone| gone.context(err))?;
                    }
                }
            }
            if let Some(pool) = &self.pool {
                // warm layer l+1 while this layer's math executes
                // (prediction uses xs before the residual update — the
                // same one-layer-early basis either way)
                if let Some(next) = routers.get(l + 1) {
                    for e in self.prefetch_candidates(next, l + 1, &xs, spec.top_k) {
                        pool.enqueue(l + 1, e);
                    }
                }
            }
            // honest residency: under a budget smaller than the batch's
            // union, some held Arcs outlive their cache slots (evicted
            // or never admitted) — the dedup trades bounded decode count
            // for holding one layer's unique set. Fold that overhang
            // into the shared peak so it is visible, never silent.
            {
                let cache = lock_recover(&self.cache);
                let held_uncached: usize = fetched
                    .iter()
                    .filter(|(e, _)| !cache.contains(l, **e))
                    .map(|(_, w)| w.bytes())
                    .sum();
                if held_uncached > 0 {
                    self.metrics.observe_expert_transient(
                        cache.total_resident_bytes() + held_uncached,
                    );
                }
            }
            let fetch = |e: usize| {
                fetched
                    .get(&e)
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("expert {e} missing from plan"))
            };
            let surviving_picks: usize = picks.iter().map(|p| p.len()).sum();
            let exec_span = trace::span(Category::Exec, "moe_exec").layer(l);
            let t_exec = Instant::now();
            let ys = if self.opts.batched_qgemm {
                // one ffn_batch (three qGEMM traversals) per unique
                // expert for its whole deduped token group
                let (ys, stats) = moe_layer_forward_grouped(&xs, &picks, fetch)?;
                self.metrics.record_exec_batched(stats.groups, stats.tokens);
                ys
            } else {
                self.metrics.record_exec_scalar(surviving_picks as u64);
                moe_layer_forward_batched(&xs, &picks, fetch)?
            };
            for (x, y) in xs.iter_mut().zip(ys) {
                for (xi, yi) in x.iter_mut().zip(y) {
                    *xi += yi;
                }
            }
            self.metrics.record_exec(t_exec.elapsed());
            drop(exec_span);
        }
        self.metrics.record_forward_wall(t_wall.elapsed());
        Ok(xs)
    }

    /// Rank layer `layer`'s experts for prefetch: mean gating probability
    /// of its router over the batch's *current* hidden states (the step
    /// is still one layer earlier, so this is a one-layer-early estimate)
    /// blended with the EWMA popularity prior; already-resident experts
    /// are skipped. Best candidates first, capped at one batch worth of
    /// picks plus `top_k` slack.
    fn prefetch_candidates(
        &self,
        router: &Router,
        layer: usize,
        xs: &[Vec<f32>],
        top_k: usize,
    ) -> Vec<usize> {
        let ne = router.n_experts();
        let mut score = vec![0f64; ne];
        for x in xs {
            for (e, p) in router.gating_probs(x).into_iter().enumerate() {
                score[e] += p as f64;
            }
        }
        let n = xs.len().max(1) as f64;
        {
            let prior = lock_recover(&self.prior);
            for (e, s) in score.iter_mut().enumerate() {
                *s = *s / n + PRIOR_WEIGHT * prior.score(layer, e);
            }
        }
        let mut idx: Vec<usize> = (0..ne).collect();
        idx.sort_by(|&a, &b| {
            score[b]
                .partial_cmp(&score[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate((top_k * xs.len() + top_k).min(ne));
        let residency = {
            // skip residents and quarantined experts (`is_quarantined` is
            // the passive probe-free check — speculative filtering must
            // not consume the demand path's periodic recovery probe)
            let cache = lock_recover(&self.cache);
            idx.retain(|&e| {
                !cache.contains(layer, e) && !self.quarantine.is_quarantined(layer, e)
            });
            cache.residency()
        };
        // cap the step's candidate set to what the slice can hold, best
        // first — otherwise a burst of same-step inserts would displace
        // its own best predictions through the slice's LRU
        let mut bytes = 0usize;
        let mut kept = Vec::with_capacity(idx.len());
        for e in idx {
            let need = match self.reader.expert_entry(layer, e) {
                Ok(entry) => match residency {
                    ExpertResidency::Decoded => entry.decoded_f32_bytes,
                    ExpertResidency::Packed => entry.packed_resident_bytes,
                },
                Err(_) => continue,
            };
            if bytes + need > self.opts.prefetch_budget_bytes {
                break;
            }
            bytes += need;
            kept.push(e);
        }
        kept
    }
}

/// Remove `expert` from a step's plan: strip its `(expert, gate)` picks
/// from every sequence, renormalize each affected sequence's surviving
/// gates to sum to 1, and drop it from the unique fetch set. Dropping
/// experts one at a time composes — the final gates equal excluding the
/// same set up front, because renormalization is division by the current
/// survivor sum. Errors with [`MoeError::Quarantined`] when a sequence
/// is left with no experts at all: degraded serving must never silently
/// zero a token's update.
fn drop_expert_from_step(
    picks: &mut [Vec<(usize, f32)>],
    unique: &mut Vec<usize>,
    expert: usize,
    layer: usize,
    metrics: &PipelineMetrics,
) -> Result<()> {
    unique.retain(|&u| u != expert);
    for seq in picks.iter_mut() {
        let before = seq.len();
        seq.retain(|&(e, _)| e != expert);
        let dropped = before - seq.len();
        if dropped == 0 {
            continue;
        }
        metrics.record_degraded_picks(dropped as u64);
        if seq.is_empty() {
            return Err(anyhow::Error::new(MoeError::Quarantined { layer }));
        }
        let sum: f32 = seq.iter().map(|&(_, g)| g).sum();
        if sum > 0.0 {
            for (_, g) in seq.iter_mut() {
                *g /= sum;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::QuantizeOptions;
    use crate::model::moe::{
        clustered_trace, load_routers, moe_demo_config, moe_stack_forward,
        quantize_moe_checkpoint, synth_moe_checkpoint, ExpertWeights,
    };
    use crate::util::TempDir;

    fn demo(seed: u64) -> (crate::config::ModelConfig, TempDir, Arc<TqmReader>) {
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, seed).unwrap();
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, "unit")
            .unwrap()
            .with_chunk_len(512);
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        (cfg, dir, Arc::new(TqmReader::open(&p).unwrap()))
    }

    fn scheduler(
        reader: &Arc<TqmReader>,
        cfg: &crate::config::ModelConfig,
        budget: usize,
        opts: SchedOptions,
    ) -> (ExpertScheduler, Arc<PipelineMetrics>) {
        let spec = cfg.moe.as_ref().unwrap();
        let metrics = Arc::new(PipelineMetrics::default());
        let cache = ExpertCache::new(reader.clone(), metrics.clone(), budget, 1);
        let sched = ExpertScheduler::new(
            reader.clone(),
            metrics.clone(),
            cache,
            cfg.n_layers,
            spec.n_experts,
            opts,
        );
        (sched, metrics)
    }

    #[test]
    fn batched_forward_matches_per_sequence_path() {
        let (cfg, _dir, reader) = demo(41);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let opts = SchedOptions {
            sync_prefetch: true,
            prefetch_budget_bytes: 1 << 20,
            ..SchedOptions::default()
        };
        let (sched, _m) = scheduler(&reader, &cfg, usize::MAX, opts);
        let xs = clustered_trace(cfg.d_model, 3, 1, 4, 13);
        let batched = sched.forward_batch(&routers, &spec, &xs).unwrap();
        for (x, got) in xs.iter().zip(&batched) {
            let want = moe_stack_forward(&routers, &spec, x, |l, e| sched.get(l, e)).unwrap();
            assert_eq!(got, &want, "scheduled forward diverged");
        }
    }

    #[test]
    fn packed_residency_scheduled_forward_bit_exact() {
        // the whole scheduled stack — dedup plan, prefetch workers,
        // demand reservations — on a *packed* cache must equal the
        // decoded per-sequence reference bit for bit
        let (cfg, _dir, reader) = demo(44);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let opts = SchedOptions {
            sync_prefetch: true,
            prefetch_budget_bytes: 1 << 20,
            ..SchedOptions::default()
        };
        let metrics = Arc::new(PipelineMetrics::default());
        let cache = ExpertCache::new(reader.clone(), metrics.clone(), usize::MAX, 1)
            .with_residency(crate::config::ExpertResidency::Packed);
        let sched = ExpertScheduler::new(
            reader.clone(),
            metrics.clone(),
            cache,
            cfg.n_layers,
            spec.n_experts,
            opts,
        );
        let xs = clustered_trace(cfg.d_model, 3, 1, 4, 13);
        let batched = sched.forward_batch(&routers, &spec, &xs).unwrap();
        sched.quiesce();
        for (x, got) in xs.iter().zip(&batched) {
            let want = moe_stack_forward(&routers, &spec, x, |l, e| {
                Ok(Arc::new(ExpertWeights::load(&reader, l, e).unwrap()))
            })
            .unwrap();
            assert_eq!(got, &want, "packed scheduled forward diverged");
        }
        // and every lookup really went through the packed mode
        assert_eq!(
            metrics.expert_packed_misses_count(),
            metrics.expert_misses_count(),
            "packed cache recorded decoded-mode misses"
        );
    }

    #[test]
    fn shared_picks_are_fetched_once() {
        let (cfg, _dir, reader) = demo(42);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let opts = SchedOptions { prefetch: false, ..SchedOptions::default() };
        let (sched, m) = scheduler(&reader, &cfg, usize::MAX, opts);
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let x = rng.normal_vec(cfg.d_model, 1.0);
        let xs = vec![x.clone(), x.clone(), x.clone(), x];
        sched.forward_batch(&routers, &spec, &xs).unwrap();
        let routed = m.sched_routed_picks();
        assert_eq!(routed as usize, 4 * cfg.n_layers * spec.top_k);
        assert_eq!(
            m.sched_planned_fetches() as usize,
            cfg.n_layers * spec.top_k,
            "identical sequences must collapse"
        );
        // decode count == planned fetches, not routed picks
        assert_eq!(m.expert_misses_count(), m.sched_planned_fetches());
        assert!((m.sched_dedup_factor() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn batched_qgemm_knob_is_bit_exact_and_records_exec_metrics() {
        let (cfg, _dir, reader) = demo(46);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let xs = clustered_trace(cfg.d_model, 2, 2, 4, 23);
        let mut outs = Vec::new();
        for batched in [false, true] {
            let opts = SchedOptions {
                prefetch: false,
                batched_qgemm: batched,
                ..SchedOptions::default()
            };
            let (sched, m) = scheduler(&reader, &cfg, usize::MAX, opts);
            outs.push(sched.forward_batch(&routers, &spec, &xs).unwrap());
            if batched {
                assert_eq!(m.exec_batched_groups_count(), m.sched_planned_fetches());
                assert_eq!(m.exec_batched_tokens_count(), m.sched_routed_picks());
                assert_eq!(m.exec_scalar_picks_count(), 0);
            } else {
                assert_eq!(m.exec_scalar_picks_count(), m.sched_routed_picks());
                assert_eq!(m.exec_batched_groups_count(), 0);
            }
        }
        assert_eq!(outs[0], outs[1], "batched qGEMM changed the outputs");
    }

    #[test]
    fn transient_faults_retry_to_bit_exact_output() {
        // a reader that fails reads transiently, plus a retry budget,
        // must produce the exact same outputs as the clean reader —
        // retries re-fetch the pristine payload
        let (cfg, dir, reader) = demo(47);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let xs = clustered_trace(cfg.d_model, 3, 1, 4, 29);
        let opts = SchedOptions {
            prefetch: false,
            retry_budget: 8,
            retry_backoff_ms: 0,
            ..SchedOptions::default()
        };
        let (clean_sched, _m) = scheduler(&reader, &cfg, usize::MAX, opts.clone());
        let want = clean_sched.forward_batch(&routers, &spec, &xs).unwrap();

        let plan = Arc::new(crate::faults::FaultPlan::new(crate::faults::FaultConfig {
            seed: 9,
            transient_p: 0.3,
            ..crate::faults::FaultConfig::default()
        }));
        let faulty = Arc::new(
            TqmReader::open(dir.join("moe.tqm")).unwrap().with_fault_plan(plan.clone()),
        );
        let (sched, m) = scheduler(&faulty, &cfg, usize::MAX, opts);
        let got = sched.forward_batch(&routers, &spec, &xs).unwrap();
        assert_eq!(got, want, "retried transients changed the math");
        assert!(plan.transient_injected() > 0, "fault plan never fired");
        assert!(m.fetch_retries_count() > 0, "no retries recorded");
        assert!(m.retry_successes_count() > 0, "no retry ever succeeded");
        assert_eq!(m.expert_drops_count(), 0, "transients must not drop experts");
    }

    #[test]
    fn poisoned_expert_is_quarantined_and_serving_degrades() {
        let (cfg, dir, reader) = demo(48);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let xs = clustered_trace(cfg.d_model, 8, 2, 8, 31);
        // poison an expert this trace is *guaranteed* to route to at
        // layer 0; every decode of it fails CRC however often retried
        let victim = LayerPlan::build(0, &routers[0], &xs, spec.top_k).unique[0];
        let poisoned = vec![crate::format::expert_record_name(0, victim, "w1")];
        let plan = Arc::new(crate::faults::FaultPlan::new(crate::faults::FaultConfig {
            seed: 4,
            poisoned: poisoned.clone(),
            ..crate::faults::FaultConfig::default()
        }));
        let faulty = Arc::new(
            TqmReader::open(dir.join("moe.tqm")).unwrap().with_fault_plan(plan),
        );
        let opts = SchedOptions {
            prefetch: false,
            retry_budget: 1,
            retry_backoff_ms: 0,
            quarantine_after: 1,
            quarantine_probe_every: 0,
            ..SchedOptions::default()
        };
        let (sched, m) = scheduler(&faulty, &cfg, usize::MAX, opts.clone());
        let out = sched.forward_batch(&routers, &spec, &xs).unwrap();
        assert_eq!(out.len(), xs.len(), "degraded step must answer every sequence");
        assert!(m.expert_drops_count() > 0, "poisoned expert was never dropped");
        assert_eq!(m.quarantined_count(), 1);
        assert_eq!(sched.quarantine().quarantined_experts(), vec![(0, victim)]);
        // degraded serving is still deterministic: an identical scheduler
        // over an identically-seeded fault plan reproduces the outputs
        let plan2 = Arc::new(crate::faults::FaultPlan::new(crate::faults::FaultConfig {
            seed: 4,
            poisoned,
            ..crate::faults::FaultConfig::default()
        }));
        let faulty2 = Arc::new(
            TqmReader::open(dir.join("moe.tqm")).unwrap().with_fault_plan(plan2),
        );
        let (sched2, _m2) = scheduler(&faulty2, &cfg, usize::MAX, opts);
        let out2 = sched2.forward_batch(&routers, &spec, &xs).unwrap();
        assert_eq!(out, out2, "degraded serving must replay bit-exactly");
        // next step: the quarantined expert is excluded before any fetch,
        // so no further decode attempts (and no further drops) happen
        let drops_before = m.expert_drops_count();
        sched.forward_batch(&routers, &spec, &xs).unwrap();
        assert_eq!(m.expert_drops_count(), drops_before, "quarantine did not stick");
    }

    #[test]
    fn drop_expert_sequential_equals_one_shot_renormalization() {
        let metrics = PipelineMetrics::default();
        let base = vec![
            vec![(0, 0.5f32), (1, 0.3), (2, 0.2)],
            vec![(1, 0.6f32), (3, 0.4)],
        ];
        // sequential: drop 0 then 2
        let mut seq_picks = base.clone();
        let mut seq_unique = vec![0usize, 1, 2, 3];
        drop_expert_from_step(&mut seq_picks, &mut seq_unique, 0, 0, &metrics).unwrap();
        drop_expert_from_step(&mut seq_picks, &mut seq_unique, 2, 0, &metrics).unwrap();
        // one-shot reference: keep survivors, divide by survivor sum
        let mut one = base;
        for s in &mut one {
            s.retain(|&(e, _)| e != 0 && e != 2);
            let sum: f32 = s.iter().map(|&(_, g)| g).sum();
            for (_, g) in s.iter_mut() {
                *g /= sum;
            }
        }
        assert_eq!(seq_unique, vec![1, 3]);
        for (a, b) in seq_picks.iter().flatten().zip(one.iter().flatten()) {
            assert_eq!(a.0, b.0);
            assert!((a.1 - b.1).abs() < 1e-6, "{} vs {}", a.1, b.1);
        }
        assert_eq!(metrics.degraded_picks_count(), 3);
    }

    #[test]
    fn dropping_every_pick_of_a_sequence_is_a_structured_error() {
        let metrics = PipelineMetrics::default();
        let mut picks = vec![vec![(0usize, 0.7f32), (1, 0.3)]];
        let mut unique = vec![0usize, 1];
        drop_expert_from_step(&mut picks, &mut unique, 0, 5, &metrics).unwrap();
        let err = drop_expert_from_step(&mut picks, &mut unique, 1, 5, &metrics)
            .expect_err("empty sequence must error");
        match err.downcast_ref::<MoeError>() {
            Some(MoeError::Quarantined { layer }) => assert_eq!(*layer, 5),
            other => panic!("wrong error class: {other:?}"),
        }
    }

    #[test]
    fn time_accounting_identity_holds_on_a_sync_prefetch_run() {
        // stall (demand-miss decode) and exec are disjoint sections of
        // the serving thread's forward loop, so they can never sum past
        // the measured wall; prefetch decode overlaps the wall on
        // background workers and is reported alongside, never added in
        let (cfg, _dir, reader) = demo(49);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let opts = SchedOptions {
            sync_prefetch: true,
            prefetch_budget_bytes: 1 << 20,
            ..SchedOptions::default()
        };
        let (sched, m) = scheduler(&reader, &cfg, usize::MAX, opts);
        let xs = clustered_trace(cfg.d_model, 3, 1, 4, 13);
        sched.forward_batch(&routers, &spec, &xs).unwrap();
        assert_eq!(m.forward_steps_count(), 1);
        let wall = m.forward_wall_secs();
        let (stall, exec) = (m.expert_stall_secs(), m.exec_secs());
        assert!(wall > 0.0 && exec > 0.0, "wall {wall} exec {exec}");
        // the three sums come from different Instant reads; allow a
        // microsecond of clock-read skew
        assert!(stall + exec <= wall + 1e-6, "stall {stall} + exec {exec} > wall {wall}");
        let line = m.time_accounting();
        assert!(line.starts_with("time: forward wall"), "{line}");
        assert!(m.summary().contains("time: forward wall"), "summary missing accounting");
    }

    #[test]
    fn brownout_to_packed_mid_run_stays_bit_exact() {
        // steps before and after the flip must produce identical outputs
        // to an all-decoded scheduler; mixed-mode residency (decoded
        // entries surviving next to fresh packed admissions) must keep
        // the byte books exact
        let (cfg, _dir, reader) = demo(51);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let opts = SchedOptions { prefetch: false, ..SchedOptions::default() };
        let xs_a = clustered_trace(cfg.d_model, 3, 1, 4, 61);
        let xs_b = clustered_trace(cfg.d_model, 3, 1, 4, 67);
        let (reference, _m) = scheduler(&reader, &cfg, usize::MAX, opts.clone());
        let want_a = reference.forward_batch(&routers, &spec, &xs_a).unwrap();
        let want_b = reference.forward_batch(&routers, &spec, &xs_b).unwrap();

        let (sched, m) = scheduler(&reader, &cfg, usize::MAX, opts);
        let got_a = sched.forward_batch(&routers, &spec, &xs_a).unwrap();
        assert!(sched.brownout_to_packed(), "first flip must report a transition");
        assert!(!sched.brownout_to_packed(), "second flip must be a no-op");
        assert_eq!(m.brownouts_count(), 1);
        let got_b = sched.forward_batch(&routers, &spec, &xs_b).unwrap();
        assert_eq!(got_a, want_a, "pre-brownout step diverged");
        assert_eq!(got_b, want_b, "post-brownout step diverged");
        // the flip only affects *future* admissions: decoded entries
        // stayed resident, new misses (if any) decoded packed
        let cache = sched.cache_handle();
        let cache = cache.lock().unwrap();
        assert_eq!(cache.residency(), crate::config::ExpertResidency::Packed);
        // byte books stay exact across the mixed-mode cache
        assert_eq!(cache.demand_inflight_bytes(), 0);
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let (cfg, _dir, reader) = demo(43);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let (sched, m) = scheduler(&reader, &cfg, usize::MAX, SchedOptions::default());
        let out = sched.forward_batch(&routers, &spec, &[]).unwrap();
        assert!(out.is_empty());
        assert_eq!(m.sched_plans_count(), 0);
    }
}
