//! Expert scheduler: the proactive half of MoE serving (the reactive
//! half being the byte-budgeted [`ExpertCache`]). It sits between the
//! coordinator's batcher and the cache and does three things per forward
//! step:
//!
//! 1. **Batch-aware decode dedup** — the routed top-k picks of *all*
//!    sequences in a batch are collected into one [`LayerPlan`] per
//!    layer, so an expert chosen by eight sequences is fetched (and, on a
//!    miss, decoded) exactly once and held for the whole step.
//! 2. **Router-logit prefetch** — while layer *l*'s math executes, a
//!    background [`PrefetchPool`] decodes layer *l+1*'s likeliest
//!    experts into the cache's speculative slice (kicked after layer
//!    *l*'s fetch, so fresh reservations can only displace *stale*
//!    prefetches, never entries this step is about to consume).
//!    Prediction blends the next router's gating probabilities on the
//!    batch's current hidden states with an [`EwmaPrior`] of expert
//!    popularity. The slice is bounded by `prefetch_budget_bytes`,
//!    charged by reservation *before* the background decode, and
//!    admission is size-aware, so prefetch can never evict what the
//!    current step needs. Demand misses use the same
//!    reserve → decode-outside-lock → commit shape
//!    ([`ExpertCache::begin_get`]), so a slow demand decode no longer
//!    serializes prefetch commits against the cache lock.
//! 3. **Scheduling counters** — dedup factor, prefetch hit/waste, and
//!    the decode stall the forward step actually paid, all through the
//!    shared [`PipelineMetrics`].
//!
//! Dataflow: `batcher -> ExpertScheduler::forward_batch -> LayerPlan ->
//! ExpertCache (demand) + PrefetchPool (speculative) -> MoE math`.

pub mod plan;
pub mod prefetch;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::{ExpertResidency, MoeSpec, ServeOptions};
use crate::format::TqmReader;
use crate::model::moe::{
    moe_layer_forward_batched, moe_layer_forward_grouped, ExpertWeights, Router,
};
use crate::pipeline::expert_cache::DemandFetch;
use crate::pipeline::{ExpertCache, PipelineMetrics};

pub use plan::LayerPlan;
pub use prefetch::{EwmaPrior, PrefetchPool};

/// Weight of the EWMA popularity prior relative to the (mean) router
/// gating probability when ranking prefetch candidates.
const PRIOR_WEIGHT: f64 = 0.25;

/// Scheduler configuration, usually derived from [`ServeOptions`].
#[derive(Clone, Debug)]
pub struct SchedOptions {
    /// Master switch for the speculative half (dedup always applies).
    pub prefetch: bool,
    /// Byte bound of the cache's speculative slice.
    pub prefetch_budget_bytes: usize,
    /// Background decode workers.
    pub prefetch_workers: usize,
    /// Decay of the EWMA popularity prior.
    pub ewma_decay: f64,
    /// Deterministic mode: wait for queued prefetches to land before
    /// fetching each layer (tests/benches want reproducible hit counts;
    /// production leaves this off so decode overlaps compute). Fully
    /// reproducible slice contents additionally require
    /// `prefetch_workers == 1` — with more workers the commit order,
    /// and thus the slice's LRU stamps, still race.
    pub sync_prefetch: bool,
    /// Execute each (layer, expert)'s deduped token group as one batched
    /// qGEMM call ([`crate::model::moe::moe_layer_forward_grouped`]) —
    /// one traversal of the expert's packed streams per step — instead
    /// of one qGEMV per routed pick. Exact accumulation: outputs are
    /// bit-identical either way; the per-step batched-vs-scalar metrics
    /// are what differ.
    pub batched_qgemm: bool,
}

impl Default for SchedOptions {
    fn default() -> Self {
        Self::from_serve(&ServeOptions::default())
    }
}

impl SchedOptions {
    pub fn from_serve(o: &ServeOptions) -> Self {
        Self {
            prefetch: o.prefetch_budget_bytes > 0,
            prefetch_budget_bytes: o.prefetch_budget_bytes,
            prefetch_workers: o.prefetch_workers,
            ewma_decay: o.prefetch_ewma_decay,
            sync_prefetch: false,
            batched_qgemm: o.batched_qgemm,
        }
    }
}

/// The scheduling subsystem: owns the expert cache (behind a mutex so the
/// prefetch workers can feed its speculative slice) and the worker pool.
pub struct ExpertScheduler {
    cache: Arc<Mutex<ExpertCache>>,
    /// Container index — candidate selection caps a step's prefetch set
    /// to what the slice can hold, using the known resident sizes.
    reader: Arc<TqmReader>,
    metrics: Arc<PipelineMetrics>,
    /// The cache's residency mode, captured at construction — demand
    /// decodes (run outside the cache lock) and prefetch workers must
    /// produce the same body the cache charges for.
    residency: ExpertResidency,
    /// Popularity prior, persisted across steps (and batches) — the
    /// workload-skew half of the prefetch score.
    prior: Mutex<EwmaPrior>,
    pool: Option<PrefetchPool>,
    opts: SchedOptions,
}

impl ExpertScheduler {
    /// Wrap `cache` (built for the same container `reader` serves) into a
    /// scheduler for a model of `n_layers` MoE sublayers with `n_experts`
    /// experts each.
    pub fn new(
        reader: Arc<TqmReader>,
        metrics: Arc<PipelineMetrics>,
        cache: ExpertCache,
        n_layers: usize,
        n_experts: usize,
        opts: SchedOptions,
    ) -> Self {
        let residency = cache.residency();
        let cache = Arc::new(Mutex::new(cache));
        let pool = (opts.prefetch && opts.prefetch_budget_bytes > 0).then(|| {
            PrefetchPool::new(
                cache.clone(),
                reader.clone(),
                metrics.clone(),
                opts.prefetch_budget_bytes,
                opts.prefetch_workers,
                residency,
            )
        });
        Self {
            cache,
            reader,
            metrics,
            residency,
            prior: Mutex::new(EwmaPrior::new(n_layers, n_experts, opts.ewma_decay)),
            pool,
            opts,
        }
    }

    pub fn metrics(&self) -> &Arc<PipelineMetrics> {
        &self.metrics
    }

    /// Shared handle to the underlying cache (pin management, tests).
    pub fn cache_handle(&self) -> Arc<Mutex<ExpertCache>> {
        self.cache.clone()
    }

    /// Demand-fetch one expert through the cache (single-sequence paths
    /// that still want the scheduler's cache + prefetch machinery). A
    /// miss reserves under the lock, decodes **without** it — so
    /// prefetch workers keep committing while the demand decode runs —
    /// and commits the result (demand-side reservations).
    pub fn get(&self, layer: usize, expert: usize) -> Result<Arc<ExpertWeights>> {
        let fetch = self.cache.lock().unwrap().begin_get(layer, expert)?;
        match fetch {
            DemandFetch::Hit(w) => Ok(w),
            DemandFetch::Miss(res) => {
                let t0 = Instant::now();
                // the decode runs with no cache lock held, so a panic in
                // it would otherwise drop the reservation uncancelled and
                // shrink the effective budget forever — catch, release,
                // re-raise
                let decoded = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    ExpertWeights::load_with(&self.reader, layer, expert, self.residency)
                }));
                match decoded {
                    Ok(Ok(w)) => Ok(self.cache.lock().unwrap().commit_demand(
                        res,
                        Arc::new(w),
                        t0.elapsed(),
                    )),
                    Ok(Err(e)) => {
                        self.cache.lock().unwrap().cancel_demand(res);
                        Err(e)
                    }
                    Err(panic) => {
                        self.cache.lock().unwrap().cancel_demand(res);
                        std::panic::resume_unwind(panic)
                    }
                }
            }
        }
    }

    /// Decode (if needed) and exempt an expert from eviction.
    pub fn pin(&self, layer: usize, expert: usize) -> Result<()> {
        self.cache.lock().unwrap().pin(layer, expert)
    }

    pub fn unpin(&self, layer: usize, expert: usize) {
        self.cache.lock().unwrap().unpin(layer, expert)
    }

    /// Wait until every queued prefetch job has been processed.
    pub fn quiesce(&self) {
        if let Some(pool) = &self.pool {
            pool.quiesce();
        }
    }

    /// One forward step for a whole batch through a stack of MoE
    /// sublayers with residual connections (`x <- x + moe_l(x)`):
    /// plan -> prefetch next layer -> fetch each unique expert once ->
    /// per-sequence gated math in router order. Bit-exact against running
    /// [`crate::model::moe::moe_stack_forward`] per sequence.
    pub fn forward_batch(
        &self,
        routers: &[Router],
        spec: &MoeSpec,
        xs0: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        if xs0.is_empty() {
            return Ok(Vec::new());
        }
        let mut xs: Vec<Vec<f32>> = xs0.to_vec();
        for (l, router) in routers.iter().enumerate() {
            let plan = LayerPlan::build(l, router, &xs, spec.top_k);
            self.metrics
                .record_sched_plan(plan.routed_picks() as u64, plan.n_unique() as u64);
            self.prior.lock().unwrap().observe(l, &plan.unique);
            if self.opts.sync_prefetch {
                // deterministic mode: the jobs kicked at layer l-1 (for
                // this layer) must land before the fetch below
                self.quiesce();
            }
            // the dedup: each unique expert fetched once, held for the
            // whole step (a tight budget can no longer force two decodes
            // of one expert within a step). Fetching *before* kicking the
            // next layer's prefetch also promotes this layer's
            // speculative entries out of the slice, so the new
            // reservations below can only ever displace stale prefetches,
            // never the ones this step is about to consume. Each miss
            // decodes outside the cache lock (demand-side reservations),
            // so in-flight prefetch commits interleave with it.
            let mut fetched: HashMap<usize, Arc<ExpertWeights>> =
                HashMap::with_capacity(plan.n_unique());
            for &e in &plan.unique {
                let w = self.get(l, e)?;
                fetched.insert(e, w);
            }
            if let Some(pool) = &self.pool {
                // warm layer l+1 while this layer's math executes
                // (prediction uses xs before the residual update — the
                // same one-layer-early basis either way)
                if let Some(next) = routers.get(l + 1) {
                    for e in self.prefetch_candidates(next, l + 1, &xs, spec.top_k) {
                        pool.enqueue(l + 1, e);
                    }
                }
            }
            // honest residency: under a budget smaller than the batch's
            // union, some held Arcs outlive their cache slots (evicted
            // or never admitted) — the dedup trades bounded decode count
            // for holding one layer's unique set. Fold that overhang
            // into the shared peak so it is visible, never silent.
            {
                let cache = self.cache.lock().unwrap();
                let held_uncached: usize = fetched
                    .iter()
                    .filter(|(e, _)| !cache.contains(l, **e))
                    .map(|(_, w)| w.bytes())
                    .sum();
                if held_uncached > 0 {
                    self.metrics.observe_expert_transient(
                        cache.total_resident_bytes() + held_uncached,
                    );
                }
            }
            let fetch = |e: usize| {
                fetched
                    .get(&e)
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("expert {e} missing from plan"))
            };
            let ys = if self.opts.batched_qgemm {
                // one ffn_batch (three qGEMM traversals) per unique
                // expert for its whole deduped token group
                let (ys, stats) = moe_layer_forward_grouped(&xs, &plan.picks, fetch)?;
                self.metrics.record_exec_batched(stats.groups, stats.tokens);
                ys
            } else {
                self.metrics.record_exec_scalar(plan.routed_picks() as u64);
                moe_layer_forward_batched(&xs, &plan.picks, fetch)?
            };
            for (x, y) in xs.iter_mut().zip(ys) {
                for (xi, yi) in x.iter_mut().zip(y) {
                    *xi += yi;
                }
            }
        }
        Ok(xs)
    }

    /// Rank layer `layer`'s experts for prefetch: mean gating probability
    /// of its router over the batch's *current* hidden states (the step
    /// is still one layer earlier, so this is a one-layer-early estimate)
    /// blended with the EWMA popularity prior; already-resident experts
    /// are skipped. Best candidates first, capped at one batch worth of
    /// picks plus `top_k` slack.
    fn prefetch_candidates(
        &self,
        router: &Router,
        layer: usize,
        xs: &[Vec<f32>],
        top_k: usize,
    ) -> Vec<usize> {
        let ne = router.n_experts();
        let mut score = vec![0f64; ne];
        for x in xs {
            for (e, p) in router.gating_probs(x).into_iter().enumerate() {
                score[e] += p as f64;
            }
        }
        let n = xs.len().max(1) as f64;
        {
            let prior = self.prior.lock().unwrap();
            for (e, s) in score.iter_mut().enumerate() {
                *s = *s / n + PRIOR_WEIGHT * prior.score(layer, e);
            }
        }
        let mut idx: Vec<usize> = (0..ne).collect();
        idx.sort_by(|&a, &b| {
            score[b]
                .partial_cmp(&score[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate((top_k * xs.len() + top_k).min(ne));
        {
            let cache = self.cache.lock().unwrap();
            idx.retain(|&e| !cache.contains(layer, e));
        }
        // cap the step's candidate set to what the slice can hold, best
        // first — otherwise a burst of same-step inserts would displace
        // its own best predictions through the slice's LRU
        let mut bytes = 0usize;
        let mut kept = Vec::with_capacity(idx.len());
        for e in idx {
            let need = match self.reader.expert_entry(layer, e) {
                Ok(entry) => match self.residency {
                    ExpertResidency::Decoded => entry.decoded_f32_bytes,
                    ExpertResidency::Packed => entry.packed_resident_bytes,
                },
                Err(_) => continue,
            };
            if bytes + need > self.opts.prefetch_budget_bytes {
                break;
            }
            bytes += need;
            kept.push(e);
        }
        kept
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::QuantizeOptions;
    use crate::model::moe::{
        clustered_trace, load_routers, moe_demo_config, moe_stack_forward,
        quantize_moe_checkpoint, synth_moe_checkpoint, ExpertWeights,
    };
    use crate::util::TempDir;

    fn demo(seed: u64) -> (crate::config::ModelConfig, TempDir, Arc<TqmReader>) {
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, seed).unwrap();
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, "unit")
            .unwrap()
            .with_chunk_len(512);
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        (cfg, dir, Arc::new(TqmReader::open(&p).unwrap()))
    }

    fn scheduler(
        reader: &Arc<TqmReader>,
        cfg: &crate::config::ModelConfig,
        budget: usize,
        opts: SchedOptions,
    ) -> (ExpertScheduler, Arc<PipelineMetrics>) {
        let spec = cfg.moe.as_ref().unwrap();
        let metrics = Arc::new(PipelineMetrics::default());
        let cache = ExpertCache::new(reader.clone(), metrics.clone(), budget, 1);
        let sched = ExpertScheduler::new(
            reader.clone(),
            metrics.clone(),
            cache,
            cfg.n_layers,
            spec.n_experts,
            opts,
        );
        (sched, metrics)
    }

    #[test]
    fn batched_forward_matches_per_sequence_path() {
        let (cfg, _dir, reader) = demo(41);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let opts = SchedOptions {
            sync_prefetch: true,
            prefetch_budget_bytes: 1 << 20,
            ..SchedOptions::default()
        };
        let (sched, _m) = scheduler(&reader, &cfg, usize::MAX, opts);
        let xs = clustered_trace(cfg.d_model, 3, 1, 4, 13);
        let batched = sched.forward_batch(&routers, &spec, &xs).unwrap();
        for (x, got) in xs.iter().zip(&batched) {
            let want = moe_stack_forward(&routers, &spec, x, |l, e| sched.get(l, e)).unwrap();
            assert_eq!(got, &want, "scheduled forward diverged");
        }
    }

    #[test]
    fn packed_residency_scheduled_forward_bit_exact() {
        // the whole scheduled stack — dedup plan, prefetch workers,
        // demand reservations — on a *packed* cache must equal the
        // decoded per-sequence reference bit for bit
        let (cfg, _dir, reader) = demo(44);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let opts = SchedOptions {
            sync_prefetch: true,
            prefetch_budget_bytes: 1 << 20,
            ..SchedOptions::default()
        };
        let metrics = Arc::new(PipelineMetrics::default());
        let cache = ExpertCache::new(reader.clone(), metrics.clone(), usize::MAX, 1)
            .with_residency(crate::config::ExpertResidency::Packed);
        let sched = ExpertScheduler::new(
            reader.clone(),
            metrics.clone(),
            cache,
            cfg.n_layers,
            spec.n_experts,
            opts,
        );
        let xs = clustered_trace(cfg.d_model, 3, 1, 4, 13);
        let batched = sched.forward_batch(&routers, &spec, &xs).unwrap();
        sched.quiesce();
        for (x, got) in xs.iter().zip(&batched) {
            let want = moe_stack_forward(&routers, &spec, x, |l, e| {
                Ok(Arc::new(ExpertWeights::load(&reader, l, e).unwrap()))
            })
            .unwrap();
            assert_eq!(got, &want, "packed scheduled forward diverged");
        }
        // and every lookup really went through the packed mode
        assert_eq!(
            metrics.expert_packed_misses_count(),
            metrics.expert_misses_count(),
            "packed cache recorded decoded-mode misses"
        );
    }

    #[test]
    fn shared_picks_are_fetched_once() {
        let (cfg, _dir, reader) = demo(42);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let opts = SchedOptions { prefetch: false, ..SchedOptions::default() };
        let (sched, m) = scheduler(&reader, &cfg, usize::MAX, opts);
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let x = rng.normal_vec(cfg.d_model, 1.0);
        let xs = vec![x.clone(), x.clone(), x.clone(), x];
        sched.forward_batch(&routers, &spec, &xs).unwrap();
        let routed = m.sched_routed_picks();
        assert_eq!(routed as usize, 4 * cfg.n_layers * spec.top_k);
        assert_eq!(
            m.sched_planned_fetches() as usize,
            cfg.n_layers * spec.top_k,
            "identical sequences must collapse"
        );
        // decode count == planned fetches, not routed picks
        assert_eq!(m.expert_misses_count(), m.sched_planned_fetches());
        assert!((m.sched_dedup_factor() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn batched_qgemm_knob_is_bit_exact_and_records_exec_metrics() {
        let (cfg, _dir, reader) = demo(46);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let xs = clustered_trace(cfg.d_model, 2, 2, 4, 23);
        let mut outs = Vec::new();
        for batched in [false, true] {
            let opts = SchedOptions {
                prefetch: false,
                batched_qgemm: batched,
                ..SchedOptions::default()
            };
            let (sched, m) = scheduler(&reader, &cfg, usize::MAX, opts);
            outs.push(sched.forward_batch(&routers, &spec, &xs).unwrap());
            if batched {
                assert_eq!(m.exec_batched_groups_count(), m.sched_planned_fetches());
                assert_eq!(m.exec_batched_tokens_count(), m.sched_routed_picks());
                assert_eq!(m.exec_scalar_picks_count(), 0);
            } else {
                assert_eq!(m.exec_scalar_picks_count(), m.sched_routed_picks());
                assert_eq!(m.exec_batched_groups_count(), 0);
            }
        }
        assert_eq!(outs[0], outs[1], "batched qGEMM changed the outputs");
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let (cfg, _dir, reader) = demo(43);
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let (sched, m) = scheduler(&reader, &cfg, usize::MAX, SchedOptions::default());
        let out = sched.forward_batch(&routers, &spec, &[]).unwrap();
        assert!(out.is_empty());
        assert_eq!(m.sched_plans_count(), 0);
    }
}
