//! Batch-aware decode plans: collapse a batch's routed top-k picks into
//! one fetch per (layer, expert).
//!
//! The per-sequence MoE path fetches every routed pick independently —
//! eight sequences routing to expert 3 cost eight cache lookups and, under
//! a tight budget, potentially eight decodes (an expert evicted between
//! two sequences of the *same step* decodes again). A [`LayerPlan`] keeps
//! the per-sequence picks (router order — the math consumes them in that
//! order, which is what keeps the scheduled forward bit-exact against the
//! per-sequence path) but derives the sorted deduplicated expert set, so
//! the scheduler fetches each expert once and holds it for the whole step.

use crate::model::moe::Router;

/// One layer's decode plan for a batch of sequences.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub layer: usize,
    /// Per-sequence routed `(expert, gate)` picks, router order.
    pub picks: Vec<Vec<(usize, f32)>>,
    /// Sorted, deduplicated expert ids across all picks — the decode
    /// order. Sorting makes the plan independent of batch order.
    pub unique: Vec<usize>,
}

impl LayerPlan {
    /// Route every sequence of the batch through `router` and dedupe the
    /// picks. Pure math — no cache or decoder involvement — so plans can
    /// be built (and tested) without a container.
    pub fn build(layer: usize, router: &Router, xs: &[Vec<f32>], top_k: usize) -> Self {
        let picks: Vec<Vec<(usize, f32)>> =
            xs.iter().map(|x| router.top_k(x, top_k)).collect();
        let mut unique: Vec<usize> = picks.iter().flatten().map(|p| p.0).collect();
        unique.sort_unstable();
        unique.dedup();
        Self { layer, picks, unique }
    }

    /// Total routed picks across the batch (what the per-sequence path
    /// would have fetched).
    pub fn routed_picks(&self) -> usize {
        self.picks.iter().map(|p| p.len()).sum()
    }

    /// Unique experts to fetch (what the scheduler actually fetches).
    pub fn n_unique(&self) -> usize {
        self.unique.len()
    }

    /// Routed picks per unique fetch (>= 1.0 for a non-empty batch; the
    /// batch-dedup win). 0.0 for an empty plan.
    pub fn dedup_factor(&self) -> f64 {
        if self.unique.is_empty() {
            return 0.0;
        }
        self.routed_picks() as f64 / self.unique.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn demo_router(d: usize, ne: usize) -> Router {
        let mut rng = crate::util::Rng::seed_from_u64(17);
        Router {
            layer: 0,
            w: Tensor::new(vec![d, ne], rng.normal_vec(d * ne, 0.5)).unwrap(),
        }
    }

    #[test]
    fn identical_sequences_collapse_to_one_fetch_each() {
        let router = demo_router(16, 8);
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let x = rng.normal_vec(16, 1.0);
        let xs = vec![x.clone(), x.clone(), x.clone(), x.clone()];
        let plan = LayerPlan::build(0, &router, &xs, 2);
        assert_eq!(plan.routed_picks(), 8);
        assert_eq!(plan.n_unique(), 2, "4 identical sequences share their picks");
        assert!((plan.dedup_factor() - 4.0).abs() < 1e-12);
        // picks preserved per sequence, router order
        for p in &plan.picks {
            assert_eq!(p, &router.top_k(&x, 2));
        }
    }

    #[test]
    fn unique_set_is_sorted_and_batch_order_independent() {
        let router = demo_router(16, 8);
        let mut rng = crate::util::Rng::seed_from_u64(9);
        let xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(16, 1.0)).collect();
        let plan = LayerPlan::build(0, &router, &xs, 2);
        assert!(plan.unique.windows(2).all(|w| w[0] < w[1]), "sorted + deduped");
        let mut rev = xs.clone();
        rev.reverse();
        let plan_rev = LayerPlan::build(0, &router, &rev, 2);
        assert_eq!(plan.unique, plan_rev.unique, "plan depends on batch order");
        // per-sequence picks just permute with the batch
        for (i, p) in plan.picks.iter().enumerate() {
            assert_eq!(p, &plan_rev.picks[xs.len() - 1 - i]);
        }
    }

    #[test]
    fn empty_batch_yields_empty_plan() {
        let router = demo_router(8, 4);
        let plan = LayerPlan::build(3, &router, &[], 2);
        assert_eq!(plan.layer, 3);
        assert_eq!(plan.routed_picks(), 0);
        assert_eq!(plan.n_unique(), 0);
        assert_eq!(plan.dedup_factor(), 0.0);
    }
}
