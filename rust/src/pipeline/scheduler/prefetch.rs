//! Router-logit-driven expert prefetch: background workers that decode
//! *predicted* experts into the cache's speculative slice while the
//! demand path is still computing the previous layer.
//!
//! Two signals feed the prediction (scored in
//! [`super::ExpertScheduler`]): the **next layer's router logits** applied
//! to the batch's current hidden states (a one-layer-early estimate of
//! where the step is about to route), and an [`EwmaPrior`] of which
//! experts the workload has been picking lately (real traffic is heavily
//! skewed — QMoE/MobileMoE both report zipf-like expert popularity).
//!
//! The pool never blocks the demand path: jobs are queued, workers decode
//! with fresh buffers (the demand path keeps the recycled-arena fast
//! path to itself), and the size-aware admission check in
//! [`crate::pipeline::ExpertCache::insert_speculative`] guarantees a
//! prefetch can only ever displace another unused prefetch, never a
//! demand-resident expert.

use std::collections::HashSet;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::format::TqmReader;
use crate::model::moe::ExpertWeights;
use crate::pipeline::{ExpertCache, PipelineMetrics};
use crate::trace::{self, Category};
use crate::util::{lock_recover, wait_recover};

/// EWMA of the per-step pick indicator for every (layer, expert): each
/// scheduling step every expert's score decays by `decay`, and the
/// experts the step actually routed to gain `1 - decay`. Scores live in
/// [0, 1] — a long-run pick frequency with exponentially fading memory.
pub struct EwmaPrior {
    decay: f64,
    scores: Vec<Vec<f64>>,
}

impl EwmaPrior {
    pub fn new(n_layers: usize, n_experts: usize, decay: f64) -> Self {
        Self { decay: decay.clamp(0.0, 1.0), scores: vec![vec![0.0; n_experts]; n_layers] }
    }

    /// Fold one step's picked expert set for `layer` into the prior.
    pub fn observe(&mut self, layer: usize, picked: &[usize]) {
        let Some(row) = self.scores.get_mut(layer) else { return };
        for s in row.iter_mut() {
            *s *= self.decay;
        }
        for &e in picked {
            if let Some(s) = row.get_mut(e) {
                *s += 1.0 - self.decay;
            }
        }
    }

    /// Popularity score of one expert (0.0 for out-of-range indices).
    pub fn score(&self, layer: usize, expert: usize) -> f64 {
        self.scores
            .get(layer)
            .and_then(|r| r.get(expert))
            .copied()
            .unwrap_or(0.0)
    }
}

type Job = (usize, usize);

/// Fixed pool of background decode workers feeding the cache's
/// speculative slice. Shut down on drop (queue closed, workers joined).
pub struct PrefetchPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<PipelineMetrics>,
    /// Jobs queued or executing; [`PrefetchPool::quiesce`] waits for 0.
    inflight: Arc<(Mutex<usize>, Condvar)>,
    /// Keys queued or executing — consecutive steps predicting the same
    /// expert must not decode it twice while the first job is in flight.
    pending: Arc<Mutex<HashSet<Job>>>,
}

impl PrefetchPool {
    pub fn new(
        cache: Arc<Mutex<ExpertCache>>,
        reader: Arc<TqmReader>,
        metrics: Arc<PipelineMetrics>,
        budget_bytes: usize,
        n_workers: usize,
        retry_budget: u32,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let pending = Arc::new(Mutex::new(HashSet::new()));
        let workers = (0..n_workers.max(1))
            .map(|i| {
                let rx = rx.clone();
                let cache = cache.clone();
                let reader = reader.clone();
                let metrics = metrics.clone();
                let inflight = inflight.clone();
                let pending = pending.clone();
                std::thread::Builder::new()
                    .name(format!("expert-prefetch-{i}"))
                    .spawn(move || loop {
                        // take the receiver lock only for the blocking
                        // recv, never while decoding
                        let job = lock_recover(&rx).recv();
                        let Ok((layer, expert)) = job else { return };
                        // containment: a panic anywhere inside the job
                        // must neither kill this worker (the pool would
                        // silently lose capacity) nor skip the pending/
                        // inflight bookkeeping below (quiesce() would
                        // wait forever). The worker absorbs the panic
                        // and keeps serving the queue.
                        let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            run_job(
                                &cache,
                                &reader,
                                &metrics,
                                budget_bytes,
                                retry_budget,
                                layer,
                                expert,
                            )
                        }));
                        if ran.is_err() {
                            metrics.record_prefetch_worker_panic();
                        }
                        lock_recover(&pending).remove(&(layer, expert));
                        let (count, cv) = &*inflight;
                        *lock_recover(count) -= 1;
                        cv.notify_all();
                    })
                    .expect("spawning prefetch worker")
            })
            .collect();
        Self { tx: Some(tx), workers, metrics, inflight, pending }
    }

    /// Queue one (layer, expert) for speculative decode. Never blocks on
    /// the decode itself; a key already queued or executing is skipped
    /// (not an issue, not a waste — just a duplicate prediction).
    pub fn enqueue(&self, layer: usize, expert: usize) {
        if !lock_recover(&self.pending).insert((layer, expert)) {
            return; // already in flight
        }
        let (count, cv) = &*self.inflight;
        *lock_recover(count) += 1;
        let sent = self
            .tx
            .as_ref()
            .map(|tx| tx.send((layer, expert)).is_ok())
            .unwrap_or(false);
        if sent {
            // only a job a worker will actually see counts as issued —
            // this is what keeps the reconciliation invariant
            // `issued == hits + wasted` exact (a shutdown-refused send
            // was formerly counted both issued AND rejected)
            self.metrics.prefetch_issue();
            trace::mark(Category::Prefetch, "issue").layer(layer).expert(expert);
        } else {
            // pool shutting down: roll the accounting back; the job
            // never existed as far as the counters are concerned
            lock_recover(&self.pending).remove(&(layer, expert));
            *lock_recover(count) -= 1;
            cv.notify_all();
        }
    }

    /// Block until every queued job has been processed — the scheduler's
    /// deterministic (`sync_prefetch`) mode, and how tests/benches draw a
    /// line between "prefetch landed" and "prefetch still in flight".
    pub fn quiesce(&self) {
        let (count, cv) = &*self.inflight;
        let mut n = lock_recover(count);
        while *n > 0 {
            n = wait_recover(cv, n);
        }
    }
}

impl Drop for PrefetchPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One speculative decode, admission **first**: reserve slice capacity
/// through [`ExpertCache::begin_speculative`] (rejects already-resident,
/// unknown, and could-never-fit experts before any decode allocation
/// exists — the reservation is what keeps in-flight prefetch bytes
/// inside the `budget + prefetch_budget` bound), then decode with fresh
/// buffers **in the cache's residency mode** — captured in the same
/// critical section as the reservation, so a concurrent brown-out flip
/// cannot desynchronize the decoded body from the reserved size — and
/// commit onto the reservation.
fn run_job(
    cache: &Mutex<ExpertCache>,
    reader: &Arc<TqmReader>,
    metrics: &PipelineMetrics,
    budget_bytes: usize,
    retry_budget: u32,
    layer: usize,
    expert: usize,
) {
    let (reserved, residency) = {
        let mut c = lock_recover(cache);
        (c.begin_speculative(layer, expert, budget_bytes), c.residency())
    };
    let Some(need) = reserved else {
        metrics.record_prefetch_rejected();
        trace::mark(Category::Prefetch, "admission_rejected").layer(layer).expert(expert);
        return;
    };
    // the span closes on Drop whatever happens below (including an
    // escaping panic), renamed to its outcome on the way out
    let mut sp = trace::span(Category::Prefetch, "decode").layer(layer).expert(expert);
    let t0 = Instant::now();
    // Transient decode failures get the same bounded retry as the demand
    // path (no backoff — speculative work competes with nothing and
    // giving up early is cheap). A *panic* in the decode is contained
    // right here so the reservation is always released — an uncancelled
    // reservation would shrink the effective slice budget forever.
    let mut decoded: Option<ExpertWeights> = None;
    for attempt in 0..=retry_budget {
        if attempt > 0 {
            metrics.record_fetch_retry();
        }
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ExpertWeights::load_with(reader, layer, expert, residency)
        })) {
            Ok(Ok(w)) => {
                if attempt > 0 {
                    metrics.record_retry_success();
                }
                decoded = Some(w);
                break;
            }
            Ok(Err(_)) => {}
            Err(_) => {
                // a panic is not a media fault — don't retry it
                metrics.record_prefetch_worker_panic();
                break;
            }
        }
    }
    match decoded {
        Some(w) => {
            let (elapsed, bytes) = (t0.elapsed(), w.bytes());
            let admitted = lock_recover(cache).commit_speculative(layer, expert, Arc::new(w));
            if admitted {
                // only decode work that landed counts as hidden — a
                // commit that lost the race to the demand path is pure
                // waste, not waste AND hidden progress
                metrics.record_prefetch_decode(elapsed, bytes);
                sp.rename("decode_admitted");
            } else {
                // demand decoded it while we were in flight
                metrics.record_prefetch_rejected();
                sp.rename("decode_rejected");
            }
        }
        None => {
            lock_recover(cache).cancel_speculative(need);
            metrics.record_prefetch_rejected();
            sp.rename("decode_failed");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::ExpertResidency;
    use crate::config::QuantizeOptions;
    use crate::model::moe::{moe_demo_config, quantize_moe_checkpoint, synth_moe_checkpoint};
    use crate::pipeline::expert_cache::DemandFetch;
    use crate::util::TempDir;

    /// A demand fetch through the cache's reserve/commit protocol (the
    /// scheduler's `get`, without needing a scheduler).
    fn demand_get(cache: &Mutex<ExpertCache>, reader: &Arc<TqmReader>, l: usize, e: usize) {
        let fetch = cache.lock().unwrap().begin_get(l, e).unwrap();
        if let DemandFetch::Miss(res) = fetch {
            match ExpertWeights::load_with(reader, l, e, ExpertResidency::Decoded) {
                Ok(w) => {
                    cache.lock().unwrap().commit_demand(
                        res,
                        Arc::new(w),
                        std::time::Duration::ZERO,
                    );
                }
                Err(_) => cache.lock().unwrap().cancel_demand(res),
            }
        }
    }

    #[test]
    fn prefetch_counters_reconcile_issued_equals_hits_plus_waste() {
        // Every issued job must terminate as exactly ONE of: hit
        // (demanded, incl. the commit_demand race-promotion), rejected
        // (admission refusal / lost race / failed decode), or evicted
        // unused. Storm the pool with demand fetches racing the workers,
        // then drain every still-speculative entry with a demand sweep
        // and check the books balance exactly.
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, 91).unwrap();
        let qopts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &qopts, CodecId::FreqSeqPacked, "unit")
            .unwrap()
            .with_chunk_len(512);
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        let reader = Arc::new(TqmReader::open(&p).unwrap());
        let spec = cfg.moe.as_ref().unwrap();
        let one = reader.expert_entry(0, 0).unwrap().decoded_f32_bytes;
        let metrics = Arc::new(PipelineMetrics::default());
        let cache = Arc::new(Mutex::new(ExpertCache::new(
            reader.clone(),
            metrics.clone(),
            3 * one,
            1,
        )));
        // slice holds only 2 experts -> admission rejections and
        // unused-eviction churn are guaranteed
        let slice = 2 * one;
        {
            let pool = PrefetchPool::new(
                cache.clone(),
                reader.clone(),
                metrics.clone(),
                slice,
                2,
                0,
            );
            for round in 0..3usize {
                for l in 0..cfg.n_layers {
                    for e in 0..spec.n_experts {
                        pool.enqueue(l, e);
                    }
                }
                // demand fetches racing the in-flight workers: some
                // prefetch commits lose (rejected), some land first and
                // get promoted through the commit_demand race branch
                for e in 0..spec.n_experts {
                    demand_get(&cache, &reader, round % cfg.n_layers, e);
                }
                pool.quiesce();
            }
            pool.quiesce();
            // drain: demand every key so each still-speculative entry
            // terminates as a hit (promotion)
            for l in 0..cfg.n_layers {
                for e in 0..spec.n_experts {
                    demand_get(&cache, &reader, l, e);
                }
            }
        }
        assert!(metrics.prefetch_issued_count() > 0, "storm issued nothing");
        assert_eq!(
            metrics.prefetch_issued_count(),
            metrics.prefetch_hits_count() + metrics.prefetch_wasted_count(),
            "issued ({}) != hits ({}) + waste ({} = rejected + evicted-unused)",
            metrics.prefetch_issued_count(),
            metrics.prefetch_hits_count(),
            metrics.prefetch_wasted_count(),
        );
        // nothing is left speculative after the drain, so the books are
        // final, not merely balanced-so-far
        assert_eq!(cache.lock().unwrap().speculative_bytes(), 0);
    }

    #[test]
    fn panicking_decode_neither_hangs_quiesce_nor_leaks_reservations() {
        // a record source that panics on expert payload access — the
        // worker must contain it, cancel the reservation, keep the
        // inflight/pending books straight, and stay alive for more jobs
        struct PanicSource;
        impl crate::faults::RecordSource for PanicSource {
            fn fetch<'a>(
                &self,
                name: &str,
                payload: &'a [u8],
            ) -> anyhow::Result<std::borrow::Cow<'a, [u8]>> {
                if name.contains(".experts.") {
                    panic!("injected decode panic on {name}");
                }
                Ok(std::borrow::Cow::Borrowed(payload))
            }
        }
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, 92).unwrap();
        let qopts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &qopts, CodecId::FreqSeqPacked, "unit")
            .unwrap()
            .with_chunk_len(512);
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        let reader = Arc::new(
            TqmReader::open(&p)
                .unwrap()
                .with_record_source(Arc::new(PanicSource)),
        );
        let metrics = Arc::new(PipelineMetrics::default());
        let cache = Arc::new(Mutex::new(ExpertCache::new(
            reader.clone(),
            metrics.clone(),
            usize::MAX,
            1,
        )));
        let pool = PrefetchPool::new(
            cache.clone(),
            reader.clone(),
            metrics.clone(),
            1 << 20,
            1, // single worker: every job must survive the panics before it
            2,
        );
        for e in 0..cfg.moe.as_ref().unwrap().n_experts {
            pool.enqueue(0, e);
        }
        pool.quiesce(); // the regression: this used to deadlock
        assert!(metrics.prefetch_worker_panics_count() > 0, "panic never recorded");
        assert_eq!(
            metrics.prefetch_issued_count(),
            metrics.prefetch_hits_count() + metrics.prefetch_wasted_count(),
            "panicked jobs broke the issued == hits + waste invariant"
        );
        // every reservation was released — nothing is charged against
        // the speculative slice
        assert_eq!(cache.lock().unwrap().speculative_bytes(), 0);
    }

    #[test]
    fn ewma_prior_tracks_pick_frequency() {
        let mut p = EwmaPrior::new(2, 4, 0.5);
        assert_eq!(p.score(0, 0), 0.0);
        p.observe(0, &[1, 2]);
        assert!((p.score(0, 1) - 0.5).abs() < 1e-12);
        assert_eq!(p.score(0, 0), 0.0);
        // repeated picks converge toward 1.0, unpicked decay toward 0.0
        for _ in 0..20 {
            p.observe(0, &[1]);
        }
        assert!(p.score(0, 1) > 0.99);
        assert!(p.score(0, 2) < 0.01);
        // other layers untouched; out-of-range indices are inert
        assert_eq!(p.score(1, 1), 0.0);
        p.observe(7, &[0]);
        p.observe(0, &[99]);
        assert_eq!(p.score(7, 0), 0.0);
        assert_eq!(p.score(0, 3), 0.0);
    }
}
