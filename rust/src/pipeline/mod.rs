//! Layer-streaming inference pipeline (S9) — the paper's core systems idea.
//!
//! Weights live **compressed** in memory (that is the deployment premise:
//! the compressed container is what fits on the device). For every forward
//! pass the engine walks the decoder blocks and materializes each layer's
//! weights just in time:
//!
//! * [`crate::config::Residency::StreamPerLayer`] — decompress layer i,
//!   execute, drop (the paper's "Compressed" rows). The decode runs on
//!   the multi-core fast path in [`decode`]: a v2 TQM container frames
//!   each payload as independently-decodable chunks, and the engine fans
//!   a layer's chunks out over `ServeOptions::n_threads` scoped workers
//!   into reusable arenas (zero steady-state allocations). With
//!   `ServeOptions::prefetch_depth > 0`, a pipeline worker decodes up to
//!   `depth` layers ahead while the current layer executes, hiding
//!   decompression latency behind compute; decoded-layer buffers recycle
//!   through a pool, so the pipeline allocates nothing per pass either.
//! * [`crate::config::Residency::AlwaysResident`] — expand everything once
//!   (the paper's "Quantized" baseline).
//! * [`crate::config::Residency::Lru(n)`] — keep n expanded layers cached
//!   (the middle ground the paper's future-work section gestures at).
//!
//! The engine tracks peak expanded-weight residency so the E8 bench can
//! plot memory-vs-latency across policies, plus decode throughput and
//! worker utilization ([`PipelineMetrics::decode_utilization`]).

pub mod decode;
pub mod expert_cache;
pub mod metrics;
pub mod scheduler;

use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{ModelConfig, Residency, ServeOptions};
use crate::format::TqmReader;
use crate::model::{LayerWeights, ResidentWeights, WeightSource};
use crate::quant::QuantizedTensor;
use crate::runtime::{literal, Runtime};
use crate::tensor::Tensor;
use crate::util::lock_recover;
use crate::xla;

pub use decode::{DecodeScratch, DecodedLayer, LayerDecoder};
pub use expert_cache::{DemandFetch, DemandReservation, ExpertCache};
pub use metrics::PipelineMetrics;
pub use scheduler::{ExpertScheduler, SchedOptions};

/// Host-side per-layer KV cache for one request (B dim stripped:
/// shape [KV, S, Dh]).
#[derive(Clone)]
pub struct LayerCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// One request's decoding state.
pub struct Session {
    pub caches: Vec<LayerCache>,
    /// Number of valid positions (absolute position of the next token).
    pub pos: usize,
    pub tokens: Vec<u32>,
}

impl Session {
    /// Placeholder used when temporarily moving a session out of a slot.
    pub fn empty() -> Self {
        Self { caches: Vec::new(), pos: 0, tokens: Vec::new() }
    }
}

/// Always-resident parts (embedding table, final norm, LM head): needed at
/// the start and end of every pass, so streaming them buys nothing; their
/// bytes are charged to the residency metric as a constant.
struct HeadParts {
    embed: QuantizedTensor,
    final_norm: Tensor,
    head: QuantizedTensor,
}

pub struct Engine {
    pub rt: Arc<Runtime>,
    reader: Option<Arc<TqmReader>>,
    resident: Option<ResidentWeights>,
    /// fp32 baseline mode: resident f32 weights, `*_f32` stages.
    f32w: Option<crate::model::F32Weights>,
    heads: Option<HeadParts>,
    /// §Perf: literals for always-resident parts, built once per engine
    /// instead of per stage call (embed table alone is vocab*d bytes).
    embed_lits: Vec<xla::Literal>,
    final_lits: Vec<xla::Literal>,
    /// §Perf: per-layer weight literals for resident / f32 modes.
    layer_lits: Option<Vec<Vec<xla::Literal>>>,
    pub residency: Residency,
    /// Decode→execute pipeline depth (0 = decode inline).
    pub prefetch_depth: usize,
    /// Decoded-expert LRU budget ([`ServeOptions::expert_budget_bytes`])
    /// applied by [`Engine::expert_cache`] for MoE containers.
    pub expert_budget_bytes: usize,
    /// What a resident expert is — decoded f32 or packed codes
    /// ([`ServeOptions::expert_residency`]), applied by
    /// [`Engine::expert_cache`].
    pub expert_residency: crate::config::ExpertResidency,
    /// Expert-scheduler knobs (prefetch slice / workers / prior decay),
    /// resolved from [`ServeOptions`] and applied by
    /// [`Engine::expert_scheduler`].
    pub sched_opts: SchedOptions,
    /// Shared so the coordinator can report pipeline/expert-cache health
    /// for a model without reaching into its serving thread.
    pub metrics: Arc<PipelineMetrics>,
    /// The multi-core streaming decode fast path (present whenever the
    /// engine serves from a compressed container).
    decoder: Option<LayerDecoder>,
    /// Recycled [`DecodedLayer`] buffers — survive across passes so the
    /// steady-state streaming loop allocates nothing.
    decode_pool: std::sync::Mutex<Vec<DecodedLayer>>,
    /// Worker scratch for the chunk fan-out (one set per engine; a pass
    /// holds the lock for its duration).
    decode_scratch: std::sync::Mutex<DecodeScratch>,
    /// LRU cache of expanded layers (index -> weights), used by Lru(n).
    lru: std::sync::Mutex<LruLayers>,
}

#[derive(Default)]
struct LruLayers {
    cap: usize,
    entries: Vec<(usize, Arc<LayerWeights>)>, // most-recent last
}

impl LruLayers {
    fn get(&mut self, i: usize) -> Option<Arc<LayerWeights>> {
        if let Some(pos) = self.entries.iter().position(|(j, _)| *j == i) {
            let e = self.entries.remove(pos);
            let w = e.1.clone();
            self.entries.push(e);
            Some(w)
        } else {
            None
        }
    }

    fn put(&mut self, i: usize, w: Arc<LayerWeights>) -> usize {
        if self.cap == 0 {
            return 0;
        }
        self.entries.retain(|(j, _)| *j != i);
        self.entries.push((i, w));
        let mut evicted = 0;
        while self.entries.len() > self.cap {
            let (_, w) = self.entries.remove(0);
            evicted += w.expanded_bytes();
        }
        evicted
    }

    fn resident_bytes(&self) -> usize {
        self.entries.iter().map(|(_, w)| w.expanded_bytes()).sum()
    }
}

impl Engine {
    pub fn new(rt: Arc<Runtime>, source: WeightSource, opts: &ServeOptions) -> Result<Self> {
        let metrics = Arc::new(PipelineMetrics::default());
        let (reader, resident, heads) = match source {
            WeightSource::Compressed(r) => {
                let heads = HeadParts {
                    embed: r.load_quantized("embed.weight")?,
                    final_norm: r.load_f32("final_norm")?,
                    head: r.load_quantized("head.weight")?,
                };
                (Some(Arc::new(r)), None, heads)
            }
            WeightSource::Resident(rw) => {
                let heads = HeadParts {
                    embed: rw.embed.clone(),
                    final_norm: rw.final_norm.clone(),
                    head: rw.head.clone(),
                };
                (None, Some(rw), heads)
            }
        };
        let residency = if resident.is_some() { Residency::AlwaysResident } else { opts.residency };
        let lru_cap = match residency {
            Residency::Lru(n) => n,
            _ => 0,
        };
        let n_threads = opts.resolved_threads();
        // the decode fast path only serves StreamPerLayer; Lru/resident
        // engines keep the owned LayerWeights path, so skip the planning
        // (and its per-payload CRC pass) they would never use
        let decoder = match (&reader, residency) {
            (Some(r), Residency::StreamPerLayer) => {
                Some(LayerDecoder::new(r.clone(), &rt.manifest.config, n_threads)?)
            }
            _ => None,
        };
        metrics.set_decode_threads(n_threads);
        let mut engine = Self {
            rt,
            reader,
            resident,
            f32w: None,
            heads: Some(heads),
            embed_lits: Vec::new(),
            final_lits: Vec::new(),
            layer_lits: None,
            residency,
            prefetch_depth: opts.prefetch_depth,
            expert_budget_bytes: opts.expert_budget_bytes,
            expert_residency: opts.expert_residency,
            sched_opts: SchedOptions::from_serve(opts),
            metrics,
            decoder,
            decode_pool: std::sync::Mutex::new(Vec::new()),
            decode_scratch: std::sync::Mutex::new(DecodeScratch::new(n_threads)),
            lru: std::sync::Mutex::new(LruLayers { cap: lru_cap, entries: Vec::new() }),
        };
        engine.embed_lits = engine.build_embed_literals()?;
        engine.final_lits = engine.build_final_literals()?;
        if let Some(rw) = &engine.resident {
            let cfg = engine.rt.manifest.config.clone();
            engine.layer_lits = Some(
                rw.layers
                    .iter()
                    .map(|l| l.to_literals(&cfg))
                    .collect::<Result<Vec<_>>>()?,
            );
        }
        engine.charge_constant_residency();
        Ok(engine)
    }

    /// fp32 baseline engine: unquantized weights, `*_f32` stages, always
    /// resident — the "llama3.2-xB" rows of Tables 2-4.
    pub fn new_f32(rt: Arc<Runtime>, ckpt: &crate::model::Checkpoint) -> Result<Self> {
        let f32w = crate::model::F32Weights::load(&rt.manifest.config, ckpt)?;
        let mut engine = Self {
            rt,
            reader: None,
            resident: None,
            f32w: Some(f32w),
            heads: None,
            embed_lits: Vec::new(),
            final_lits: Vec::new(),
            layer_lits: None,
            residency: Residency::AlwaysResident,
            prefetch_depth: 0,
            expert_budget_bytes: 0,
            expert_residency: crate::config::ExpertResidency::Decoded,
            sched_opts: SchedOptions { prefetch: false, ..SchedOptions::default() },
            metrics: Arc::new(PipelineMetrics::default()),
            decoder: None,
            decode_pool: std::sync::Mutex::new(Vec::new()),
            decode_scratch: std::sync::Mutex::new(DecodeScratch::new(1)),
            lru: std::sync::Mutex::new(LruLayers::default()),
        };
        engine.embed_lits = engine.build_embed_literals()?;
        engine.final_lits = engine.build_final_literals()?;
        engine.layer_lits = Some(
            engine
                .f32w
                .as_ref()
                .unwrap()
                .layers
                .iter()
                .map(|l| l.to_literals())
                .collect::<Result<Vec<_>>>()?,
        );
        engine
            .metrics
            .set_constant_bytes(engine.f32w.as_ref().unwrap().total_bytes());
        Ok(engine)
    }

    pub fn is_f32(&self) -> bool {
        self.f32w.is_some()
    }

    /// Variant label for reports.
    pub fn variant(&self) -> String {
        if self.is_f32() {
            "fp32".into()
        } else if self.reader.is_some() {
            format!("compressed/{}", self.residency.label())
        } else {
            "quantized".into()
        }
    }

    fn stage(&self, base: &str) -> String {
        if self.is_f32() {
            format!("{base}_f32")
        } else {
            base.to_string()
        }
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.rt.manifest.config
    }

    /// Build a decoded-expert LRU cache over this engine's compressed
    /// container (MoE serving) using the configured knobs
    /// ([`ServeOptions::expert_budget_bytes`] and the engine's decode
    /// thread count): hits skip the decoder, misses decode per-expert
    /// records and account against the budget. Shares the engine's
    /// [`PipelineMetrics`], so expert hit-rate / residency show up in the
    /// same report. Errors if the engine is not serving from a compressed
    /// source or the container carries no expert records.
    pub fn expert_cache(&self) -> Result<ExpertCache> {
        self.expert_cache_with(self.expert_budget_bytes, self.metrics.decode_threads())
    }

    /// [`Engine::expert_cache`] with explicit budget/thread overrides.
    pub fn expert_cache_with(
        &self,
        budget_bytes: usize,
        n_threads: usize,
    ) -> Result<ExpertCache> {
        let reader = self
            .reader
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("expert cache requires a compressed weight source"))?;
        anyhow::ensure!(
            !reader.expert_entries().is_empty(),
            "container has no expert records (dense model?)"
        );
        Ok(ExpertCache::new(
            reader.clone(),
            self.metrics.clone(),
            budget_bytes,
            n_threads.max(1),
        )
        .with_residency(self.expert_residency))
    }

    /// Build the full expert-scheduling subsystem over this engine's
    /// compressed container: the byte-budgeted cache from
    /// [`Engine::expert_cache`], wrapped by an [`ExpertScheduler`] doing
    /// batch-aware decode dedup and router-logit prefetch with the knobs
    /// resolved from [`ServeOptions`] (`prefetch_budget_bytes`,
    /// `prefetch_workers`, `prefetch_ewma_decay`). Shares the engine's
    /// [`PipelineMetrics`].
    pub fn expert_scheduler(&self) -> Result<ExpertScheduler> {
        let cache = self.expert_cache()?;
        let reader = self.reader.as_ref().expect("expert_cache checked the source").clone();
        let n_layers = self.cfg().n_layers;
        let n_experts = (0..n_layers).map(|l| reader.n_experts(l)).max().unwrap_or(0);
        Ok(ExpertScheduler::new(
            reader,
            self.metrics.clone(),
            cache,
            n_layers,
            n_experts,
            self.sched_opts.clone(),
        ))
    }

    fn charge_constant_residency(&self) {
        let Some(heads) = &self.heads else { return };
        let constant = heads.embed.unpacked_bytes()
            + heads.head.unpacked_bytes()
            + heads.final_norm.data.len() * 4
            + match (&self.resident, &self.reader) {
                (Some(rw), _) => rw.layers.iter().map(|l| l.expanded_bytes()).sum::<usize>(),
                (None, Some(r)) => r.file_bytes(), // the compressed blob itself
                _ => 0,
            };
        self.metrics.set_constant_bytes(constant);
    }

    // -- weight materialization ---------------------------------------------

    fn layer_arc(&self, i: usize) -> Result<Arc<LayerWeights>> {
        if let Some(rw) = &self.resident {
            // resident weights live for the engine's lifetime; cheap clone
            return Ok(Arc::new(rw.layers[i].clone()));
        }
        if let Residency::Lru(_) = self.residency {
            if let Some(w) = lock_recover(&self.lru).get(i) {
                self.metrics.lru_hit();
                return Ok(w);
            }
        }
        let reader = self.reader.as_ref().expect("no weight source");
        let t0 = std::time::Instant::now();
        let w = Arc::new(LayerWeights::load(reader, i)?);
        self.metrics.record_decompress(t0.elapsed(), w.expanded_bytes());
        if let Residency::Lru(_) = self.residency {
            let evicted = lock_recover(&self.lru).put(i, w.clone());
            let resident = lock_recover(&self.lru).resident_bytes();
            self.metrics.update_lru_resident(resident, evicted);
        }
        Ok(w)
    }

    /// Run `f` for every layer in order with that layer's stage-argument
    /// literals, materializing weights according to the residency policy.
    ///
    /// `StreamPerLayer` takes the multi-core decode fast path: layers are
    /// decoded into recycled [`DecodedLayer`] arenas (chunk fan-out across
    /// `n_threads` workers), either inline (`prefetch_depth == 0`) or on a
    /// pipeline worker running up to `prefetch_depth` layers ahead of
    /// execution. `Lru` keeps the owned `LayerWeights` path so cached
    /// layers stay materialized.
    fn walk_layers<F>(&self, mut f: F) -> Result<()>
    where
        F: FnMut(usize, &[xla::Literal]) -> Result<()>,
    {
        let n = self.cfg().n_layers;
        let stream = matches!(self.residency, Residency::StreamPerLayer);
        if !stream {
            // Lru (resident/f32 never reach walk_layers — they use the
            // prebuilt layer_lits cache)
            for i in 0..n {
                let w = self.layer_arc(i)?;
                let lits = w.to_literals(self.cfg())?;
                f(i, &lits)?;
            }
            return Ok(());
        }

        let decoder = self.decoder.as_ref().expect("stream requires a decoder");
        let mut scratch = lock_recover(&self.decode_scratch);
        if self.prefetch_depth == 0 {
            let mut buf = lock_recover(&self.decode_pool).pop().unwrap_or_default();
            for i in 0..n {
                let t0 = std::time::Instant::now();
                let stats = decoder.decode_into(i, &mut buf, &mut scratch)?;
                self.metrics
                    .record_decode(t0.elapsed(), stats.payload_bytes, stats.busy_ns);
                self.metrics.observe_transient(decoder.expanded_bytes(i));
                let lits = decoder.to_literals(&mut buf)?;
                f(i, &lits)?;
            }
            lock_recover(&self.decode_pool).push(buf);
            return Ok(());
        }

        // pipelined: a worker decodes up to `depth` layers ahead; decoded
        // buffers recycle through a free channel so the pass allocates
        // nothing once the pool is warm. Channels are created inside the
        // scope so an early error drops the receivers before the scope
        // joins the worker (no send-deadlock on the error path).
        let depth = self.prefetch_depth;
        let metrics = &self.metrics;
        let scratch = &mut *scratch;
        std::thread::scope(|scope| -> Result<()> {
            let (full_tx, full_rx) = mpsc::sync_channel::<Result<DecodedLayer>>(depth);
            let (free_tx, free_rx) = mpsc::channel::<DecodedLayer>();
            {
                let mut pool = lock_recover(&self.decode_pool);
                for _ in 0..=depth {
                    let _ = free_tx.send(pool.pop().unwrap_or_default());
                }
            }
            let worker = scope.spawn(move || {
                for i in 0..n {
                    let mut buf = match free_rx.recv() {
                        Ok(b) => b,
                        Err(_) => return free_rx, // consumer bailed
                    };
                    let t0 = std::time::Instant::now();
                    match decoder.decode_into(i, &mut buf, scratch) {
                        Ok(stats) => {
                            metrics.record_decode(
                                t0.elapsed(),
                                stats.payload_bytes,
                                stats.busy_ns,
                            );
                            if full_tx.send(Ok(buf)).is_err() {
                                return free_rx; // consumer bailed
                            }
                        }
                        Err(e) => {
                            let _ = full_tx.send(Err(e));
                            return free_rx;
                        }
                    }
                }
                free_rx
            });
            let run = (|| -> Result<()> {
                for i in 0..n {
                    let mut buf = full_rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("prefetch pipeline died"))??;
                    // executing layer + up to `depth` decoded ahead coexist
                    self.metrics
                        .observe_transient(decoder.expanded_bytes(i) * (depth + 1));
                    let lits = decoder.to_literals(&mut buf)?;
                    f(i, &lits)?;
                    let _ = free_tx.send(buf);
                }
                Ok(())
            })();
            // unblock the worker whatever happened, then reclaim buffers
            drop(full_rx);
            drop(free_tx);
            let free_rx = worker
                .join()
                .map_err(|_| anyhow::anyhow!("prefetch worker panicked"))?;
            let mut pool = lock_recover(&self.decode_pool);
            while let Ok(buf) = free_rx.try_recv() {
                pool.push(buf);
            }
            run
        })
    }

    // -- stage plumbing --------------------------------------------------------

    fn build_embed_literals(&self) -> Result<Vec<xla::Literal>> {
        if let Some(fw) = &self.f32w {
            return Ok(vec![literal::tensor_literal(&fw.embed)?]);
        }
        let e = &self.heads.as_ref().unwrap().embed;
        let v = e.codes.shape[0];
        let (s, z) = e.channel_params(v);
        Ok(vec![
            literal::u8_literal(&e.codes.shape, &e.codes.data)?,
            literal::f32_literal(&[v], &s)?,
            literal::f32_literal(&[v], &z)?,
        ])
    }

    fn build_final_literals(&self) -> Result<Vec<xla::Literal>> {
        if let Some(fw) = &self.f32w {
            return Ok(vec![
                literal::tensor_literal(&fw.final_norm)?,
                literal::tensor_literal(&fw.head)?,
            ]);
        }
        let heads = self.heads.as_ref().unwrap();
        let h = &heads.head;
        let v = h.codes.shape[1];
        let (s, z) = h.channel_params(v);
        Ok(vec![
            literal::tensor_literal(&heads.final_norm)?,
            literal::u8_literal(&h.codes.shape, &h.codes.data)?,
            literal::f32_literal(&[v], &s)?,
            literal::f32_literal(&[v], &z)?,
        ])
    }

    fn run_embed(&self, b: usize, t: usize, tokens_padded: &[i32]) -> Result<xla::Literal> {
        let tok = literal::i32_literal(&[b, t], tokens_padded)?;
        let mut args: Vec<&xla::Literal> = vec![&tok];
        args.extend(self.embed_lits.iter());
        let out = self.rt.run_refs(&self.stage("embed"), b, t, &args)?;
        Ok(out.into_iter().next().unwrap())
    }

    fn run_final(&self, b: usize, t: usize, hidden: xla::Literal) -> Result<Tensor> {
        let mut args: Vec<&xla::Literal> = vec![&hidden];
        args.extend(self.final_lits.iter());
        let out = self.rt.run_refs(&self.stage("final"), b, t, &args)?;
        literal::to_tensor(&out[0])
    }

    /// Execute one block stage: returns (hidden', k cache, v cache).
    fn exec_block(
        &self,
        b: usize,
        t: usize,
        i: usize,
        h: &xla::Literal,
        init_caches: Option<&[LayerCache]>,
        pos: &[i32],
        wlits: &[xla::Literal],
    ) -> Result<(xla::Literal, LayerCache)> {
        let cfg = self.cfg();
        let (kv, s, hd) = (cfg.n_kv_heads, cfg.max_seq, cfg.head_dim);
        let cache_elems = kv * s * hd;
        let (kbuf, vbuf): (Vec<f32>, Vec<f32>) = match init_caches {
            Some(caches) => {
                let lc = &caches[i];
                anyhow::ensure!(lc.k.len() == b * cache_elems, "cache shape mismatch");
                (lc.k.clone(), lc.v.clone())
            }
            None => (vec![0.0f32; b * cache_elems], vec![0.0f32; b * cache_elems]),
        };
        let k_lit = literal::f32_literal(&[b, kv, s, hd], &kbuf)?;
        let v_lit = literal::f32_literal(&[b, kv, s, hd], &vbuf)?;
        let pos_lit = literal::i32_literal(&[b], pos)?;
        let mut args: Vec<&xla::Literal> = vec![h, &k_lit, &v_lit, &pos_lit];
        args.extend(wlits.iter());
        let t0 = std::time::Instant::now();
        let mut out = self.rt.run_refs(&self.stage("block"), b, t, &args)?;
        self.metrics.record_exec(t0.elapsed());
        anyhow::ensure!(out.len() == 3, "block stage must return 3 outputs");
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        let h_next = out.pop().unwrap();
        Ok((
            h_next,
            LayerCache { k: literal::to_f32_vec(&kc)?, v: literal::to_f32_vec(&vc)? },
        ))
    }

    /// Core layer loop: hidden + fresh caches -> (hidden', caches').
    /// `pos` is the absolute position of hidden[:, 0] per batch row.
    fn run_blocks(
        &self,
        b: usize,
        t: usize,
        hidden: xla::Literal,
        init_caches: Option<&[LayerCache]>,
        pos: &[i32],
    ) -> Result<(xla::Literal, Vec<LayerCache>)> {
        let cfg = self.cfg();
        let mut h = hidden;
        let mut out_caches: Vec<LayerCache> = Vec::with_capacity(cfg.n_layers);
        if let Some(cached) = &self.layer_lits {
            // resident / f32 modes: weight literals prebuilt once (§Perf)
            for (i, wlits) in cached.iter().enumerate() {
                let (h2, lc) = self.exec_block(b, t, i, &h, init_caches, pos, wlits)?;
                h = h2;
                out_caches.push(lc);
            }
        } else {
            self.walk_layers(|i, wlits| {
                let (h2, lc) = self.exec_block(b, t, i, &h, init_caches, pos, wlits)?;
                h = h2;
                out_caches.push(lc);
                Ok(())
            })?;
        }
        Ok((h, out_caches))
    }

    // -- public API ----------------------------------------------------------

    /// Pick the smallest compiled prefill bucket fitting `t` tokens.
    pub fn prefill_bucket(&self, t: usize) -> Result<usize> {
        self.rt
            .manifest
            .prefill_bucket(1, t)
            .map(|e| e.t)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "prompt of {t} tokens exceeds every lowered prefill bucket for {}",
                    self.cfg().name
                )
            })
    }

    /// Full-prompt logits [T_real, V] at batch 1 — the eval scoring path.
    pub fn forward_logits(&self, tokens: &[u32]) -> Result<Tensor> {
        let t_real = tokens.len();
        let bucket = self.prefill_bucket(t_real)?;
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(bucket, 0);
        let h = self.run_embed(1, bucket, &padded)?;
        let (h, _) = self.run_blocks(1, bucket, h, None, &[0])?;
        let logits = self.run_final(1, bucket, h)?;
        // slice to real length
        let v = self.cfg().vocab;
        let data = logits.data[..t_real * v].to_vec();
        Tensor::new(vec![t_real, v], data)
    }

    /// Prefill a prompt, returning the decoding session and the logits of
    /// the last real position (for sampling the first generated token).
    pub fn prefill_session(&self, tokens: &[u32]) -> Result<(Session, Vec<f32>)> {
        let t_real = tokens.len();
        anyhow::ensure!(t_real > 0, "empty prompt");
        let bucket = self.prefill_bucket(t_real)?;
        let mut padded: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        padded.resize(bucket, 0);
        let h = self.run_embed(1, bucket, &padded)?;
        let (h, caches) = self.run_blocks(1, bucket, h, None, &[0])?;
        let logits = self.run_final(1, bucket, h)?;
        let v = self.cfg().vocab;
        let last = logits.data[(t_real - 1) * v..t_real * v].to_vec();
        Ok((
            Session { caches, pos: t_real, tokens: tokens.to_vec() },
            last,
        ))
    }

    /// One decode step for a batch of sessions (padded to a compiled
    /// decode geometry). `last_tokens[i]` is the token to feed session i.
    /// Returns next-token logits per session.
    pub fn decode_batch(
        &self,
        sessions: &mut [&mut Session],
        last_tokens: &[u32],
    ) -> Result<Vec<Vec<f32>>> {
        let n = sessions.len();
        anyhow::ensure!(n > 0 && n == last_tokens.len(), "bad batch");
        let cfg = self.cfg();
        let b = *cfg
            .decode_b
            .iter()
            .filter(|&&b| b >= n)
            .min()
            .ok_or_else(|| anyhow::anyhow!("batch {n} exceeds compiled decode_b {:?}", cfg.decode_b))?;
        for s in sessions.iter() {
            anyhow::ensure!(s.pos < cfg.max_seq, "session exceeded KV capacity");
        }

        // tokens + positions, padded by replicating row 0
        let mut toks: Vec<i32> = (0..b)
            .map(|i| last_tokens[i.min(n - 1)] as i32)
            .collect();
        // embed expects [B, 1]
        let h = self.run_embed(b, 1, &mut toks)?;
        let pos: Vec<i32> = (0..b).map(|i| sessions[i.min(n - 1)].pos as i32).collect();

        // stack caches across the batch per layer
        let (kv, s_len, hd) = (cfg.n_kv_heads, cfg.max_seq, cfg.head_dim);
        let cache_elems = kv * s_len * hd;
        let n_layers = cfg.n_layers;
        let mut stacked: Vec<LayerCache> = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            let mut k = Vec::with_capacity(b * cache_elems);
            let mut v = Vec::with_capacity(b * cache_elems);
            for bi in 0..b {
                let src = &sessions[bi.min(n - 1)].caches[li];
                k.extend_from_slice(&src.k);
                v.extend_from_slice(&src.v);
            }
            stacked.push(LayerCache { k, v });
        }

        let (h, new_caches) = self.run_blocks(b, 1, h, Some(&stacked), &pos)?;
        let logits = self.run_final(b, 1, h)?;

        // scatter caches back and collect per-session logits
        let v_dim = cfg.vocab;
        let mut out = Vec::with_capacity(n);
        for bi in 0..n {
            for li in 0..n_layers {
                let lc = &new_caches[li];
                sessions[bi].caches[li] = LayerCache {
                    k: lc.k[bi * cache_elems..(bi + 1) * cache_elems].to_vec(),
                    v: lc.v[bi * cache_elems..(bi + 1) * cache_elems].to_vec(),
                };
            }
            sessions[bi].pos += 1;
            sessions[bi].tokens.push(last_tokens[bi]);
            out.push(logits.data[bi * v_dim..(bi + 1) * v_dim].to_vec());
        }
        Ok(out)
    }

    /// Convenience single-session decode.
    pub fn decode_one(&self, session: &mut Session, token: u32) -> Result<Vec<f32>> {
        let mut refs = [session];
        let mut out = self.decode_batch(&mut refs, &[token])?;
        Ok(out.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::{default_artifacts_root, QuantizeOptions, ServeOptions};
    use crate::model::{quantize_checkpoint, Checkpoint};
    use crate::util::TempDir;

    fn build_engine(residency: Residency, prefetch: bool) -> Option<(Engine, TempDir)> {
        if !crate::runtime::backend_available() {
            eprintln!("skipping: pjrt backend not compiled in");
            return None;
        }
        let root = default_artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let rt = Arc::new(Runtime::new(&root, "tiny").unwrap());
        let ckpt = Checkpoint::load(root.join("tiny/weights/tiny.tqw")).unwrap();
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_checkpoint(
            &rt.manifest.config,
            &ckpt,
            &opts,
            CodecId::FreqSeqPacked,
            None,
            "tiny.tqw",
        )
        .unwrap();
        let dir = TempDir::new().unwrap();
        let p = dir.join("tiny.tqm");
        w.write(&p).unwrap();
        let source = match residency {
            Residency::AlwaysResident => {
                WeightSource::open_resident(&p, &rt.manifest.config).unwrap()
            }
            _ => WeightSource::open_compressed(&p).unwrap(),
        };
        // prefetch=true exercises a depth-2 pipeline with multi-threaded
        // chunk decode; prefetch=false is the inline serial path
        let sopts = ServeOptions {
            residency,
            prefetch_depth: if prefetch { 2 } else { 0 },
            n_threads: if prefetch { 0 } else { 1 },
            ..Default::default()
        };
        Some((Engine::new(rt, source, &sopts).unwrap(), dir))
    }

    #[test]
    fn forward_logits_shape() {
        let Some((eng, _dir)) = build_engine(Residency::StreamPerLayer, false) else {
            return;
        };
        let tokens: Vec<u32> = vec![1, 2, 3, 20, 21];
        let logits = eng.forward_logits(&tokens).unwrap();
        assert_eq!(logits.shape, vec![5, eng.cfg().vocab]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn residency_modes_agree_bitwise() {
        // THE lossless-serving invariant: stream, lru and resident modes
        // must produce identical logits (same codes, same executables).
        let Some((stream, _d1)) = build_engine(Residency::StreamPerLayer, false) else {
            return;
        };
        let (resident, _d2) = build_engine(Residency::AlwaysResident, false).unwrap();
        let (lru, _d3) = build_engine(Residency::Lru(1), false).unwrap();
        let (prefetched, _d4) = build_engine(Residency::StreamPerLayer, true).unwrap();
        let tokens: Vec<u32> = vec![1, 5, 9, 13];
        let a = stream.forward_logits(&tokens).unwrap();
        let b = resident.forward_logits(&tokens).unwrap();
        let c = lru.forward_logits(&tokens).unwrap();
        let d = prefetched.forward_logits(&tokens).unwrap();
        assert_eq!(a.data, b.data);
        assert_eq!(a.data, c.data);
        assert_eq!(a.data, d.data);
    }

    #[test]
    fn prefill_then_decode_matches_prefill_of_longer_prompt() {
        // decode(prefill(p), t) logits == forward_logits(p + t) last row
        let Some((eng, _dir)) = build_engine(Residency::StreamPerLayer, false) else {
            return;
        };
        let prompt: Vec<u32> = vec![2, 17, 30, 3];
        let next: u32 = 25;
        let (mut sess, _) = eng.prefill_session(&prompt).unwrap();
        let dec = eng.decode_one(&mut sess, next).unwrap();

        let mut full = prompt.clone();
        full.push(next);
        let logits = eng.forward_logits(&full).unwrap();
        let v = eng.cfg().vocab;
        let last = &logits.data[(full.len() - 1) * v..];
        for (x, y) in dec.iter().zip(last) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
        assert_eq!(sess.pos, 5);
    }

    #[test]
    fn batched_decode_matches_single() {
        let Some((eng, _dir)) = build_engine(Residency::StreamPerLayer, false) else {
            return;
        };
        let p1: Vec<u32> = vec![2, 17, 30];
        let p2: Vec<u32> = vec![1, 6, 2, 40, 3];
        let (mut s1, _) = eng.prefill_session(&p1).unwrap();
        let (mut s2, _) = eng.prefill_session(&p2).unwrap();
        let (mut s1b, _) = eng.prefill_session(&p1).unwrap();
        let (mut s2b, _) = eng.prefill_session(&p2).unwrap();

        let a1 = eng.decode_one(&mut s1, 7).unwrap();
        let a2 = eng.decode_one(&mut s2, 9).unwrap();
        let mut batch = [&mut s1b, &mut s2b];
        let out = eng.decode_batch(&mut batch, &[7, 9]).unwrap();
        for (x, y) in a1.iter().zip(&out[0]) {
            assert!((x - y).abs() < 2e-3);
        }
        for (x, y) in a2.iter().zip(&out[1]) {
            assert!((x - y).abs() < 2e-3);
        }
    }

    #[test]
    fn streaming_transient_residency_is_one_layer() {
        // The paper's memory claim, measured at the *transient* level:
        // streaming expands one layer at a time (two with prefetch),
        // while resident mode keeps all of them expanded. The TOTAL peak
        // for streaming also includes the compressed blob — at the honest
        // ~1.2x ratios of this reproduction that overhead can exceed the
        // savings on tiny models; the E8 bench (pipeline_residency)
        // reports exactly that trade-off on the larger configs.
        let Some((stream, _d1)) = build_engine(Residency::StreamPerLayer, false) else {
            return;
        };
        let (resident, _d2) = build_engine(Residency::AlwaysResident, false).unwrap();
        let tokens: Vec<u32> = vec![1, 2, 3];
        stream.forward_logits(&tokens).unwrap();
        resident.forward_logits(&tokens).unwrap();
        let n_layers = stream.cfg().n_layers;
        // streaming decompresses every layer once per pass...
        assert_eq!(stream.metrics.decompress_count() as usize, n_layers);
        // ...but holds at most one expanded layer at a time
        let reader = stream.reader.as_ref().unwrap();
        let one_layer = LayerWeights::load(reader, 0).unwrap().expanded_bytes();
        let transient = stream.metrics.transient_peak_bytes();
        assert!(transient <= one_layer * 12 / 10, "transient {transient} > 1.2 layers");
        // resident never decompresses during serving and its constant part
        // carries every expanded layer
        assert_eq!(resident.metrics.decompress_count(), 0);
        assert!(resident.metrics.constant_bytes() >= n_layers * one_layer);
    }

    #[test]
    fn too_long_prompt_rejected() {
        let Some((eng, _dir)) = build_engine(Residency::StreamPerLayer, false) else {
            return;
        };
        let tokens: Vec<u32> = vec![1; 4096];
        assert!(eng.forward_logits(&tokens).is_err());
    }
}
