//! The multi-core, zero-allocation decompress→unpack fast path under
//! `StreamPerLayer` serving.
//!
//! The legacy `LayerWeights::load` chain decodes one tensor at a time on
//! one core and allocates fresh buffers per tensor per pass. This module
//! replaces it on the streaming hot loop:
//!
//! * [`LayerDecoder`] — built once per engine. Precomputes, per layer,
//!   every decode *chunk* (a v2 container frames each quantized payload
//!   as independently-decompressable chunks) with absolute source byte
//!   ranges and destination arena offsets, plus a partition of those
//!   chunks into `n_threads` byte-balanced groups. The per-pass hot loop
//!   therefore does no name lookups, no index parsing and no planning.
//! * [`DecodedLayer`] — a reusable arena set (packed stream, unpacked
//!   codes, norm f32s, broadcast-param staging) a layer is decoded into.
//!   Buffers only ever grow; after a one-pass warmup the steady-state
//!   loop performs **zero heap allocations** in this crate's code
//!   (tracked by [`DecodedLayer::growth_count`] /
//!   [`DecodeScratch::capacity_bytes`], asserted by tests).
//! * [`DecodeScratch`] — per-worker decompression buffers + error/timing
//!   slots, split across the scoped decode threads.
//!
//! Decode of one layer: CRC-verify the payloads, then fan the layer's
//! chunks (across *all* of its tensors — parallelism is not limited to
//! one tensor's chunks) out over scoped threads, each decompressing into
//! its disjoint slice of the packed arena; then a single serial
//! unpack/copy pass expands sub-8-bit streams into the codes arena. With
//! `n_threads == 1` (or a single chunk) everything runs inline on the
//! caller's thread — the 1-vCPU graceful fallback.

use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::format::{TensorKind, TqmReader};
use crate::model::MATRIX_NAMES;
use crate::quant::packing;
use crate::runtime::literal;
use crate::xla;

/// Grow-only resize that counts reallocation events (the zero-alloc
/// assertion watches this counter go quiet after warmup).
fn grow_to<T: Clone + Default>(v: &mut Vec<T>, n: usize, grew: &mut u64) {
    if v.capacity() < n {
        *grew += 1;
    }
    v.resize(n, T::default());
}

/// One decompression work unit: a chunk's compressed bytes (absolute
/// range in the container) and its destination in the packed arena.
#[derive(Clone, Debug)]
struct ChunkPlan {
    src: Range<usize>,
    dst: Range<usize>,
}

/// Per-matrix layout within a layer's arenas.
#[derive(Clone, Debug)]
struct MatPlan {
    rec: usize,
    packed: Range<usize>,
    codes: Range<usize>,
}

/// A contiguous run of chunks assigned to one decode thread, with the
/// packed-arena range it owns (group ranges tile the arena in order, so
/// the arena can be handed out via `split_at_mut` with no allocation).
#[derive(Clone, Debug)]
struct GroupPlan {
    chunks: Range<usize>,
    packed: Range<usize>,
}

#[derive(Clone, Debug)]
struct LayerPlan {
    mats: Vec<MatPlan>,      // 7 entries, MATRIX_NAMES order
    norm_recs: [usize; 2],   // ln1, ln2
    norm_lens: [usize; 2],   // element counts
    chunks: Vec<ChunkPlan>,
    groups: Vec<GroupPlan>,
    packed_total: usize,
    codes_total: usize,
    expanded_bytes: usize,
}

/// Per-worker decode state. Lives in [`DecodeScratch`] so the buffers are
/// reused across layers and passes.
#[derive(Default)]
struct WorkerSlot {
    buf: Vec<u8>,
    err: Option<anyhow::Error>,
    busy_ns: u64,
}

/// Reusable worker-thread scratch for one decode loop.
pub struct DecodeScratch {
    slots: Vec<WorkerSlot>,
}

impl DecodeScratch {
    pub fn new(n_threads: usize) -> Self {
        Self { slots: (0..n_threads.max(1)).map(|_| WorkerSlot::default()).collect() }
    }

    /// Total capacity currently held by the worker buffers. The buffers
    /// are grow-only and reused, so in steady state this is constant —
    /// the zero-allocation test snapshots it after warmup.
    pub fn capacity_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.buf.capacity()).sum()
    }
}

/// Reusable arena set one decoded layer lands in.
#[derive(Default)]
pub struct DecodedLayer {
    pub index: usize,
    /// Decompressed (still bit-packed for sub-8-bit) streams, 7 matrices
    /// laid out back to back.
    packed: Vec<u8>,
    /// One-byte-per-code expansion (what the stage HLOs take).
    codes: Vec<u8>,
    /// ln1 ++ ln2 f32 values.
    norms: Vec<f32>,
    /// Staging for broadcasting per-tensor scale/zero to channel vectors.
    params: Vec<f32>,
    grew: u64,
}

impl DecodedLayer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reallocation events inside this layer's arenas so far.
    pub fn growth_count(&self) -> u64 {
        self.grew
    }

    /// Unpacked codes of matrix `m` (MATRIX_NAMES order) — test hook.
    pub fn codes_of(&self, decoder: &LayerDecoder, layer: usize, m: usize) -> &[u8] {
        let plan = &decoder.layers[layer].mats[m];
        &self.codes[plan.codes.clone()]
    }
}

/// Timing/throughput sample for one layer decode.
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeStats {
    /// Sum of per-worker busy time (≥ wall time × utilized cores).
    pub busy_ns: u64,
    /// Decompressed payload bytes produced (packed stream + norms).
    pub payload_bytes: usize,
}

pub struct LayerDecoder {
    reader: Arc<TqmReader>,
    n_threads: usize,
    layers: Vec<LayerPlan>,
}

impl LayerDecoder {
    /// Plan the decode of every layer. `n_threads` is the worker count the
    /// chunk fan-out targets (1 = always serial).
    pub fn new(reader: Arc<TqmReader>, cfg: &ModelConfig, n_threads: usize) -> Result<Self> {
        let n_threads = n_threads.max(1);
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            layers.push(Self::plan_layer(&reader, i, n_threads)?);
        }
        Ok(Self { reader, n_threads, layers })
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Bytes layer `i` occupies once expanded (codes + params + norms) —
    /// same accounting as `LayerWeights::expanded_bytes`.
    pub fn expanded_bytes(&self, i: usize) -> usize {
        self.layers[i].expanded_bytes
    }

    fn plan_layer(reader: &TqmReader, i: usize, n_threads: usize) -> Result<LayerPlan> {
        let rec_of = |name: &str| reader.record_index(&format!("layers.{i}.{name}"));
        let norm_recs = [rec_of("ln1")?, rec_of("ln2")?];
        let mut norm_lens = [0usize; 2];
        for (k, &ri) in norm_recs.iter().enumerate() {
            let r = reader.record_at(ri);
            if r.kind != TensorKind::F32Raw {
                bail!("tqm: layers.{i} norm {k} is not f32");
            }
            norm_lens[k] = r.raw_len / 4;
        }

        let mut mats = Vec::with_capacity(MATRIX_NAMES.len());
        let mut chunks = Vec::new();
        let mut packed_off = 0usize;
        let mut codes_off = 0usize;
        let mut expanded = norm_lens.iter().sum::<usize>() * 4;
        for name in MATRIX_NAMES {
            let ri = rec_of(name)?;
            let r = reader.record_at(ri);
            if r.kind != TensorKind::QuantU8 || r.shape.len() != 2 {
                bail!("tqm: layers.{i}.{name} is not a quantized matrix");
            }
            // layer matmul weights are per-tensor or per-out-channel
            // (axis 1); anything else would silently mis-broadcast params
            if matches!(r.granularity, crate::quant::Granularity::PerChannel { axis } if axis != 1)
            {
                bail!("tqm: layers.{i}.{name} has unsupported granularity {:?}", r.granularity);
            }
            let n_codes = crate::tensor::numel(&r.shape);
            let payload = reader
                .payload_bytes(r)
                .with_context(|| format!("planning layers.{i}.{name}"))?;
            let mat_packed_start = packed_off;
            if reader.is_chunked() {
                let idx = crate::compress::stream::parse_chunk_index(payload)?;
                anyhow::ensure!(
                    idx.raw_len() == r.raw_len,
                    "tqm: layers.{i}.{name} chunk raw lens sum {} != {}",
                    idx.raw_len(),
                    r.raw_len
                );
                let body_abs = r.payload_offset + idx.body_start;
                let body_len = payload.len() - idx.body_start;
                for (ci, &(off, raw_len)) in idx.entries.iter().enumerate() {
                    let end = idx.chunk_end(ci, body_len);
                    chunks.push(ChunkPlan {
                        src: body_abs + off..body_abs + end,
                        dst: packed_off..packed_off + raw_len,
                    });
                    packed_off += raw_len;
                }
            } else {
                chunks.push(ChunkPlan {
                    src: r.payload_offset..r.payload_offset + r.payload_len,
                    dst: packed_off..packed_off + r.raw_len,
                });
                packed_off += r.raw_len;
            }
            mats.push(MatPlan {
                rec: ri,
                packed: mat_packed_start..packed_off,
                codes: codes_off..codes_off + n_codes,
            });
            codes_off += n_codes;
            expanded += n_codes + 4 * (r.scale.len() + r.zero.len());
        }

        // partition chunks into <= n_threads contiguous, byte-balanced
        // groups; group packed ranges tile [0, packed_total)
        let groups = Self::partition(&chunks, packed_off, n_threads);
        Ok(LayerPlan {
            mats,
            norm_recs,
            norm_lens,
            chunks,
            groups,
            packed_total: packed_off,
            codes_total: codes_off,
            expanded_bytes: expanded,
        })
    }

    fn partition(chunks: &[ChunkPlan], total: usize, n_threads: usize) -> Vec<GroupPlan> {
        if chunks.is_empty() {
            return Vec::new();
        }
        let n_groups = n_threads.clamp(1, chunks.len());
        let target = (total + n_groups - 1) / n_groups.max(1);
        let mut groups: Vec<GroupPlan> = Vec::with_capacity(n_groups);
        let mut start = 0usize;
        let mut bytes = 0usize;
        for (ci, c) in chunks.iter().enumerate() {
            bytes += c.dst.len();
            let is_last = ci + 1 == chunks.len();
            // close the group when it reached its byte target (but never
            // leave fewer chunks than remaining groups), or when exactly
            // one chunk per remaining group is left (forced close so every
            // group gets work — e.g. 7 single-chunk tensors on 7 threads)
            let groups_left = n_groups - groups.len();
            let chunks_left = chunks.len() - (ci + 1);
            let must_close = groups_left > 1 && chunks_left == groups_left - 1;
            let may_close =
                bytes >= target && groups_left > 1 && chunks_left >= groups_left - 1;
            if is_last || must_close || may_close {
                groups.push(GroupPlan {
                    chunks: start..ci + 1,
                    packed: chunks[start].dst.start..c.dst.end,
                });
                start = ci + 1;
                bytes = 0;
                if groups.len() == n_groups {
                    break;
                }
            }
        }
        // the early-close conditions require groups_left > 1, so the final
        // group is always closed by is_last and every chunk is assigned
        debug_assert_eq!(start, chunks.len());
        groups
    }

    /// Decode layer `i` into `out`, fanning out across `n_threads` scoped
    /// workers. Zero allocations in steady state (arenas and worker
    /// buffers are grow-only and reused).
    pub fn decode_into(
        &self,
        i: usize,
        out: &mut DecodedLayer,
        scratch: &mut DecodeScratch,
    ) -> Result<DecodeStats> {
        let plan = &self.layers[i];
        let reader = &*self.reader;
        out.index = i;
        grow_to(&mut out.packed, plan.packed_total, &mut out.grew);
        grow_to(&mut out.codes, plan.codes_total, &mut out.grew);
        let norms_total = plan.norm_lens.iter().sum::<usize>();
        grow_to(&mut out.norms, norms_total, &mut out.grew);

        // CRC pass: verify every payload this layer touches (the planner's
        // absolute chunk ranges then slice the verified bytes directly).
        // Deliberately re-checked every pass, matching the legacy loader:
        // the container's torn-write/bit-flip protection stays on the
        // serving path. crc32fast runs at multiple GB/s, well above codec
        // decode throughput, so the serial cost ahead of the fan-out is
        // a few percent.
        for m in &plan.mats {
            reader.payload_bytes(reader.record_at(m.rec))?;
        }

        // fan the chunk decodes out; groups tile the packed arena in
        // order, so it can be carved up with split_at_mut, allocation-free
        for s in scratch.slots.iter_mut() {
            s.busy_ns = 0;
            s.err = None;
        }
        let data = reader.bytes();
        // serial fallback: one group, one worker slot, or a caller-supplied
        // scratch smaller than the planned fan-out
        if plan.groups.len() <= 1 || scratch.slots.len() < plan.groups.len() {
            let slot = &mut scratch.slots[0];
            let t0 = Instant::now();
            for c in &plan.chunks {
                reader.decode_unit_into(&data[c.src.clone()], c.dst.len(), &mut slot.buf)?;
                out.packed[c.dst.clone()].copy_from_slice(&slot.buf);
            }
            slot.busy_ns = t0.elapsed().as_nanos() as u64;
        } else {
            // scoped threads are spawned per layer decode: simple, safe,
            // and cheap relative to ms-scale layer decodes. If profiling
            // ever shows spawn overhead on very small layers, the group
            // plans are already shaped for a persistent worker pool.
            std::thread::scope(|s| {
                let mut rest: &mut [u8] = &mut out.packed[..plan.packed_total];
                for (g, slot) in plan.groups.iter().zip(scratch.slots.iter_mut()) {
                    // group packed ranges tile the arena in order (see
                    // group_partition_tiles_arena), so peeling slices off
                    // the front hands each worker exactly its range
                    let (mine, tail) = rest.split_at_mut(g.packed.len());
                    rest = tail;
                    let chunks = &plan.chunks[g.chunks.clone()];
                    let base = g.packed.start;
                    s.spawn(move || {
                        let t0 = Instant::now();
                        for c in chunks {
                            match reader.decode_unit_into(
                                &data[c.src.clone()],
                                c.dst.len(),
                                &mut slot.buf,
                            ) {
                                Ok(()) => {
                                    mine[c.dst.start - base..c.dst.end - base]
                                        .copy_from_slice(&slot.buf);
                                }
                                Err(e) => {
                                    slot.err = Some(e);
                                    break;
                                }
                            }
                        }
                        slot.busy_ns = t0.elapsed().as_nanos() as u64;
                    });
                }
            });
            if let Some(e) = scratch.slots.iter_mut().find_map(|s| s.err.take()) {
                return Err(e).with_context(|| format!("decoding layer {i}"));
            }
        }

        // expand sub-8-bit streams to one byte per code (8-bit is a copy)
        for m in &plan.mats {
            let r = reader.record_at(m.rec);
            let bits = r.bits.storage_bits();
            let src = &out.packed[m.packed.clone()];
            let dst = &mut out.codes[m.codes.clone()];
            if bits < 8 {
                packing::unpack_into(src, bits, dst);
            } else {
                dst.copy_from_slice(src);
            }
        }

        // norm vectors: raw little-endian f32 payloads
        let mut off = 0usize;
        for (k, &ri) in plan.norm_recs.iter().enumerate() {
            let r = reader.record_at(ri);
            let p = reader.payload_bytes(r)?;
            let n = plan.norm_lens[k];
            for (dst, src) in out.norms[off..off + n].iter_mut().zip(p.chunks_exact(4)) {
                *dst = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
            }
            off += n;
        }

        let busy_ns = scratch.slots.iter().map(|s| s.busy_ns).sum();
        Ok(DecodeStats { busy_ns, payload_bytes: plan.packed_total + norms_total * 4 })
    }

    /// Flatten a decoded layer into the stage-argument literal list —
    /// identical order and contents to `LayerWeights::to_literals`:
    /// ln1, (wq,s,z), (wk,..), (wv,..), (wo,..), ln2, (w1,..), (w3,..),
    /// (w2,..). Per-tensor params are broadcast through the layer's
    /// reusable staging buffer, so no per-tensor Vec is allocated here
    /// either (the xla literals themselves own fresh storage, of course).
    pub fn to_literals(&self, layer: &mut DecodedLayer) -> Result<Vec<xla::Literal>> {
        let plan = &self.layers[layer.index];
        let reader = &*self.reader;
        let mut out = Vec::with_capacity(2 + plan.mats.len() * 3);

        let norm_lit = |layer: &DecodedLayer, k: usize| -> Result<xla::Literal> {
            let start: usize = plan.norm_lens[..k].iter().sum();
            let n = plan.norm_lens[k];
            let r = reader.record_at(plan.norm_recs[k]);
            literal::f32_literal(&r.shape, &layer.norms[start..start + n])
        };

        let mat_lits =
            |layer: &mut DecodedLayer, m: &MatPlan, out: &mut Vec<xla::Literal>| -> Result<()> {
                let r = reader.record_at(m.rec);
                let ch = r.shape[1];
                out.push(literal::u8_literal(&r.shape, &layer.codes[m.codes.clone()])?);
                if r.scale.len() == 1 {
                    grow_to(&mut layer.params, ch, &mut layer.grew);
                    layer.params[..ch].fill(r.scale[0]);
                    out.push(literal::f32_literal(&[ch], &layer.params[..ch])?);
                    layer.params[..ch].fill(r.zero[0]);
                    out.push(literal::f32_literal(&[ch], &layer.params[..ch])?);
                } else {
                    anyhow::ensure!(
                        r.scale.len() == ch,
                        "tqm: {:?} scale count {} != out channels {ch}",
                        r.name,
                        r.scale.len()
                    );
                    out.push(literal::f32_literal(&[ch], &r.scale)?);
                    out.push(literal::f32_literal(&[ch], &r.zero)?);
                }
                Ok(())
            };

        out.push(norm_lit(layer, 0)?);
        for mi in 0..4 {
            let m = plan.mats[mi].clone();
            mat_lits(layer, &m, &mut out)?;
        }
        out.push(norm_lit(layer, 1)?);
        for mi in 4..plan.mats.len() {
            let m = plan.mats[mi].clone();
            mat_lits(layer, &m, &mut out)?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::QuantizeOptions;
    use crate::model::tests::{fake_checkpoint, tiny_cfg};
    use crate::model::{quantize_checkpoint, LayerWeights};
    use crate::util::TempDir;

    fn build_reader(codec: CodecId, chunk_len: usize, per_channel: bool) -> Arc<TqmReader> {
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 11);
        let opts = QuantizeOptions { per_channel, ..Default::default() };
        let w = quantize_checkpoint(&cfg, &ckpt, &opts, codec, None, "unit")
            .unwrap()
            .with_chunk_len(chunk_len);
        let dir = TempDir::new().unwrap();
        let p = dir.join("m.tqm");
        w.write(&p).unwrap();
        Arc::new(TqmReader::open(&p).unwrap())
    }

    #[test]
    fn parallel_matches_serial_for_every_codec() {
        // tiny chunk_len forces multi-chunk payloads; the fan-out decode
        // must reproduce the legacy single-threaded path byte for byte
        let cfg = tiny_cfg();
        for codec in crate::compress::all_codec_ids() {
            let reader = build_reader(codec, 97, true);
            let serial = LayerDecoder::new(reader.clone(), &cfg, 1).unwrap();
            let parallel = LayerDecoder::new(reader.clone(), &cfg, 4).unwrap();
            for i in 0..cfg.n_layers {
                let legacy = LayerWeights::load(&reader, i).unwrap();
                let mut a = DecodedLayer::new();
                let mut b = DecodedLayer::new();
                let mut sa = DecodeScratch::new(1);
                let mut sb = DecodeScratch::new(4);
                serial.decode_into(i, &mut a, &mut sa).unwrap();
                parallel.decode_into(i, &mut b, &mut sb).unwrap();
                assert_eq!(a.codes, b.codes, "{codec:?} layer {i}");
                assert_eq!(a.norms, b.norms, "{codec:?} layer {i}");
                // and both match the legacy per-tensor load
                let legacy_mats =
                    [&legacy.wq, &legacy.wk, &legacy.wv, &legacy.wo, &legacy.w1, &legacy.w3, &legacy.w2];
                for (mi, q) in legacy_mats.iter().enumerate() {
                    assert_eq!(
                        a.codes_of(&serial, i, mi),
                        q.codes.data.as_slice(),
                        "{codec:?} layer {i} mat {mi}"
                    );
                }
                assert_eq!(
                    serial.expanded_bytes(i),
                    legacy.expanded_bytes(),
                    "{codec:?} layer {i} expanded accounting"
                );
            }
        }
    }

    #[test]
    fn steady_state_decode_is_allocation_free() {
        // after one warmup pass over all layers, further passes must not
        // grow any arena or worker buffer — the zero-alloc criterion
        let cfg = tiny_cfg();
        let reader = build_reader(CodecId::FreqSeqPacked, 64, true);
        let dec = LayerDecoder::new(reader, &cfg, 4).unwrap();
        let mut layer = DecodedLayer::new();
        let mut scratch = DecodeScratch::new(4);
        for i in 0..cfg.n_layers {
            dec.decode_into(i, &mut layer, &mut scratch).unwrap();
            let _ = dec.to_literals(&mut layer).unwrap();
        }
        let arena_growth = layer.growth_count();
        let scratch_cap = scratch.capacity_bytes();
        assert!(arena_growth > 0, "warmup must have grown the arenas");
        for _pass in 0..3 {
            for i in 0..cfg.n_layers {
                dec.decode_into(i, &mut layer, &mut scratch).unwrap();
                let _ = dec.to_literals(&mut layer).unwrap();
            }
        }
        assert_eq!(layer.growth_count(), arena_growth, "steady-state arenas reallocated");
        assert_eq!(scratch.capacity_bytes(), scratch_cap, "worker buffers grew in steady state");
    }

    #[test]
    fn literals_match_legacy_layer_weights() {
        let cfg = tiny_cfg();
        for per_channel in [false, true] {
            let reader = build_reader(CodecId::Huffman, 128, per_channel);
            let dec = LayerDecoder::new(reader.clone(), &cfg, 2).unwrap();
            let mut layer = DecodedLayer::new();
            let mut scratch = DecodeScratch::new(2);
            for i in 0..cfg.n_layers {
                dec.decode_into(i, &mut layer, &mut scratch).unwrap();
                let fast = dec.to_literals(&mut layer).unwrap();
                let legacy = LayerWeights::load(&reader, i).unwrap().to_literals(&cfg).unwrap();
                assert_eq!(fast.len(), legacy.len());
                for (k, (f, l)) in fast.iter().zip(&legacy).enumerate() {
                    assert_eq!(
                        literal::literal_shape(f).unwrap(),
                        literal::literal_shape(l).unwrap(),
                        "arg {k} shape (per_channel={per_channel})"
                    );
                    let (ft, lt) = (f.ty().unwrap(), l.ty().unwrap());
                    assert_eq!(ft, lt, "arg {k} dtype");
                    if ft == xla::ElementType::U8 {
                        assert_eq!(
                            f.to_vec::<u8>().unwrap(),
                            l.to_vec::<u8>().unwrap(),
                            "arg {k} codes"
                        );
                    } else {
                        assert_eq!(
                            f.to_vec::<f32>().unwrap(),
                            l.to_vec::<f32>().unwrap(),
                            "arg {k} f32"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn corrupt_payload_is_rejected_not_panicking() {
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 12);
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_checkpoint(&cfg, &ckpt, &opts, CodecId::Lzw, None, "unit")
            .unwrap()
            .with_chunk_len(80);
        let dir = TempDir::new().unwrap();
        let p = dir.join("m.tqm");
        w.write(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // poison a byte in the middle of a layer matrix payload
        let clean = TqmReader::from_bytes(bytes.clone()).unwrap();
        let rec = clean.record("layers.1.w2").unwrap();
        let poison_at = rec.payload_offset + rec.payload_len / 2;
        drop(clean);
        bytes[poison_at] ^= 0xA5;
        let reader = Arc::new(TqmReader::from_bytes(bytes).unwrap());
        // the CRC fails either at plan time or at decode time — both are
        // errors, never a panic or silent corruption
        match LayerDecoder::new(reader, &cfg, 4) {
            Err(_) => {}
            Ok(dec) => {
                let mut layer = DecodedLayer::new();
                let mut scratch = DecodeScratch::new(4);
                let mut saw_err = false;
                for i in 0..cfg.n_layers {
                    if dec.decode_into(i, &mut layer, &mut scratch).is_err() {
                        saw_err = true;
                    }
                }
                assert!(saw_err, "corruption went unnoticed");
            }
        }
    }

    #[test]
    fn group_partition_tiles_arena() {
        let chunks: Vec<ChunkPlan> = [10usize, 30, 5, 25, 40, 1, 9]
            .iter()
            .scan(0usize, |acc, &len| {
                let c = ChunkPlan { src: 0..0, dst: *acc..*acc + len };
                *acc += len;
                Some(c)
            })
            .collect();
        let total = 120;
        for n_threads in 1..=9 {
            let groups = LayerDecoder::partition(&chunks, total, n_threads);
            assert!(!groups.is_empty());
            assert!(groups.len() <= n_threads.max(1));
            assert_eq!(groups[0].chunks.start, 0);
            assert_eq!(groups[0].packed.start, 0);
            assert_eq!(groups.last().unwrap().chunks.end, chunks.len());
            assert_eq!(groups.last().unwrap().packed.end, total);
            for w in groups.windows(2) {
                assert_eq!(w[0].chunks.end, w[1].chunks.start, "n={n_threads}");
                assert_eq!(w[0].packed.end, w[1].packed.start, "n={n_threads}");
            }
        }
    }
}
