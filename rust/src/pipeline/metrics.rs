//! Pipeline observability: decompression / execution timing and the
//! expanded-weight residency accounting behind the E8 bench.
//!
//! Residency model: `constant` covers what is always held (embedding +
//! head + either the compressed blob or all expanded layers), `transient`
//! is the high-water mark of per-layer expansions live at once (1 for
//! plain streaming, 2 with prefetch, LRU-resident bytes for Lru(n)).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use crate::util::Json;

/// Schema version stamped into `METRICS_<run>.json` snapshots; bump on
/// incompatible change.
pub const METRICS_SCHEMA_VERSION: u32 = 1;

#[derive(Default)]
pub struct PipelineMetrics {
    decompress_ns: AtomicU64,
    decompress_bytes: AtomicU64,
    decompress_count: AtomicU64,
    /// Sum of per-worker busy time inside the parallel layer decode —
    /// `decode_busy_ns / decompress_ns` is the mean number of cores the
    /// decode kept busy.
    decode_busy_ns: AtomicU64,
    /// Decode worker threads the engine was configured with.
    decode_threads: AtomicUsize,
    exec_ns: AtomicU64,
    exec_count: AtomicU64,
    lru_hits: AtomicU64,
    constant_bytes: AtomicUsize,
    peak_transient_bytes: AtomicUsize,
    lru_resident_bytes: AtomicUsize,
    // -- expert cache (MoE serving) -----------------------------------------
    expert_hits: AtomicU64,
    expert_misses: AtomicU64,
    /// Of the hits/misses above, how many were served by a *packed*
    /// (quantized-domain) cache — the per-residency-mode split.
    expert_hits_packed: AtomicU64,
    expert_misses_packed: AtomicU64,
    expert_evictions: AtomicU64,
    /// Experts currently held by the cache (demand + speculative slots).
    expert_resident_count: AtomicUsize,
    /// Wall time spent decoding experts on cache misses.
    expert_decode_ns: AtomicU64,
    expert_decoded_bytes: AtomicU64,
    /// Decoded-expert bytes currently held by the cache.
    expert_resident_bytes: AtomicUsize,
    /// High-water mark of decoded-expert bytes (cached + in-flight decode)
    /// — the number the cache-budget acceptance test asserts against.
    /// With a prefetch slice active it covers demand + speculative bytes,
    /// so the bound it is tested against becomes
    /// `expert_budget_bytes + prefetch_budget_bytes`.
    expert_peak_resident_bytes: AtomicUsize,
    // -- expert scheduler (batch dedup + prefetch) ---------------------------
    /// Routed (sequence, layer, expert) picks the scheduler planned for.
    sched_routed_picks: AtomicU64,
    /// Unique (layer, expert) entries across those plans — what actually
    /// had to be fetched. `routed / planned` is the batch dedup factor.
    sched_planned_fetches: AtomicU64,
    /// Scheduler layer-plans built (one per layer per forward step).
    sched_plans: AtomicU64,
    /// Wall time of completed `forward_batch` steps — the reconciliation
    /// base for the time-accounting identity (stall + exec ≤ wall).
    forward_wall_ns: AtomicU64,
    /// Completed forward steps behind `forward_wall_ns`.
    forward_steps: AtomicU64,
    /// Batched (layer, expert, token-group) qGEMM calls executed — one
    /// traversal of the expert's packed streams each. With batching on,
    /// equals `sched_planned_fetches`.
    exec_batched_groups: AtomicU64,
    /// Routed tokens served by those batched calls.
    exec_batched_tokens: AtomicU64,
    /// Routed picks executed as per-token qGEMV calls (batching off).
    exec_scalar_picks: AtomicU64,
    /// Prefetch jobs handed to the worker pool.
    prefetch_issued: AtomicU64,
    /// Speculative decodes admitted into the cache's prefetch slice.
    prefetch_inserted: AtomicU64,
    /// Demand lookups served by a speculative entry (stall fully hidden).
    prefetch_hits: AtomicU64,
    /// Prefetches rejected by the size-aware admission check (or lost a
    /// race with the demand path) — decode work that bought nothing.
    prefetch_rejected: AtomicU64,
    /// Speculative entries dropped without ever being demanded.
    prefetch_evicted_unused: AtomicU64,
    /// Background decode wall time — work moved *off* the forward step.
    prefetch_decode_ns: AtomicU64,
    prefetch_decoded_bytes: AtomicU64,
    /// Speculative (prefetched, not yet demanded) bytes currently cached.
    expert_speculative_bytes: AtomicUsize,
    // -- fault handling (retry / quarantine / degradation) -------------------
    /// Expert fetch attempts re-issued after a decode-class failure
    /// (demand path and prefetch workers share the counter).
    fetch_retries: AtomicU64,
    /// Retried fetches that eventually succeeded — transient faults the
    /// retry budget absorbed without any visible degradation.
    retry_successes: AtomicU64,
    /// Experts newly placed in quarantine (failure streak hit the limit).
    quarantined: AtomicU64,
    /// Quarantined experts restored after a successful re-probe decode.
    quarantine_recoveries: AtomicU64,
    /// Recovery probes granted to quarantined experts.
    quarantine_probes: AtomicU64,
    /// Experts dropped from a forward step after exhausting retries.
    expert_drops: AtomicU64,
    /// Routed (sequence, expert) picks stripped by degradation — the
    /// gates of each affected sequence were renormalized over survivors.
    degraded_picks: AtomicU64,
    /// Panics contained inside prefetch workers (worker kept alive).
    prefetch_worker_panics: AtomicU64,
    /// Requests answered with a structured Timeout instead of an answer.
    deadline_timeouts: AtomicU64,
    /// Injected faults, by class (only a bound [`crate::faults::FaultPlan`]
    /// feeds these — all zero in production).
    faults_transient: AtomicU64,
    faults_corrupt: AtomicU64,
    faults_delay: AtomicU64,
    // -- admission / overload (host) -----------------------------------------
    // Two exact identities, the same discipline as the prefetch
    // `issued == hits + waste` reconciliation:
    //   submitted == admitted + rejected
    //   admitted  == completed + timed_out (deadline_timeouts) + shed
    //               + aborted + in-flight
    // [`PipelineMetrics::admission_identity`] renders and checks both.
    /// Requests offered to the host (admitted or not).
    requests_submitted: AtomicU64,
    /// Requests that passed admission into the queue.
    requests_admitted: AtomicU64,
    /// Requests refused at admission (`MoeError::Overloaded`) — the
    /// bounded queue, a tenant quota, or the fair-share clamp said no.
    requests_rejected: AtomicU64,
    /// Admitted requests dropped before their first forward step
    /// (`MoeError::Shed`) — deadline-aware shed-before-work, disjoint
    /// from `deadline_timeouts` which is charged after work was spent.
    requests_shed: AtomicU64,
    /// Admitted requests answered with their full output.
    requests_completed: AtomicU64,
    /// Admitted requests answered with an error other than
    /// timeout/shed (forward failure, host shutdown mid-request).
    requests_aborted: AtomicU64,
    /// Cache-backpressure events: the admitted batch was halved because
    /// demand-miss stall or eviction churn crossed its threshold.
    batch_shrinks: AtomicU64,
    /// Brown-out transitions to packed expert residency (one-way; >1
    /// only across multiple hosts sharing the metrics).
    brownouts: AtomicU64,
}

impl PipelineMetrics {
    pub fn record_decompress(&self, d: Duration, bytes: usize) {
        self.decompress_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.decompress_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.decompress_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one multi-core layer decode: wall time, expanded bytes, and
    /// the summed busy time of the decode workers.
    pub fn record_decode(&self, wall: Duration, bytes: usize, busy_ns: u64) {
        self.record_decompress(wall, bytes);
        self.decode_busy_ns.fetch_add(busy_ns, Ordering::Relaxed);
    }

    pub fn set_decode_threads(&self, n: usize) {
        self.decode_threads.store(n, Ordering::Relaxed);
    }

    pub fn decode_threads(&self) -> usize {
        self.decode_threads.load(Ordering::Relaxed)
    }

    /// Mean cores kept busy by the layer decode (busy time / wall time);
    /// 0.0 until a decode has been recorded. A value near
    /// `decode_threads()` means the chunk fan-out saturated its workers.
    pub fn decode_utilization(&self) -> f64 {
        let wall = self.decompress_ns.load(Ordering::Relaxed);
        if wall == 0 {
            return 0.0;
        }
        self.decode_busy_ns.load(Ordering::Relaxed) as f64 / wall as f64
    }

    pub fn record_exec(&self, d: Duration) {
        self.exec_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.exec_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn lru_hit(&self) {
        self.lru_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn set_constant_bytes(&self, b: usize) {
        self.constant_bytes.store(b, Ordering::Relaxed);
    }

    pub fn observe_transient(&self, bytes: usize) {
        self.peak_transient_bytes.fetch_max(bytes, Ordering::Relaxed);
    }

    pub fn update_lru_resident(&self, resident: usize, _evicted: usize) {
        self.lru_resident_bytes.store(resident, Ordering::Relaxed);
        self.peak_transient_bytes.fetch_max(resident, Ordering::Relaxed);
    }

    /// Peak bytes held for weights during serving.
    pub fn peak_bytes(&self) -> usize {
        self.constant_bytes.load(Ordering::Relaxed)
            + self.peak_transient_bytes.load(Ordering::Relaxed)
    }

    /// High-water mark of per-layer expansions only (excludes the constant
    /// part: heads + compressed blob / resident layers).
    pub fn transient_peak_bytes(&self) -> usize {
        self.peak_transient_bytes.load(Ordering::Relaxed)
    }

    pub fn constant_bytes(&self) -> usize {
        self.constant_bytes.load(Ordering::Relaxed)
    }

    pub fn decompress_secs(&self) -> f64 {
        self.decompress_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn exec_secs(&self) -> f64 {
        self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn decompress_count(&self) -> u64 {
        self.decompress_count.load(Ordering::Relaxed)
    }

    pub fn lru_hits_count(&self) -> u64 {
        self.lru_hits.load(Ordering::Relaxed)
    }

    // -- expert cache -------------------------------------------------------

    /// A router pick found its expert resident in the cache (no decode).
    /// `packed` records which residency mode served it.
    pub fn expert_hit(&self, packed: bool) {
        self.expert_hits.fetch_add(1, Ordering::Relaxed);
        if packed {
            self.expert_hits_packed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A router pick missed: `d` is the decode wall time, `bytes` the
    /// resident size of the expert in its mode (f32 arenas when decoded,
    /// code streams + params when packed).
    pub fn record_expert_miss(&self, d: Duration, bytes: usize, packed: bool) {
        self.expert_misses.fetch_add(1, Ordering::Relaxed);
        if packed {
            self.expert_misses_packed.fetch_add(1, Ordering::Relaxed);
        }
        self.expert_decode_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.expert_decoded_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_expert_eviction(&self) {
        self.expert_evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Cached decoded-expert bytes after an insert/evict (also advances
    /// the peak).
    pub fn set_expert_resident(&self, bytes: usize) {
        self.expert_resident_bytes.store(bytes, Ordering::Relaxed);
        self.expert_peak_resident_bytes.fetch_max(bytes, Ordering::Relaxed);
    }

    /// Advance the decoded-expert high-water mark without changing the
    /// resident figure (in-flight decode bytes during a miss).
    pub fn observe_expert_transient(&self, bytes: usize) {
        self.expert_peak_resident_bytes.fetch_max(bytes, Ordering::Relaxed);
    }

    /// Experts held by the cache right now (demand + speculative).
    pub fn set_expert_resident_count(&self, n: usize) {
        self.expert_resident_count.store(n, Ordering::Relaxed);
    }

    pub fn expert_resident_count(&self) -> usize {
        self.expert_resident_count.load(Ordering::Relaxed)
    }

    pub fn expert_hits_count(&self) -> u64 {
        self.expert_hits.load(Ordering::Relaxed)
    }

    pub fn expert_misses_count(&self) -> u64 {
        self.expert_misses.load(Ordering::Relaxed)
    }

    /// Hits served by a packed-resident cache (per-mode split; the
    /// decoded share is `expert_hits_count() - expert_packed_hits_count()`).
    pub fn expert_packed_hits_count(&self) -> u64 {
        self.expert_hits_packed.load(Ordering::Relaxed)
    }

    pub fn expert_packed_misses_count(&self) -> u64 {
        self.expert_misses_packed.load(Ordering::Relaxed)
    }

    /// Bytes materialized by expert-cache misses so far (resident-mode
    /// sized: f32 when decoded, packed streams when packed) — the
    /// "bytes/token decoded" numerator of the residency table.
    pub fn expert_decoded_bytes(&self) -> u64 {
        self.expert_decoded_bytes.load(Ordering::Relaxed)
    }

    pub fn expert_evictions_count(&self) -> u64 {
        self.expert_evictions.load(Ordering::Relaxed)
    }

    /// Hit fraction of expert lookups so far (0.0 before any lookup).
    pub fn expert_hit_rate(&self) -> f64 {
        let h = self.expert_hits_count();
        let m = self.expert_misses_count();
        if h + m == 0 {
            return 0.0;
        }
        h as f64 / (h + m) as f64
    }

    pub fn expert_resident_bytes(&self) -> usize {
        self.expert_resident_bytes.load(Ordering::Relaxed)
    }

    pub fn expert_peak_resident_bytes(&self) -> usize {
        self.expert_peak_resident_bytes.load(Ordering::Relaxed)
    }

    /// Mean decode latency per expert-cache miss, in milliseconds.
    pub fn expert_miss_mean_ms(&self) -> f64 {
        let m = self.expert_misses_count();
        if m == 0 {
            return 0.0;
        }
        self.expert_decode_ns.load(Ordering::Relaxed) as f64 / 1e6 / m as f64
    }

    /// Total decode wall time spent *at the forward step* on expert-cache
    /// misses — the stall the scheduler's prefetch exists to hide
    /// (speculative decodes run on background workers and are accounted
    /// separately by [`PipelineMetrics::prefetch_hidden_secs`]).
    pub fn expert_stall_secs(&self) -> f64 {
        self.expert_decode_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    // -- expert scheduler ---------------------------------------------------

    /// One layer plan built: `routed` picks across the batch collapsed
    /// into `planned` unique expert fetches.
    pub fn record_sched_plan(&self, routed: u64, planned: u64) {
        self.sched_routed_picks.fetch_add(routed, Ordering::Relaxed);
        self.sched_planned_fetches.fetch_add(planned, Ordering::Relaxed);
        self.sched_plans.fetch_add(1, Ordering::Relaxed);
    }

    pub fn sched_routed_picks(&self) -> u64 {
        self.sched_routed_picks.load(Ordering::Relaxed)
    }

    pub fn sched_planned_fetches(&self) -> u64 {
        self.sched_planned_fetches.load(Ordering::Relaxed)
    }

    pub fn sched_plans_count(&self) -> u64 {
        self.sched_plans.load(Ordering::Relaxed)
    }

    /// Routed picks per unique fetch across all plans so far (1.0 = no
    /// batch overlap; 0.0 before any plan).
    pub fn sched_dedup_factor(&self) -> f64 {
        let planned = self.sched_planned_fetches();
        if planned == 0 {
            return 0.0;
        }
        self.sched_routed_picks() as f64 / planned as f64
    }

    /// One completed `forward_batch` step: its wall time is the base the
    /// time-accounting identity reconciles stall + exec against.
    pub fn record_forward_wall(&self, d: Duration) {
        self.forward_wall_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.forward_steps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn forward_wall_secs(&self) -> f64 {
        self.forward_wall_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn forward_steps_count(&self) -> u64 {
        self.forward_steps.load(Ordering::Relaxed)
    }

    /// Where forward wall time went. On the serving thread, demand-miss
    /// decode (`stall`) and expert execution (`exec`) are disjoint
    /// sections of the forward loop, so `other = wall - stall - exec` is
    /// the residual (routing, planning, bookkeeping) and can never be
    /// meaningfully negative — the unit tests assert that identity on a
    /// deterministic sync-prefetch run. Prefetch decode time overlaps the
    /// wall on background workers, so it is reported alongside rather
    /// than summed into the identity.
    pub fn time_accounting(&self) -> String {
        let wall = self.forward_wall_secs();
        let stall = self.expert_stall_secs();
        let exec = self.exec_secs();
        let other = wall - stall - exec;
        format!(
            "time: forward wall {:.1} ms = stall {:.1} + exec {:.1} + other {:.1} ms (+ {:.1} ms prefetch decode hidden on workers) over {} steps",
            wall * 1e3,
            stall * 1e3,
            exec * 1e3,
            other * 1e3,
            self.prefetch_hidden_secs() * 1e3,
            self.forward_steps_count(),
        )
    }

    /// One grouped layer executed with batched qGEMM: `groups` (expert,
    /// token-group) calls serving `tokens` routed picks — one packed-
    /// stream traversal per group instead of one per pick.
    pub fn record_exec_batched(&self, groups: u64, tokens: u64) {
        self.exec_batched_groups.fetch_add(groups, Ordering::Relaxed);
        self.exec_batched_tokens.fetch_add(tokens, Ordering::Relaxed);
    }

    /// Routed picks executed on the per-token (scalar qGEMV) path.
    pub fn record_exec_scalar(&self, picks: u64) {
        self.exec_scalar_picks.fetch_add(picks, Ordering::Relaxed);
    }

    pub fn exec_batched_groups_count(&self) -> u64 {
        self.exec_batched_groups.load(Ordering::Relaxed)
    }

    pub fn exec_batched_tokens_count(&self) -> u64 {
        self.exec_batched_tokens.load(Ordering::Relaxed)
    }

    pub fn exec_scalar_picks_count(&self) -> u64 {
        self.exec_scalar_picks.load(Ordering::Relaxed)
    }

    pub fn prefetch_issue(&self) {
        self.prefetch_issued.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_prefetch_insert(&self) {
        self.prefetch_inserted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn prefetch_hit(&self) {
        self.prefetch_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_prefetch_rejected(&self) {
        self.prefetch_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_prefetch_evicted_unused(&self) {
        self.prefetch_evicted_unused.fetch_add(1, Ordering::Relaxed);
    }

    /// One background (speculative) expert decode: wall time + decoded
    /// f32 bytes. This time is *hidden* from the forward step.
    pub fn record_prefetch_decode(&self, d: Duration, bytes: usize) {
        self.prefetch_decode_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.prefetch_decoded_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Speculative bytes gauge. Peak maintenance is the caller's job:
    /// the cache pairs its mutations with
    /// [`PipelineMetrics::observe_expert_transient`] calls.
    pub fn set_expert_speculative(&self, bytes: usize) {
        self.expert_speculative_bytes.store(bytes, Ordering::Relaxed);
    }

    pub fn expert_speculative_bytes(&self) -> usize {
        self.expert_speculative_bytes.load(Ordering::Relaxed)
    }

    pub fn prefetch_issued_count(&self) -> u64 {
        self.prefetch_issued.load(Ordering::Relaxed)
    }

    pub fn prefetch_inserted_count(&self) -> u64 {
        self.prefetch_inserted.load(Ordering::Relaxed)
    }

    pub fn prefetch_hits_count(&self) -> u64 {
        self.prefetch_hits.load(Ordering::Relaxed)
    }

    /// Prefetch work that bought nothing: rejected inserts plus
    /// speculative entries evicted before a demand touched them.
    pub fn prefetch_wasted_count(&self) -> u64 {
        self.prefetch_rejected.load(Ordering::Relaxed)
            + self.prefetch_evicted_unused.load(Ordering::Relaxed)
    }

    /// Decode wall time moved off the forward step onto the prefetch
    /// workers (compare with [`PipelineMetrics::expert_stall_secs`]).
    pub fn prefetch_hidden_secs(&self) -> f64 {
        self.prefetch_decode_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Decoded f32 bytes produced by the prefetch workers.
    pub fn prefetch_decoded_bytes(&self) -> u64 {
        self.prefetch_decoded_bytes.load(Ordering::Relaxed)
    }

    // -- fault handling -----------------------------------------------------

    pub fn record_fetch_retry(&self) {
        self.fetch_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_retry_success(&self) {
        self.retry_successes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_quarantined(&self) {
        self.quarantined.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_quarantine_recovery(&self) {
        self.quarantine_recoveries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_quarantine_probe(&self) {
        self.quarantine_probes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_expert_drop(&self) {
        self.expert_drops.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_degraded_picks(&self, n: u64) {
        self.degraded_picks.fetch_add(n, Ordering::Relaxed);
    }

    pub fn record_prefetch_worker_panic(&self) {
        self.prefetch_worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_deadline_timeout(&self) {
        self.deadline_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    // -- admission / overload ------------------------------------------------

    pub fn record_submitted(&self) {
        self.requests_submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_admitted(&self) {
        self.requests_admitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.requests_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.requests_shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_request_completed(&self) {
        self.requests_completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_request_aborted(&self) {
        self.requests_aborted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch_shrink(&self) {
        self.batch_shrinks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_brownout(&self) {
        self.brownouts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn requests_submitted_count(&self) -> u64 {
        self.requests_submitted.load(Ordering::Relaxed)
    }

    pub fn requests_admitted_count(&self) -> u64 {
        self.requests_admitted.load(Ordering::Relaxed)
    }

    pub fn requests_rejected_count(&self) -> u64 {
        self.requests_rejected.load(Ordering::Relaxed)
    }

    pub fn requests_shed_count(&self) -> u64 {
        self.requests_shed.load(Ordering::Relaxed)
    }

    pub fn requests_completed_count(&self) -> u64 {
        self.requests_completed.load(Ordering::Relaxed)
    }

    pub fn requests_aborted_count(&self) -> u64 {
        self.requests_aborted.load(Ordering::Relaxed)
    }

    pub fn batch_shrinks_count(&self) -> u64 {
        self.batch_shrinks.load(Ordering::Relaxed)
    }

    pub fn brownouts_count(&self) -> u64 {
        self.brownouts.load(Ordering::Relaxed)
    }

    /// Admitted requests not yet answered (derived, 0 once drained).
    pub fn requests_in_flight(&self) -> u64 {
        let done = self.requests_completed_count()
            + self.deadline_timeouts_count()
            + self.requests_shed_count()
            + self.requests_aborted_count();
        self.requests_admitted_count().saturating_sub(done)
    }

    /// Whether both admission identities hold on the current counter
    /// values: `submitted == admitted + rejected`, and every admitted
    /// request is accounted for by exactly one terminal outcome (or is
    /// still in flight). Exact only at a quiet point (host drained);
    /// mid-run reads can transiently disagree across atomics.
    pub fn admission_reconciles(&self) -> bool {
        let done = self.requests_completed_count()
            + self.deadline_timeouts_count()
            + self.requests_shed_count()
            + self.requests_aborted_count();
        self.requests_submitted_count()
            == self.requests_admitted_count() + self.requests_rejected_count()
            && done <= self.requests_admitted_count()
    }

    /// The admission identity, rendered for the summary line and the CI
    /// grep gate: ends in `[OK]` when both identities reconcile,
    /// `[VIOLATION]` otherwise.
    pub fn admission_identity(&self) -> String {
        format!(
            "admission: submitted {} = admitted {} + rejected {}; admitted = completed {} + timeout {} + shed {} + aborted {} + in-flight {} [{}]",
            self.requests_submitted_count(),
            self.requests_admitted_count(),
            self.requests_rejected_count(),
            self.requests_completed_count(),
            self.deadline_timeouts_count(),
            self.requests_shed_count(),
            self.requests_aborted_count(),
            self.requests_in_flight(),
            if self.admission_reconciles() { "OK" } else { "VIOLATION" },
        )
    }

    pub fn record_fault_transient(&self) {
        self.faults_transient.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_fault_corrupt(&self) {
        self.faults_corrupt.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_fault_delay(&self) {
        self.faults_delay.fetch_add(1, Ordering::Relaxed);
    }

    pub fn fetch_retries_count(&self) -> u64 {
        self.fetch_retries.load(Ordering::Relaxed)
    }

    pub fn retry_successes_count(&self) -> u64 {
        self.retry_successes.load(Ordering::Relaxed)
    }

    pub fn quarantined_count(&self) -> u64 {
        self.quarantined.load(Ordering::Relaxed)
    }

    pub fn quarantine_recoveries_count(&self) -> u64 {
        self.quarantine_recoveries.load(Ordering::Relaxed)
    }

    pub fn quarantine_probes_count(&self) -> u64 {
        self.quarantine_probes.load(Ordering::Relaxed)
    }

    pub fn expert_drops_count(&self) -> u64 {
        self.expert_drops.load(Ordering::Relaxed)
    }

    pub fn degraded_picks_count(&self) -> u64 {
        self.degraded_picks.load(Ordering::Relaxed)
    }

    pub fn prefetch_worker_panics_count(&self) -> u64 {
        self.prefetch_worker_panics.load(Ordering::Relaxed)
    }

    pub fn deadline_timeouts_count(&self) -> u64 {
        self.deadline_timeouts.load(Ordering::Relaxed)
    }

    pub fn faults_injected_count(&self) -> u64 {
        self.faults_transient.load(Ordering::Relaxed)
            + self.faults_corrupt.load(Ordering::Relaxed)
            + self.faults_delay.load(Ordering::Relaxed)
    }

    pub fn faults_transient_count(&self) -> u64 {
        self.faults_transient.load(Ordering::Relaxed)
    }

    pub fn faults_corrupt_count(&self) -> u64 {
        self.faults_corrupt.load(Ordering::Relaxed)
    }

    pub fn faults_delay_count(&self) -> u64 {
        self.faults_delay.load(Ordering::Relaxed)
    }

    pub fn decompress_mb_s(&self) -> f64 {
        let secs = self.decompress_secs();
        if secs == 0.0 {
            return 0.0;
        }
        self.decompress_bytes.load(Ordering::Relaxed) as f64 / 1e6 / secs
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "decompress: {} calls, {:.1} ms total ({:.0} MB/s, {:.1}/{} cores busy); exec: {} calls, {:.1} ms; peak weights: {:.2} MB; lru hits: {}",
            self.decompress_count(),
            self.decompress_secs() * 1e3,
            self.decompress_mb_s(),
            self.decode_utilization(),
            self.decode_threads().max(1),
            self.exec_count.load(Ordering::Relaxed),
            self.exec_secs() * 1e3,
            self.peak_bytes() as f64 / 1e6,
            self.lru_hits_count(),
        );
        let (h, m) = (self.expert_hits_count(), self.expert_misses_count());
        if h + m > 0 {
            s.push_str(&format!(
                "; experts: {:.0}% hit ({h}/{}), {} resident ({:.2} MB, peak {:.2} MB), {:.3} ms/miss, {} evictions",
                self.expert_hit_rate() * 100.0,
                h + m,
                self.expert_resident_count(),
                self.expert_resident_bytes() as f64 / 1e6,
                self.expert_peak_resident_bytes() as f64 / 1e6,
                self.expert_miss_mean_ms(),
                self.expert_evictions_count(),
            ));
            let (hp, mp) = (self.expert_packed_hits_count(), self.expert_packed_misses_count());
            if hp + mp > 0 {
                s.push_str(&format!(" [packed-resident: {} of {} lookups]", hp + mp, h + m));
            }
        }
        if self.sched_plans_count() > 0 {
            s.push_str(&format!(
                "; sched: {:.2}x dedup ({} picks -> {} fetches), stall {:.1} ms",
                self.sched_dedup_factor(),
                self.sched_routed_picks(),
                self.sched_planned_fetches(),
                self.expert_stall_secs() * 1e3,
            ));
        }
        let (bg, sp) = (self.exec_batched_groups_count(), self.exec_scalar_picks_count());
        if bg + sp > 0 {
            s.push_str(&format!(
                "; moe exec: {bg} batched groups ({} tokens), {sp} scalar picks",
                self.exec_batched_tokens_count(),
            ));
        }
        if self.prefetch_issued_count() > 0 {
            s.push_str(&format!(
                "; prefetch: {} issued, {} hits, {} wasted, {:.1} ms hidden",
                self.prefetch_issued_count(),
                self.prefetch_hits_count(),
                self.prefetch_wasted_count(),
                self.prefetch_hidden_secs() * 1e3,
            ));
        }
        if self.fetch_retries_count() > 0
            || self.expert_drops_count() > 0
            || self.deadline_timeouts_count() > 0
            || self.prefetch_worker_panics_count() > 0
        {
            s.push_str(&format!(
                "; faults: {} retries ({} recovered), {} drops, {} quarantined ({} recovered), {} timeouts, {} worker panics",
                self.fetch_retries_count(),
                self.retry_successes_count(),
                self.expert_drops_count(),
                self.quarantined_count(),
                self.quarantine_recoveries_count(),
                self.deadline_timeouts_count(),
                self.prefetch_worker_panics_count(),
            ));
        }
        if self.requests_submitted_count() > 0 {
            s.push_str("; ");
            s.push_str(&self.admission_identity());
            if self.batch_shrinks_count() > 0 || self.brownouts_count() > 0 {
                s.push_str(&format!(
                    "; backpressure: {} batch shrink(s), {} brownout(s)",
                    self.batch_shrinks_count(),
                    self.brownouts_count(),
                ));
            }
        }
        if self.faults_injected_count() > 0 {
            s.push_str(&format!(
                "; injected: {} transient, {} corrupt, {} delays",
                self.faults_transient_count(),
                self.faults_corrupt_count(),
                self.faults_delay_count(),
            ));
        }
        if self.forward_steps_count() > 0 {
            s.push_str("; ");
            s.push_str(&self.time_accounting());
        }
        s
    }

    /// Snapshot every counter and gauge as a schema-versioned JSON object
    /// — the `METRICS_<run>.json` barometer artifact. Field names match
    /// the struct fields so the snapshot is greppable against the source.
    pub fn to_json(&self) -> Json {
        let n = |v: u64| Json::num(v as f64);
        let nu = |v: usize| Json::num(v as f64);
        Json::obj(vec![
            ("schema_version", Json::num(METRICS_SCHEMA_VERSION as f64)),
            ("decompress_ns", n(self.decompress_ns.load(Ordering::Relaxed))),
            ("decompress_bytes", n(self.decompress_bytes.load(Ordering::Relaxed))),
            ("decompress_count", n(self.decompress_count())),
            ("decode_busy_ns", n(self.decode_busy_ns.load(Ordering::Relaxed))),
            ("decode_threads", nu(self.decode_threads())),
            ("exec_ns", n(self.exec_ns.load(Ordering::Relaxed))),
            ("exec_count", n(self.exec_count.load(Ordering::Relaxed))),
            ("lru_hits", n(self.lru_hits_count())),
            ("constant_bytes", nu(self.constant_bytes())),
            ("peak_transient_bytes", nu(self.transient_peak_bytes())),
            ("lru_resident_bytes", nu(self.lru_resident_bytes.load(Ordering::Relaxed))),
            ("expert_hits", n(self.expert_hits_count())),
            ("expert_misses", n(self.expert_misses_count())),
            ("expert_hits_packed", n(self.expert_packed_hits_count())),
            ("expert_misses_packed", n(self.expert_packed_misses_count())),
            ("expert_evictions", n(self.expert_evictions_count())),
            ("expert_resident_count", nu(self.expert_resident_count())),
            ("expert_decode_ns", n(self.expert_decode_ns.load(Ordering::Relaxed))),
            ("expert_decoded_bytes", n(self.expert_decoded_bytes())),
            ("expert_resident_bytes", nu(self.expert_resident_bytes())),
            ("expert_peak_resident_bytes", nu(self.expert_peak_resident_bytes())),
            ("expert_speculative_bytes", nu(self.expert_speculative_bytes())),
            ("sched_routed_picks", n(self.sched_routed_picks())),
            ("sched_planned_fetches", n(self.sched_planned_fetches())),
            ("sched_plans", n(self.sched_plans_count())),
            ("forward_wall_ns", n(self.forward_wall_ns.load(Ordering::Relaxed))),
            ("forward_steps", n(self.forward_steps_count())),
            ("exec_batched_groups", n(self.exec_batched_groups_count())),
            ("exec_batched_tokens", n(self.exec_batched_tokens_count())),
            ("exec_scalar_picks", n(self.exec_scalar_picks_count())),
            ("prefetch_issued", n(self.prefetch_issued_count())),
            ("prefetch_inserted", n(self.prefetch_inserted_count())),
            ("prefetch_hits", n(self.prefetch_hits_count())),
            ("prefetch_rejected", n(self.prefetch_rejected.load(Ordering::Relaxed))),
            (
                "prefetch_evicted_unused",
                n(self.prefetch_evicted_unused.load(Ordering::Relaxed)),
            ),
            ("prefetch_decode_ns", n(self.prefetch_decode_ns.load(Ordering::Relaxed))),
            ("prefetch_decoded_bytes", n(self.prefetch_decoded_bytes())),
            ("fetch_retries", n(self.fetch_retries_count())),
            ("retry_successes", n(self.retry_successes_count())),
            ("quarantined", n(self.quarantined_count())),
            ("quarantine_recoveries", n(self.quarantine_recoveries_count())),
            ("quarantine_probes", n(self.quarantine_probes_count())),
            ("expert_drops", n(self.expert_drops_count())),
            ("degraded_picks", n(self.degraded_picks_count())),
            ("prefetch_worker_panics", n(self.prefetch_worker_panics_count())),
            ("deadline_timeouts", n(self.deadline_timeouts_count())),
            ("faults_transient", n(self.faults_transient_count())),
            ("faults_corrupt", n(self.faults_corrupt_count())),
            ("faults_delay", n(self.faults_delay_count())),
            ("requests_submitted", n(self.requests_submitted_count())),
            ("requests_admitted", n(self.requests_admitted_count())),
            ("requests_rejected", n(self.requests_rejected_count())),
            ("requests_shed", n(self.requests_shed_count())),
            ("requests_completed", n(self.requests_completed_count())),
            ("requests_aborted", n(self.requests_aborted_count())),
            ("batch_shrinks", n(self.batch_shrinks_count())),
            ("brownouts", n(self.brownouts_count())),
        ])
    }

    pub fn reset_timers(&self) {
        self.decompress_ns.store(0, Ordering::Relaxed);
        self.decompress_bytes.store(0, Ordering::Relaxed);
        self.decompress_count.store(0, Ordering::Relaxed);
        self.decode_busy_ns.store(0, Ordering::Relaxed);
        self.exec_ns.store(0, Ordering::Relaxed);
        self.exec_count.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let m = PipelineMetrics::default();
        m.set_constant_bytes(100);
        m.observe_transient(50);
        m.observe_transient(30); // max semantics
        assert_eq!(m.peak_bytes(), 150);
        m.record_decompress(Duration::from_millis(10), 1_000_000);
        assert!(m.decompress_secs() >= 0.01);
        assert!(m.decompress_mb_s() > 0.0);
        assert_eq!(m.decompress_count(), 1);
        m.reset_timers();
        assert_eq!(m.decompress_count(), 0);
        assert_eq!(m.peak_bytes(), 150, "residency survives timer reset");
    }

    #[test]
    fn expert_accounting() {
        let m = PipelineMetrics::default();
        assert_eq!(m.expert_hit_rate(), 0.0, "no lookups yet");
        m.record_expert_miss(Duration::from_millis(2), 1000, false);
        m.observe_expert_transient(1000);
        m.set_expert_resident(1000);
        m.set_expert_resident_count(1);
        m.expert_hit(false);
        m.expert_hit(false);
        m.expert_hit(false);
        assert_eq!(m.expert_hits_count(), 3);
        assert_eq!(m.expert_misses_count(), 1);
        assert_eq!(m.expert_resident_count(), 1);
        assert_eq!(m.expert_packed_hits_count(), 0, "decoded lookups must not count as packed");
        assert_eq!(m.expert_decoded_bytes(), 1000);
        assert!((m.expert_hit_rate() - 0.75).abs() < 1e-12);
        assert!(m.expert_miss_mean_ms() >= 2.0);
        m.record_expert_eviction();
        m.set_expert_resident(0);
        assert_eq!(m.expert_resident_bytes(), 0);
        assert_eq!(m.expert_peak_resident_bytes(), 1000, "peak survives eviction");
        assert_eq!(m.expert_evictions_count(), 1);
        // expert section shows up in the human summary once active
        assert!(m.summary().contains("experts:"));
        assert!(!m.summary().contains("packed-resident"), "no packed lookups yet");
        // the per-mode split: packed lookups tally both counters
        m.expert_hit(true);
        m.record_expert_miss(Duration::from_millis(1), 500, true);
        assert_eq!(m.expert_hits_count(), 4);
        assert_eq!(m.expert_packed_hits_count(), 1);
        assert_eq!(m.expert_packed_misses_count(), 1);
        assert!(m.summary().contains("packed-resident"));
    }

    #[test]
    fn scheduler_and_prefetch_accounting() {
        let m = PipelineMetrics::default();
        assert_eq!(m.sched_dedup_factor(), 0.0, "no plans yet");
        // 8 routed picks collapsed into 2 fetches, twice
        m.record_sched_plan(8, 2);
        m.record_sched_plan(8, 2);
        assert_eq!(m.sched_routed_picks(), 16);
        assert_eq!(m.sched_planned_fetches(), 4);
        assert!((m.sched_dedup_factor() - 4.0).abs() < 1e-12);
        assert_eq!(m.sched_plans_count(), 2);
        // prefetch: 3 issued, 2 inserted, 1 hit, 1 rejected, 1 aged out
        m.prefetch_issue();
        m.prefetch_issue();
        m.prefetch_issue();
        m.record_prefetch_insert();
        m.record_prefetch_insert();
        m.prefetch_hit();
        m.record_prefetch_rejected();
        m.record_prefetch_evicted_unused();
        m.record_prefetch_decode(Duration::from_millis(3), 1000);
        assert_eq!(m.prefetch_issued_count(), 3);
        assert_eq!(m.prefetch_inserted_count(), 2);
        assert_eq!(m.prefetch_hits_count(), 1);
        assert_eq!(m.prefetch_wasted_count(), 2);
        assert!(m.prefetch_hidden_secs() >= 0.003);
        m.set_expert_speculative(4096);
        assert_eq!(m.expert_speculative_bytes(), 4096);
        // stall is the demand-miss decode time, not the hidden decode time
        m.record_expert_miss(Duration::from_millis(5), 2000, false);
        assert!(m.expert_stall_secs() >= 0.005);
        assert!(m.expert_stall_secs() < 0.008, "prefetch time leaked into stall");
        let s = m.summary();
        assert!(s.contains("sched:"));
        assert!(s.contains("prefetch:"));
    }

    #[test]
    fn batched_exec_accounting() {
        let m = PipelineMetrics::default();
        assert_eq!(m.exec_batched_groups_count(), 0);
        assert!(!m.summary().contains("moe exec:"), "inactive section must stay silent");
        // one step: 3 expert groups serving 8 routed tokens batched,
        // then a scalar step of 8 picks
        m.record_exec_batched(3, 8);
        m.record_exec_scalar(8);
        assert_eq!(m.exec_batched_groups_count(), 3);
        assert_eq!(m.exec_batched_tokens_count(), 8);
        assert_eq!(m.exec_scalar_picks_count(), 8);
        let s = m.summary();
        assert!(s.contains("moe exec: 3 batched groups (8 tokens), 8 scalar picks"), "{s}");
    }

    #[test]
    fn fault_accounting() {
        let m = PipelineMetrics::default();
        assert!(!m.summary().contains("faults:"), "inactive section must stay silent");
        assert!(!m.summary().contains("injected:"));
        m.record_fetch_retry();
        m.record_fetch_retry();
        m.record_retry_success();
        m.record_quarantined();
        m.record_quarantine_probe();
        m.record_quarantine_recovery();
        m.record_expert_drop();
        m.record_degraded_picks(3);
        m.record_prefetch_worker_panic();
        m.record_deadline_timeout();
        assert_eq!(m.fetch_retries_count(), 2);
        assert_eq!(m.retry_successes_count(), 1);
        assert_eq!(m.quarantined_count(), 1);
        assert_eq!(m.quarantine_probes_count(), 1);
        assert_eq!(m.quarantine_recoveries_count(), 1);
        assert_eq!(m.expert_drops_count(), 1);
        assert_eq!(m.degraded_picks_count(), 3);
        assert_eq!(m.prefetch_worker_panics_count(), 1);
        assert_eq!(m.deadline_timeouts_count(), 1);
        assert!(m.summary().contains("faults:"), "{}", m.summary());
        // injection tallies are separate from handling tallies
        m.record_fault_transient();
        m.record_fault_corrupt();
        m.record_fault_delay();
        assert_eq!(m.faults_injected_count(), 3);
        assert!(m.summary().contains("injected: 1 transient, 1 corrupt, 1 delays"));
    }

    #[test]
    fn time_accounting_line_appears_once_forward_steps_exist() {
        let m = PipelineMetrics::default();
        assert!(!m.summary().contains("time:"), "silent before any forward step");
        m.record_expert_miss(Duration::from_millis(3), 1000, false); // stall
        m.record_exec(Duration::from_millis(5)); // exec
        m.record_forward_wall(Duration::from_millis(10)); // wall
        let line = m.time_accounting();
        assert!(line.contains("forward wall 10.0 ms"), "{line}");
        assert!(line.contains("stall 3.0"), "{line}");
        assert!(line.contains("exec 5.0"), "{line}");
        assert!(line.contains("other 2.0"), "{line}");
        assert!(m.summary().contains("time: forward wall"), "{}", m.summary());
    }

    #[test]
    fn metrics_snapshot_serializes_every_counter() {
        let m = PipelineMetrics::default();
        m.record_expert_miss(Duration::from_millis(2), 1000, true);
        m.expert_hit(false);
        m.record_forward_wall(Duration::from_millis(4));
        m.record_exec(Duration::from_millis(1));
        m.prefetch_issue();
        m.record_fetch_retry();
        m.record_fault_transient();
        let j = m.to_json();
        let text = j.to_string();
        let back = Json::parse(&text).expect("snapshot round-trips through text");
        assert_eq!(
            back.get("schema_version").unwrap().as_u32().unwrap(),
            METRICS_SCHEMA_VERSION
        );
        for key in [
            "decompress_ns",
            "exec_ns",
            "expert_hits",
            "expert_misses",
            "expert_misses_packed",
            "expert_peak_resident_bytes",
            "sched_routed_picks",
            "forward_wall_ns",
            "forward_steps",
            "prefetch_issued",
            "fetch_retries",
            "quarantined",
            "deadline_timeouts",
            "faults_transient",
        ] {
            assert!(back.opt(key).is_some(), "snapshot missing {key}");
        }
        assert_eq!(back.get("expert_misses").unwrap().as_usize().unwrap(), 1);
        assert_eq!(back.get("forward_steps").unwrap().as_usize().unwrap(), 1);
        assert_eq!(back.get("faults_transient").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn admission_identity_reconciles_and_flags_violations() {
        let m = PipelineMetrics::default();
        assert!(!m.summary().contains("admission:"), "inactive section must stay silent");
        assert!(m.admission_reconciles(), "all-zero counters reconcile trivially");
        // 6 submitted: 5 admitted + 1 rejected; of the admitted,
        // 2 completed + 1 timeout + 1 shed + 1 aborted, 0 in flight
        for _ in 0..6 {
            m.record_submitted();
        }
        for _ in 0..5 {
            m.record_admitted();
        }
        m.record_rejected();
        m.record_request_completed();
        m.record_request_completed();
        m.record_deadline_timeout();
        m.record_shed();
        m.record_request_aborted();
        assert_eq!(m.requests_in_flight(), 0);
        assert!(m.admission_reconciles());
        let line = m.admission_identity();
        assert!(line.ends_with("[OK]"), "{line}");
        assert!(line.contains("submitted 6 = admitted 5 + rejected 1"), "{line}");
        assert!(m.summary().contains(&line), "identity line missing from summary");
        // an unanswered admitted request shows up as in-flight, still OK
        m.record_submitted();
        m.record_admitted();
        assert_eq!(m.requests_in_flight(), 1);
        assert!(m.admission_identity().ends_with("[OK]"));
        // a lost submit (admitted nor rejected) breaks the first identity
        m.record_submitted();
        assert!(!m.admission_reconciles());
        assert!(m.admission_identity().ends_with("[VIOLATION]"));
        m.record_admitted();
        assert!(m.admission_reconciles(), "identity restored");
        // more outcomes than admissions breaks the second identity
        m.record_request_completed();
        m.record_request_completed();
        m.record_request_completed();
        assert!(!m.admission_reconciles());
        assert!(m.admission_identity().ends_with("[VIOLATION]"));
    }

    #[test]
    fn backpressure_counters_surface_in_summary_and_snapshot() {
        let m = PipelineMetrics::default();
        m.record_submitted();
        m.record_admitted();
        m.record_request_completed();
        assert!(!m.summary().contains("backpressure:"), "silent with no shrink/brownout");
        m.record_batch_shrink();
        m.record_batch_shrink();
        m.record_brownout();
        assert_eq!(m.batch_shrinks_count(), 2);
        assert_eq!(m.brownouts_count(), 1);
        assert!(
            m.summary().contains("backpressure: 2 batch shrink(s), 1 brownout(s)"),
            "{}",
            m.summary()
        );
        let j = m.to_json();
        let back = Json::parse(&j.to_string()).unwrap();
        for key in [
            "requests_submitted",
            "requests_admitted",
            "requests_rejected",
            "requests_shed",
            "requests_completed",
            "requests_aborted",
            "batch_shrinks",
            "brownouts",
        ] {
            assert!(back.opt(key).is_some(), "snapshot missing {key}");
        }
        assert_eq!(back.get("batch_shrinks").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn decode_utilization() {
        let m = PipelineMetrics::default();
        m.set_decode_threads(4);
        assert_eq!(m.decode_threads(), 4);
        assert_eq!(m.decode_utilization(), 0.0, "no samples yet");
        // 10 ms wall, 35 ms of summed worker busy time -> 3.5 cores
        m.record_decode(Duration::from_millis(10), 1_000, 35_000_000);
        let u = m.decode_utilization();
        assert!((u - 3.5).abs() < 0.01, "utilization {u}");
        m.reset_timers();
        assert_eq!(m.decode_utilization(), 0.0, "busy time resets with timers");
    }
}
