//! Pipeline observability: decompression / execution timing and the
//! expanded-weight residency accounting behind the E8 bench.
//!
//! Residency model: `constant` covers what is always held (embedding +
//! head + either the compressed blob or all expanded layers), `transient`
//! is the high-water mark of per-layer expansions live at once (1 for
//! plain streaming, 2 with prefetch, LRU-resident bytes for Lru(n)).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

#[derive(Default)]
pub struct PipelineMetrics {
    decompress_ns: AtomicU64,
    decompress_bytes: AtomicU64,
    decompress_count: AtomicU64,
    exec_ns: AtomicU64,
    exec_count: AtomicU64,
    lru_hits: AtomicU64,
    constant_bytes: AtomicUsize,
    peak_transient_bytes: AtomicUsize,
    lru_resident_bytes: AtomicUsize,
}

impl PipelineMetrics {
    pub fn record_decompress(&self, d: Duration, bytes: usize) {
        self.decompress_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.decompress_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.decompress_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_exec(&self, d: Duration) {
        self.exec_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.exec_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn lru_hit(&self) {
        self.lru_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn set_constant_bytes(&self, b: usize) {
        self.constant_bytes.store(b, Ordering::Relaxed);
    }

    pub fn observe_transient(&self, bytes: usize) {
        self.peak_transient_bytes.fetch_max(bytes, Ordering::Relaxed);
    }

    pub fn update_lru_resident(&self, resident: usize, _evicted: usize) {
        self.lru_resident_bytes.store(resident, Ordering::Relaxed);
        self.peak_transient_bytes.fetch_max(resident, Ordering::Relaxed);
    }

    /// Peak bytes held for weights during serving.
    pub fn peak_bytes(&self) -> usize {
        self.constant_bytes.load(Ordering::Relaxed)
            + self.peak_transient_bytes.load(Ordering::Relaxed)
    }

    /// High-water mark of per-layer expansions only (excludes the constant
    /// part: heads + compressed blob / resident layers).
    pub fn transient_peak_bytes(&self) -> usize {
        self.peak_transient_bytes.load(Ordering::Relaxed)
    }

    pub fn constant_bytes(&self) -> usize {
        self.constant_bytes.load(Ordering::Relaxed)
    }

    pub fn decompress_secs(&self) -> f64 {
        self.decompress_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn exec_secs(&self) -> f64 {
        self.exec_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn decompress_count(&self) -> u64 {
        self.decompress_count.load(Ordering::Relaxed)
    }

    pub fn lru_hits_count(&self) -> u64 {
        self.lru_hits.load(Ordering::Relaxed)
    }

    pub fn decompress_mb_s(&self) -> f64 {
        let secs = self.decompress_secs();
        if secs == 0.0 {
            return 0.0;
        }
        self.decompress_bytes.load(Ordering::Relaxed) as f64 / 1e6 / secs
    }

    pub fn summary(&self) -> String {
        format!(
            "decompress: {} calls, {:.1} ms total ({:.0} MB/s); exec: {} calls, {:.1} ms; peak weights: {:.2} MB; lru hits: {}",
            self.decompress_count(),
            self.decompress_secs() * 1e3,
            self.decompress_mb_s(),
            self.exec_count.load(Ordering::Relaxed),
            self.exec_secs() * 1e3,
            self.peak_bytes() as f64 / 1e6,
            self.lru_hits_count(),
        )
    }

    pub fn reset_timers(&self) {
        self.decompress_ns.store(0, Ordering::Relaxed);
        self.decompress_bytes.store(0, Ordering::Relaxed);
        self.decompress_count.store(0, Ordering::Relaxed);
        self.exec_ns.store(0, Ordering::Relaxed);
        self.exec_count.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let m = PipelineMetrics::default();
        m.set_constant_bytes(100);
        m.observe_transient(50);
        m.observe_transient(30); // max semantics
        assert_eq!(m.peak_bytes(), 150);
        m.record_decompress(Duration::from_millis(10), 1_000_000);
        assert!(m.decompress_secs() >= 0.01);
        assert!(m.decompress_mb_s() > 0.0);
        assert_eq!(m.decompress_count(), 1);
        m.reset_timers();
        assert_eq!(m.decompress_count(), 0);
        assert_eq!(m.peak_bytes(), 150, "residency survives timer reset");
    }
}
