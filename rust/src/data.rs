//! SynthLang asset loader (S12): the rust side never re-implements the
//! generator — it reads what `python/compile/data.py` exported under
//! `artifacts/data/` (token corpora as u16 little-endian streams, eval
//! sets and vocab as JSON). See DESIGN.md's substitution table.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::Json;

/// Special token ids (mirrors data.py; also present in lang.json).
#[derive(Clone, Debug)]
pub struct SpecialTokens {
    pub pad: u32,
    pub bos: u32,
    pub q: u32,
    pub a: u32,
    pub sep: u32,
    pub eos: u32,
}

#[derive(Clone, Debug)]
pub struct LangMeta {
    pub vocab: usize,
    pub n_keys: usize,
    pub seed: u64,
    pub special: SpecialTokens,
    pub key_base: u32,
}

/// One multiple-choice question.
#[derive(Clone, Debug)]
pub struct Question {
    pub prompt: Vec<u32>,
    pub options: Vec<Vec<u32>>,
    pub answer: usize,
}

/// A full eval set (one of synth-mmlu / synth-arc-*).
#[derive(Clone, Debug)]
pub struct EvalSet {
    pub family: String,
    pub n_shots: usize,
    pub vocab: usize,
    pub questions: Vec<Question>,
}

pub struct DataDir {
    pub root: PathBuf,
    pub lang: LangMeta,
    pub vocab_names: Vec<String>,
}

impl DataDir {
    /// Open the data directory matching a model's vocab size.
    pub fn open_for_vocab(artifacts_root: impl AsRef<Path>, vocab: usize) -> Result<Self> {
        let base = artifacts_root.as_ref().join("data");
        let sub = base.join(format!("vocab{vocab}"));
        let root = if sub.join("lang.json").exists() { sub } else { base };
        Self::open(root)
    }

    pub fn open(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        let j = Json::parse(
            &std::fs::read_to_string(root.join("lang.json"))
                .with_context(|| format!("reading {root:?}/lang.json (run `make artifacts`?)"))?,
        )?;
        let sp = j.get("special")?;
        let lang = LangMeta {
            vocab: j.get("vocab")?.as_usize()?,
            n_keys: j.get("n_keys")?.as_usize()?,
            seed: j.get("seed")?.as_usize()? as u64,
            special: SpecialTokens {
                pad: sp.get("pad")?.as_u32()?,
                bos: sp.get("bos")?.as_u32()?,
                q: sp.get("q")?.as_u32()?,
                a: sp.get("a")?.as_u32()?,
                sep: sp.get("sep")?.as_u32()?,
                eos: sp.get("eos")?.as_u32()?,
            },
            key_base: j.get("key_base")?.as_u32()?,
        };
        let vocab_names =
            Json::parse(&std::fs::read_to_string(root.join("vocab.json"))?)?.str_arr()?;
        Ok(Self { root, lang, vocab_names })
    }

    /// Load a u16-LE token stream (calib.bin / sample.bin).
    pub fn tokens(&self, file: &str) -> Result<Vec<u32>> {
        let bytes = std::fs::read(self.root.join(file))?;
        anyhow::ensure!(bytes.len() % 2 == 0, "odd token file length");
        Ok(bytes
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]) as u32)
            .collect())
    }

    pub fn calibration_tokens(&self) -> Result<Vec<u32>> {
        self.tokens("calib.bin")
    }

    pub fn eval_set(&self, family: &str) -> Result<EvalSet> {
        let path = self.root.join(format!("eval_{family}.json"));
        let j = Json::parse(
            &std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?,
        )?;
        let mut questions = Vec::new();
        for q in j.get("questions")?.as_arr()? {
            let mut options = Vec::new();
            for o in q.get("options")?.as_arr()? {
                options.push(o.u32_arr()?);
            }
            questions.push(Question {
                prompt: q.get("prompt")?.u32_arr()?,
                options,
                answer: q.get("answer")?.as_usize()?,
            });
        }
        Ok(EvalSet {
            family: j.get("family")?.as_str()?.to_string(),
            n_shots: j.get("n_shots")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            questions,
        })
    }

    /// Human-readable detokenization for demos/logging.
    pub fn detok(&self, tokens: &[u32]) -> String {
        tokens
            .iter()
            .map(|&t| {
                self.vocab_names
                    .get(t as usize)
                    .map(|s| s.as_str())
                    .unwrap_or("<?>")
                    .to_string()
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

pub const EVAL_FAMILIES: [&str; 3] = ["mmlu", "arc-challenge", "arc-easy"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_artifacts_root;

    fn open() -> Option<DataDir> {
        let root = default_artifacts_root();
        DataDir::open_for_vocab(&root, 512).ok()
    }

    #[test]
    fn loads_lang_meta() {
        let Some(d) = open() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(d.lang.vocab, 512);
        assert!(d.lang.n_keys > 0);
        assert_eq!(d.vocab_names.len(), 512);
        assert_eq!(d.vocab_names[d.lang.special.q as usize], "Q");
    }

    #[test]
    fn loads_eval_sets() {
        let Some(d) = open() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for fam in EVAL_FAMILIES {
            let es = d.eval_set(fam).unwrap();
            assert_eq!(es.questions.len(), 200, "{fam}");
            for q in &es.questions {
                assert_eq!(q.options.len(), 4);
                assert!(q.answer < 4);
                assert!(q.prompt.iter().all(|&t| (t as usize) < d.lang.vocab));
            }
        }
    }

    #[test]
    fn loads_calibration_tokens() {
        let Some(d) = open() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let toks = d.calibration_tokens().unwrap();
        assert_eq!(toks.len(), 1 << 16);
        assert!(toks.iter().all(|&t| (t as usize) < d.lang.vocab));
    }

    #[test]
    fn detok_roundtrip_sane() {
        let Some(d) = open() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let s = d.detok(&[d.lang.special.q, d.lang.key_base + 3, d.lang.special.a]);
        assert_eq!(s, "Q k3 A");
    }
}
