//! Per-option log-likelihood scoring (the paper's §5 "the model computes
//! the log likelihood for each answer option").

use anyhow::Result;

use crate::data::Question;
use crate::tensor::Tensor;

pub type LogitsFn<'a> = dyn FnMut(&[u32]) -> Result<Tensor> + 'a;

#[derive(Clone, Debug)]
pub struct ScoredQuestion {
    pub scores: Vec<f64>,
    pub best: usize,
}

/// log softmax over one row of logits, returning logprob of `target`.
fn logprob(row: &[f32], target: u32) -> f64 {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum::<f64>().ln() + m;
    row[target as usize] as f64 - lse
}

/// Score every option of a question: run the model over prompt+option and
/// sum the logprobs of the option tokens (teacher-forced continuation).
pub fn score_question(
    q: &Question,
    logits_fn: &mut impl FnMut(&[u32]) -> Result<Tensor>,
) -> Result<ScoredQuestion> {
    let mut scores = Vec::with_capacity(q.options.len());
    for opt in &q.options {
        let mut tokens = q.prompt.clone();
        tokens.extend_from_slice(opt);
        let logits = logits_fn(&tokens)?;
        let (t, v) = (logits.shape[0], logits.shape[1]);
        anyhow::ensure!(t == tokens.len(), "logits rows {t} != tokens {}", tokens.len());
        // option token j sits at position prompt_len + j and is predicted
        // by the logits at position prompt_len + j - 1
        let p0 = q.prompt.len();
        let mut s = 0.0f64;
        for (j, &tok) in opt.iter().enumerate() {
            let row = &logits.data[(p0 + j - 1) * v..(p0 + j) * v];
            anyhow::ensure!((tok as usize) < v, "option token {tok} out of vocab {v}");
            s += logprob(row, tok);
        }
        scores.push(s);
    }
    let best = scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    Ok(ScoredQuestion { scores, best })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logprob_normalizes() {
        let row = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| logprob(&row, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(logprob(&row, 2) > logprob(&row, 0));
    }

    #[test]
    fn logprob_stable_for_large_logits() {
        let row = vec![1000.0f32, 999.0, 0.0];
        let lp = logprob(&row, 0);
        assert!(lp.is_finite() && lp < 0.0);
    }

    #[test]
    fn picks_higher_likelihood_option() {
        let q = Question {
            prompt: vec![5, 6],
            options: vec![vec![7], vec![9]],
            answer: 0,
        };
        let mut f = |tokens: &[u32]| {
            let v = 16;
            let mut data = vec![0.0f32; tokens.len() * v];
            // position 1 (predicting position 2) favours token 7
            data[v + 7] = 5.0;
            Tensor::new(vec![tokens.len(), v], data)
        };
        let s = score_question(&q, &mut f).unwrap();
        assert_eq!(s.best, 0);
        assert!(s.scores[0] > s.scores[1]);
    }

    #[test]
    fn multi_token_options_sum() {
        let q = Question {
            prompt: vec![1],
            options: vec![vec![2, 3], vec![2, 9]],
            answer: 0,
        };
        let mut f = |tokens: &[u32]| {
            let v = 16;
            let mut data = vec![0.0f32; tokens.len() * v];
            for i in 0..tokens.len() {
                data[i * v + 2] = 2.0; // always likes token 2
                data[i * v + 3] = 1.0; // mildly likes 3, never 9
            }
            Tensor::new(vec![tokens.len(), v], data)
        };
        let s = score_question(&q, &mut f).unwrap();
        assert_eq!(s.best, 0);
    }
}
