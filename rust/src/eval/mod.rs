//! Evaluation harness (S13): the paper's §5 pipeline — multiple-choice
//! scoring by per-option log-likelihood, accuracy + per-question latency.
//!
//! Identical mechanics to a real MMLU/ARC harness: build the prompt,
//! tokenize (SynthLang is already tokens), run the model over
//! prompt+option, sum the log-probabilities of the option tokens, pick the
//! argmax option, record wall-clock per question.

pub mod report;
pub mod scorer;

use std::time::Instant;

use anyhow::Result;

use crate::data::{EvalSet, Question};

pub use scorer::{LogitsFn, ScoredQuestion};

/// Result of one benchmark run.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub family: String,
    pub variant: String,
    pub n_questions: usize,
    pub n_correct: usize,
    pub mean_latency_s: f64,
    pub p95_latency_s: f64,
    pub total_s: f64,
}

impl EvalReport {
    pub fn accuracy(&self) -> f64 {
        self.n_correct as f64 / self.n_questions.max(1) as f64
    }
}

/// Run an eval set through a logits function (fp32 reference or the
/// quantized/compressed pipeline). `limit` bounds question count.
pub fn run_eval(
    es: &EvalSet,
    variant: &str,
    limit: usize,
    mut logits_fn: impl FnMut(&[u32]) -> Result<crate::tensor::Tensor>,
) -> Result<EvalReport> {
    let n = es.questions.len().min(limit);
    let mut correct = 0;
    let mut lats = Vec::with_capacity(n);
    let t_start = Instant::now();
    for q in &es.questions[..n] {
        let t0 = Instant::now();
        let pick = scorer::score_question(q, &mut logits_fn)?;
        lats.push(t0.elapsed().as_secs_f64());
        if pick.best == q.answer {
            correct += 1;
        }
    }
    crate::util::stats::sort_samples(&mut lats);
    Ok(EvalReport {
        family: es.family.clone(),
        variant: variant.to_string(),
        n_questions: n,
        n_correct: correct,
        mean_latency_s: lats.iter().sum::<f64>() / n.max(1) as f64,
        p95_latency_s: crate::util::stats::percentile(&lats, 95),
        total_s: t_start.elapsed().as_secs_f64(),
    })
}

/// Sanity baseline: the expected accuracy of random guessing.
pub fn chance_accuracy(es: &EvalSet) -> f64 {
    let opts: usize = es.questions.first().map(|q| q.options.len()).unwrap_or(4);
    1.0 / opts as f64
}

/// Quick structural validation of an eval set (used by `tqm eval --check`).
pub fn validate(es: &EvalSet) -> Result<()> {
    anyhow::ensure!(!es.questions.is_empty(), "empty eval set");
    for (i, q) in es.questions.iter().enumerate() {
        anyhow::ensure!(q.options.len() >= 2, "question {i}: < 2 options");
        anyhow::ensure!(q.answer < q.options.len(), "question {i}: answer out of range");
        anyhow::ensure!(!q.prompt.is_empty(), "question {i}: empty prompt");
        for o in &q.options {
            anyhow::ensure!(!o.is_empty(), "question {i}: empty option");
        }
    }
    Ok(())
}

/// A trivially-scorable fixture for harness unit tests.
#[cfg(test)]
pub(crate) fn fixture_eval_set() -> EvalSet {
    // model = "always predicts token t+1 follows t"; correct options
    // continue the arithmetic run, distractors break it.
    let questions = (0..20)
        .map(|i| {
            let start = 10 + (i % 5) as u32;
            Question {
                prompt: vec![start, start + 1, start + 2],
                options: vec![
                    vec![start + 3, start + 4],
                    vec![start + 7, start + 1],
                    vec![start, start],
                    vec![99, 98],
                ],
                answer: 0,
            }
        })
        .collect();
    EvalSet { family: "fixture".into(), n_shots: 0, vocab: 128, questions }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    /// Logits for the "successor function" language: P(next = last + 1) high.
    fn successor_logits(tokens: &[u32]) -> Result<Tensor> {
        let v = 128;
        let t = tokens.len();
        let mut data = vec![0.0f32; t * v];
        for (i, &tok) in tokens.iter().enumerate() {
            let next = ((tok + 1) as usize) % v;
            data[i * v + next] = 10.0;
        }
        Tensor::new(vec![t, v], data)
    }

    #[test]
    fn perfect_model_scores_100() {
        let es = fixture_eval_set();
        validate(&es).unwrap();
        let rep = run_eval(&es, "unit", 100, successor_logits).unwrap();
        assert_eq!(rep.n_questions, 20);
        assert_eq!(rep.accuracy(), 1.0);
        assert!(rep.mean_latency_s >= 0.0);
    }

    #[test]
    fn uniform_model_scores_near_chance() {
        let es = fixture_eval_set();
        let rep = run_eval(&es, "unit", 100, |tokens| {
            Tensor::new(vec![tokens.len(), 128], vec![0.0; tokens.len() * 128])
        })
        .unwrap();
        // with uniform logits every option ties; argmax picks first scored,
        // which is option order dependent — accuracy should be low-ish but
        // deterministic. Just check it runs and reports.
        assert_eq!(rep.n_questions, 20);
        assert!(rep.accuracy() <= 1.0);
    }

    #[test]
    fn limit_respected() {
        let es = fixture_eval_set();
        let rep = run_eval(&es, "unit", 5, successor_logits).unwrap();
        assert_eq!(rep.n_questions, 5);
    }

    #[test]
    fn chance_is_quarter() {
        let es = fixture_eval_set();
        assert_eq!(chance_accuracy(&es), 0.25);
    }

    #[test]
    fn validate_catches_bad_sets() {
        let mut es = fixture_eval_set();
        es.questions[0].answer = 9;
        assert!(validate(&es).is_err());
    }
}
