//! Eval report persistence: JSON artifacts so table regeneration is
//! scriptable and diffs across runs are reviewable (`tqm eval` and the
//! bench binaries write these under `artifacts/reports/` when
//! `TQM_REPORT_DIR` is set).

use std::path::{Path, PathBuf};

use anyhow::Result;

use super::EvalReport;
use crate::util::Json;

pub fn report_to_json(r: &EvalReport) -> Json {
    Json::obj(vec![
        ("family", Json::str(r.family.clone())),
        ("variant", Json::str(r.variant.clone())),
        ("n_questions", Json::num(r.n_questions as f64)),
        ("n_correct", Json::num(r.n_correct as f64)),
        ("accuracy", Json::num(r.accuracy())),
        ("mean_latency_s", Json::num(r.mean_latency_s)),
        ("p95_latency_s", Json::num(r.p95_latency_s)),
        ("total_s", Json::num(r.total_s)),
    ])
}

pub fn report_from_json(j: &Json) -> Result<EvalReport> {
    Ok(EvalReport {
        family: j.get("family")?.as_str()?.to_string(),
        variant: j.get("variant")?.as_str()?.to_string(),
        n_questions: j.get("n_questions")?.as_usize()?,
        n_correct: j.get("n_correct")?.as_usize()?,
        mean_latency_s: j.get("mean_latency_s")?.as_f64()?,
        p95_latency_s: j.get("p95_latency_s")?.as_f64()?,
        total_s: j.get("total_s")?.as_f64()?,
    })
}

/// Write a batch of reports as one JSON file; returns the path.
pub fn save(dir: impl AsRef<Path>, name: &str, reports: &[EvalReport]) -> Result<PathBuf> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    let arr = Json::Arr(reports.iter().map(report_to_json).collect());
    std::fs::write(&path, arr.to_string())?;
    Ok(path)
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<EvalReport>> {
    let j = Json::parse(&std::fs::read_to_string(path)?)?;
    j.as_arr()?.iter().map(report_from_json).collect()
}

/// Directory for report artifacts if the user asked for them.
pub fn report_dir() -> Option<PathBuf> {
    // PathBuf parsing is infallible, so this can only be Some/None
    crate::util::env_parse_opt("TQM_REPORT_DIR").expect("PathBuf parse is infallible")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> EvalReport {
        EvalReport {
            family: "arc-easy".into(),
            variant: "compressed".into(),
            n_questions: 60,
            n_correct: 54,
            mean_latency_s: 0.08,
            p95_latency_s: 0.12,
            total_s: 5.0,
        }
    }

    #[test]
    fn json_roundtrip() {
        let r = sample();
        let j = report_to_json(&r);
        let back = report_from_json(&j).unwrap();
        assert_eq!(back.family, r.family);
        assert_eq!(back.n_correct, 54);
        assert!((back.accuracy() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::TempDir::new().unwrap();
        let reports = vec![sample(), sample()];
        let p = save(dir.path(), "t4", &reports).unwrap();
        let got = load(&p).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].variant, "compressed");
    }
}
