//! Config system: model geometry (mirrors `python/compile/config.py` via
//! the AOT manifest — rust never hardcodes dims) plus serving / quantize /
//! compress options assembled from CLI flags and JSON config files.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::quant::Bits;
use crate::util::Json;

/// Mixture-of-Experts geometry: the FFN sublayer is `n_experts` SwiGLU
/// experts behind a learned top-`top_k` router instead of one dense FFN.
/// `None` in [`ModelConfig::moe`] selects the classic dense path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MoeSpec {
    pub n_experts: usize,
    /// Experts activated per token (renormalized softmax gating).
    pub top_k: usize,
    /// Hidden width of each expert (the dense-equivalent FFN width is
    /// `n_experts * d_expert`).
    pub d_expert: usize,
}

impl MoeSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let s = Self {
            n_experts: j.get("n_experts")?.as_usize()?,
            top_k: j.get("top_k")?.as_usize()?,
            d_expert: j.get("d_expert")?.as_usize()?,
        };
        anyhow::ensure!(
            s.n_experts > 0 && s.d_expert > 0 && (1..=s.n_experts).contains(&s.top_k),
            "bad moe spec {s:?} (need n_experts > 0, d_expert > 0, 1 <= top_k <= n_experts)"
        );
        Ok(s)
    }
}

/// Model geometry parsed from `artifacts/<name>/manifest.json::config`.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    pub head_dim: usize,
    pub kv_dim: usize,
    pub n_params: usize,
    pub prefill_t: Vec<usize>,
    pub prefill_b: Vec<usize>,
    pub decode_b: Vec<usize>,
    /// MoE FFN geometry; `None` = dense FFN (`d_ff`). Optional in the
    /// manifest, so dense configs parse unchanged.
    pub moe: Option<MoeSpec>,
}

impl ModelConfig {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            moe: match j.opt("moe") {
                Some(m) => Some(MoeSpec::from_json(m)?),
                None => None,
            },
            name: j.get("name")?.as_str()?.to_string(),
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            n_kv_heads: j.get("n_kv_heads")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
            rope_theta: j.get("rope_theta")?.as_f64()?,
            norm_eps: j.get("norm_eps")?.as_f64()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            kv_dim: j.get("kv_dim")?.as_usize()?,
            n_params: j.get("n_params")?.as_usize()?,
            prefill_t: j.get("prefill_t")?.usize_arr()?,
            prefill_b: j.get("prefill_b")?.usize_arr()?,
            decode_b: j.get("decode_b")?.usize_arr()?,
        })
    }
}

/// One lowered stage geometry from the manifest.
#[derive(Clone, Debug)]
pub struct StageEntry {
    pub stage: String,
    pub file: String,
    pub b: usize,
    pub t: usize,
    pub s: usize,
    pub n_outputs: usize,
}

/// Full AOT manifest for one model config.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: u32,
    pub config: ModelConfig,
    pub stages: Vec<StageEntry>,
    pub weights_file: String,
}

impl Manifest {
    pub fn load(artifacts_root: impl AsRef<Path>, model: &str) -> Result<Self> {
        let path = artifacts_root.as_ref().join(model).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let version = j.get("version")?.as_u32()?;
        anyhow::ensure!(
            version == crate::FORMAT_VERSION,
            "manifest version {} != {}",
            version,
            crate::FORMAT_VERSION
        );
        let mut stages = Vec::new();
        for s in j.get("stages")?.as_arr()? {
            stages.push(StageEntry {
                stage: s.get("stage")?.as_str()?.to_string(),
                file: s.get("file")?.as_str()?.to_string(),
                b: s.get("b")?.as_usize()?,
                t: s.get("t")?.as_usize()?,
                s: s.get("s")?.as_usize()?,
                n_outputs: s.get("n_outputs")?.as_usize()?,
            });
        }
        Ok(Manifest {
            version,
            config: ModelConfig::from_json(j.get("config")?)?,
            stages,
            weights_file: j.get("weights_file")?.as_str()?.to_string(),
        })
    }

    pub fn model_dir(&self, artifacts_root: impl AsRef<Path>) -> PathBuf {
        artifacts_root.as_ref().join(&self.config.name)
    }

    /// Smallest prefill bucket that fits `t` tokens at batch `b`.
    pub fn prefill_bucket(&self, b: usize, t: usize) -> Option<&StageEntry> {
        self.stages
            .iter()
            .filter(|s| s.stage == "block" && s.b == b && s.t >= t && s.t > 1)
            .min_by_key(|s| s.t)
    }

    pub fn stage(&self, stage: &str, b: usize, t: usize) -> Option<&StageEntry> {
        self.stages.iter().find(|s| s.stage == stage && s.b == b && s.t == t)
    }
}

/// How to quantize a checkpoint (paper §3).
#[derive(Clone, Debug)]
pub struct QuantizeOptions {
    pub bits: Bits,
    pub per_channel: bool,
    /// Use GPTQ with calibration data instead of the naive quantizer.
    pub gptq: bool,
    /// GPTQ damping (fraction of mean Hessian diagonal).
    pub percdamp: f64,
    /// Calibration token budget for GPTQ.
    pub calib_tokens: usize,
}

impl Default for QuantizeOptions {
    fn default() -> Self {
        Self { bits: Bits::B8, per_channel: false, gptq: false, percdamp: 0.01, calib_tokens: 8192 }
    }
}

/// Weight residency policy for the serving pipeline (E8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// Decompress everything up front; keep expanded weights resident
    /// (the paper's "Quantized" baseline).
    AlwaysResident,
    /// Decompress each layer just-in-time and drop it after use
    /// (the paper's per-layer streaming).
    StreamPerLayer,
    /// Keep up to N expanded layers in an LRU cache.
    Lru(usize),
}

impl Residency {
    pub fn parse(s: &str) -> Result<Self> {
        if s == "resident" {
            return Ok(Residency::AlwaysResident);
        }
        if s == "stream" {
            return Ok(Residency::StreamPerLayer);
        }
        if let Some(n) = s.strip_prefix("lru:") {
            return Ok(Residency::Lru(n.parse()?));
        }
        anyhow::bail!("bad residency {s:?} (resident|stream|lru:N)")
    }

    pub fn label(&self) -> String {
        match self {
            Residency::AlwaysResident => "resident".into(),
            Residency::StreamPerLayer => "stream".into(),
            Residency::Lru(n) => format!("lru:{n}"),
        }
    }
}

/// How the MoE expert cache holds a resident expert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpertResidency {
    /// Dequantized f32 arenas — fastest per-token math, largest
    /// footprint (4 bytes/weight regardless of quantization width).
    Decoded,
    /// The container's bit-packed codes + quant params, computed against
    /// directly by the fused qGEMV kernels: ~`32/bits`× more experts
    /// resident per byte of budget, and a miss skips the
    /// unpack→dequantize pass entirely. Bit-exact vs `Decoded`.
    Packed,
}

impl ExpertResidency {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "decoded" => Ok(ExpertResidency::Decoded),
            "packed" => Ok(ExpertResidency::Packed),
            _ => anyhow::bail!("bad expert residency {s:?} (decoded|packed)"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ExpertResidency::Decoded => "decoded",
            ExpertResidency::Packed => "packed",
        }
    }
}

/// Serving configuration (coordinator).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub residency: Residency,
    /// Decode→execute pipeline depth for `StreamPerLayer`: how many
    /// layers ahead the prefetch worker may run while the current layer
    /// executes. 0 disables prefetch (decode inline); 1 reproduces the
    /// classic depth-1 overlap; deeper pipelines absorb decode-time
    /// jitter at the cost of one extra expanded layer of memory each.
    pub prefetch_depth: usize,
    /// Worker threads for the chunk-parallel layer decode (a v2 TQM
    /// container frames payloads in independently-decodable chunks).
    /// 0 = one per available core; 1 = fully serial decode.
    pub n_threads: usize,
    /// Dynamic batcher: max batch size (must match a lowered decode_b).
    pub max_batch: usize,
    /// Dynamic batcher: max queue wait before dispatching a partial batch.
    pub max_wait_ms: u64,
    /// Max generated tokens per request.
    pub max_new_tokens: usize,
    /// Byte budget of the decoded-expert LRU cache (MoE serving): router
    /// hits return a cached expert without touching the decoder; misses
    /// decode on demand and evict least-recently-used experts until the
    /// budget holds. Must be at least one expert's decoded bytes for the
    /// cache to retain anything (smaller budgets degrade to pure
    /// streaming). Irrelevant for dense models.
    pub expert_budget_bytes: usize,
    /// What a resident expert *is*: decoded f32 arenas, or the
    /// container's bit-packed codes served through the qGEMV kernels.
    /// Packed residency multiplies the experts per byte of
    /// `expert_budget_bytes` by ~`32/bits` and removes the dequantize
    /// pass from the miss path, at a per-token matmul cost; outputs are
    /// bit-identical either way.
    pub expert_residency: ExpertResidency,
    /// Byte budget of the expert scheduler's *speculative* slice: how
    /// many decoded bytes the prefetch workers may hold in the cache
    /// ahead of a demand. Kept separate from `expert_budget_bytes` so a
    /// prefetch can never evict what the current step needs; total
    /// decoded residency is bounded by the sum of the two. `0` disables
    /// prefetch entirely.
    pub prefetch_budget_bytes: usize,
    /// Background prefetch decode workers (scheduler worker pool).
    pub prefetch_workers: usize,
    /// Decay of the scheduler's EWMA expert-popularity prior (closer to
    /// 1.0 = longer memory of which experts a workload keeps routing to).
    pub prefetch_ewma_decay: f64,
    /// Execute each (layer, expert)'s deduped token group as ONE batched
    /// qGEMM call (one traversal of the expert's packed streams per
    /// step) instead of one qGEMV per routed token. Exact accumulation
    /// mode — outputs are bit-identical to the scalar path either way;
    /// the knob exists for apples-to-apples measurement and as an
    /// escape hatch. Irrelevant for dense models.
    pub batched_qgemm: bool,
    /// Retries after a failed expert fetch/decode (transient IO faults)
    /// before the failure counts against the expert. 0 = fail fast.
    pub retry_budget: u32,
    /// Base backoff between expert-fetch retries; doubles per attempt
    /// (bounded exponential backoff).
    pub retry_backoff_ms: u64,
    /// Consecutive decode/CRC failures before an expert is quarantined
    /// (dropped from routing, gates renormalized over survivors).
    /// 0 disables quarantine — every failure is terminal for its request.
    pub quarantine_after: u32,
    /// Re-probe a quarantined expert every N serving steps (recovery
    /// path for transiently-bad media). 0 = never re-probe.
    pub quarantine_probe_every: u64,
    /// Per-request deadline in milliseconds, measured from submission:
    /// a request still unfinished past its deadline is answered with a
    /// structured `MoeError::Timeout` instead of more decode work.
    /// 0 disables deadlines.
    pub deadline_ms: u64,
    /// Bounded admission queue: max requests queued or in flight at the
    /// host at once. A submit past the bound is rejected immediately with
    /// a structured `MoeError::Overloaded { retry_after_ms }` instead of
    /// buffering without limit. 0 = unbounded (the pre-admission-control
    /// behavior).
    pub admission_queue: usize,
    /// Per-tenant cap on requests queued or in flight at once; a tenant
    /// at its quota is rejected with `Overloaded` even when the global
    /// queue has room. 0 = no per-tenant quota.
    pub tenant_quota: usize,
    /// Weighted fair admission shares, indexed by tenant id (tenants past
    /// the end of the vec get weight 1; an empty vec = everyone weight
    /// 1). Under contention — queue more than half full — each tenant is
    /// held to its weight's share of the queue, but never below one slot,
    /// so a tenant with any quota always gets nonzero goodput.
    pub tenant_weights: Vec<u32>,
    /// Deadline-aware shedding: before a request's first forward step,
    /// predict its completion time from the live per-step EWMA and answer
    /// `MoeError::Shed` immediately when it cannot finish inside its
    /// deadline anyway — shed-before-work, counted separately from
    /// timeouts. Off by default (and irrelevant without `deadline_ms`):
    /// with it off the serving path is bit-exact with the pre-overload
    /// host.
    pub shed_predictive: bool,
    /// Cache-backpressure trigger: when the demand-miss stall fraction of
    /// a step's wall time exceeds this, the admitted batch is halved
    /// (AIMD; recovers one slot per healthy step). 0.0 disables.
    pub shrink_stall_frac: f64,
    /// Cache-backpressure trigger on eviction churn: evictions observed
    /// during a single step above this count also shrink the admitted
    /// batch. 0 disables.
    pub shrink_evictions_per_step: u64,
    /// Brown-out: under sustained cache backpressure, switch the expert
    /// cache to packed residency (~`32/bits`x more experts per byte,
    /// bit-exact outputs) instead of letting every request's p99 explode.
    /// One-way per host run. Off by default.
    pub brownout_packed: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            residency: Residency::StreamPerLayer,
            prefetch_depth: 1,
            n_threads: 0,
            max_batch: 4,
            max_wait_ms: 2,
            max_new_tokens: 32,
            expert_budget_bytes: 64 << 20,
            expert_residency: ExpertResidency::Decoded,
            prefetch_budget_bytes: 16 << 20,
            prefetch_workers: 1,
            prefetch_ewma_decay: 0.8,
            batched_qgemm: true,
            retry_budget: 2,
            retry_backoff_ms: 1,
            quarantine_after: 3,
            quarantine_probe_every: 64,
            deadline_ms: 0,
            admission_queue: 1024,
            tenant_quota: 0,
            tenant_weights: Vec::new(),
            shed_predictive: false,
            shrink_stall_frac: 0.0,
            shrink_evictions_per_step: 0,
            brownout_packed: false,
        }
    }
}

impl ServeOptions {
    /// Fair-admission weight of `tenant` (tenants beyond the configured
    /// vec, and zero-configured weights, count as 1 — a weight of 0 would
    /// silently starve a tenant, which the fairness guarantee forbids).
    pub fn tenant_weight(&self, tenant: u32) -> u32 {
        self.tenant_weights.get(tenant as usize).copied().unwrap_or(1).max(1)
    }
}

impl ServeOptions {
    /// Resolve the decode thread count (0 = auto-detect cores).
    pub fn resolved_threads(&self) -> usize {
        if self.n_threads == 0 {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
        } else {
            self.n_threads
        }
    }
}

/// Where build artifacts live; resolves the repo-root default.
pub fn default_artifacts_root() -> PathBuf {
    // PathBuf parsing is infallible, so this can only be Some/None
    if let Some(p) =
        crate::util::env_parse_opt::<PathBuf>("TQM_ARTIFACTS").expect("PathBuf parse is infallible")
    {
        return p;
    }
    // walk up from cwd looking for artifacts/
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residency_parse() {
        assert_eq!(Residency::parse("resident").unwrap(), Residency::AlwaysResident);
        assert_eq!(Residency::parse("stream").unwrap(), Residency::StreamPerLayer);
        assert_eq!(Residency::parse("lru:3").unwrap(), Residency::Lru(3));
        assert!(Residency::parse("bogus").is_err());
        assert_eq!(Residency::Lru(2).label(), "lru:2");
    }

    #[test]
    fn expert_residency_parse() {
        assert_eq!(ExpertResidency::parse("decoded").unwrap(), ExpertResidency::Decoded);
        assert_eq!(ExpertResidency::parse("packed").unwrap(), ExpertResidency::Packed);
        assert!(ExpertResidency::parse("fp32").is_err());
        assert_eq!(ExpertResidency::Packed.label(), "packed");
        assert_eq!(ServeOptions::default().expert_residency, ExpertResidency::Decoded);
    }

    #[test]
    fn moe_spec_parse_and_validation() {
        let j = crate::util::Json::parse(
            r#"{"n_experts": 8, "top_k": 2, "d_expert": 64}"#,
        )
        .unwrap();
        let s = MoeSpec::from_json(&j).unwrap();
        assert_eq!(s, MoeSpec { n_experts: 8, top_k: 2, d_expert: 64 });
        // top_k must not exceed n_experts
        let bad = crate::util::Json::parse(
            r#"{"n_experts": 2, "top_k": 3, "d_expert": 64}"#,
        )
        .unwrap();
        assert!(MoeSpec::from_json(&bad).is_err());
        let zero = crate::util::Json::parse(
            r#"{"n_experts": 0, "top_k": 0, "d_expert": 64}"#,
        )
        .unwrap();
        assert!(MoeSpec::from_json(&zero).is_err());
    }

    #[test]
    fn overload_knob_defaults_preserve_the_pre_admission_serving_path() {
        let s = ServeOptions::default();
        // bounded queue is on by default, everything that could change
        // outputs (shedding, shrink, brownout) is off
        assert!(s.admission_queue > 0);
        assert_eq!(s.tenant_quota, 0);
        assert!(!s.shed_predictive);
        assert_eq!(s.shrink_stall_frac, 0.0);
        assert_eq!(s.shrink_evictions_per_step, 0);
        assert!(!s.brownout_packed);
        // weight lookup: empty vec = everyone 1; configured weights hold;
        // out-of-range and zero weights clamp to 1 (no silent starvation)
        assert_eq!(s.tenant_weight(0), 1);
        assert_eq!(s.tenant_weight(17), 1);
        let w = ServeOptions { tenant_weights: vec![4, 0, 2], ..Default::default() };
        assert_eq!(w.tenant_weight(0), 4);
        assert_eq!(w.tenant_weight(1), 1, "zero weight must clamp to 1");
        assert_eq!(w.tenant_weight(2), 2);
        assert_eq!(w.tenant_weight(3), 1, "past-the-end tenants get weight 1");
    }

    #[test]
    fn manifest_parses_real_artifact() {
        let root = default_artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&root, "tiny").unwrap();
        assert_eq!(m.config.name, "tiny");
        assert_eq!(m.config.d_model, 64);
        assert!(m.stage("block", 1, 1).is_some());
        let bucket = m.prefill_bucket(1, 10).unwrap();
        assert!(bucket.t >= 10);
    }
}
