//! Flight-recorder tracing: lock-light per-thread span ring buffers with
//! a monotonic clock, drained to schema-versioned Chrome trace-event JSON
//! (`TRACE_<run>.json`, loadable in Perfetto / `chrome://tracing`) when
//! `TQM_TRACE_DIR` is set.
//!
//! Design constraints, in order:
//!
//! 1. **Zero overhead when off.** Every recording entry point starts with
//!    one relaxed atomic load ([`enabled`]); when it is false no clock is
//!    read, no allocation happens, and no lock is touched, so the serving
//!    path stays bit-exact and effectively untouched.
//! 2. **Panic-safe by construction.** Spans are recorded as *complete*
//!    events at guard [`Drop`] time — there is no open `begin` record that
//!    a `catch_unwind` boundary (prefetch workers, demand decode) could
//!    strand, so a trace can never contain a dangling open span.
//! 3. **Lock-light and bounded.** Each thread owns a bounded ring
//!    ([`TQM_TRACE_BUF`][TRACE_BUF_VAR] events, oldest overwritten); the
//!    hot path takes an uncontended `try_lock` on its own ring and on the
//!    rare conflict with a concurrent [`drain`] the event is counted into
//!    a dropped counter instead of blocking the serving thread.
//!
//! The recorder is process-global: [`init_from_env`] arms it from the
//! `TQM_TRACE_*` knobs, [`drain`] collects all rings into a [`TraceBatch`]
//! and [`write_run`] serializes one to disk via [`chrome`]. [`report`]
//! turns either a live batch or a loaded file into per-request waterfalls
//! with critical-path stage attribution (`tqm trace-report`).

pub mod chrome;
pub mod report;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, TryLockError};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::{env_parse, env_parse_opt, lock_recover};

/// Directory to write `TRACE_<run>.json` files into; setting it enables
/// the recorder. Parsed loudly via `util::env_parse`.
pub const TRACE_DIR_VAR: &str = "TQM_TRACE_DIR";
/// Per-thread ring capacity in events (default [`DEFAULT_CAPACITY`]).
pub const TRACE_BUF_VAR: &str = "TQM_TRACE_BUF";
/// Default per-thread ring capacity.
pub const DEFAULT_CAPACITY: usize = 65_536;
/// Stamped into `otherData.schema_version`; bump on incompatible change.
pub const SCHEMA_VERSION: u32 = 1;

/// Sentinel for "no request id" (not serialized).
pub const NO_REQ: u64 = u64::MAX;
/// Sentinel for "no layer / expert index" (not serialized).
pub const NO_IDX: u32 = u32::MAX;

/// Event category — becomes the Chrome `cat` field and the stage key the
/// report attributes request time to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    /// Request sat in the host queue before its batch formed.
    Queue,
    /// Batcher drain window (waiting for batch-mates).
    Drain,
    /// Whole request: batch admission to final token.
    Request,
    /// One `forward_batch` step across all layers.
    Step,
    /// Router + `LayerPlan` build (includes quarantine filtering).
    Plan,
    /// Serving thread blocked on expert bytes (demand decode, quiesce).
    Stall,
    /// Expert FFN execution for one layer.
    Exec,
    /// Individual qGEMV/qGEMM kernel calls (nested inside `Exec`).
    Kernel,
    /// Prefetch worker activity (off the critical path when hidden).
    Prefetch,
    /// Expert-cache events: evictions, speculative promotion.
    Cache,
    /// Fetch retries and backoff sleeps.
    Retry,
    /// Injected faults, quarantine transitions, timeouts, drops.
    Fault,
}

impl Category {
    pub fn label(self) -> &'static str {
        match self {
            Category::Queue => "queue",
            Category::Drain => "drain",
            Category::Request => "request",
            Category::Step => "step",
            Category::Plan => "plan",
            Category::Stall => "stall",
            Category::Exec => "exec",
            Category::Kernel => "kernel",
            Category::Prefetch => "prefetch",
            Category::Cache => "cache",
            Category::Retry => "retry",
            Category::Fault => "fault",
        }
    }
}

/// One recorded event. Fixed-size and `Copy` so ring writes never
/// allocate; names are `&'static str` by design.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Nanoseconds since the recorder's monotonic anchor.
    pub ts_ns: u64,
    /// Duration in nanoseconds (0 for instants).
    pub dur_ns: u64,
    /// Instant marker (`ph: "i"`) rather than a complete span (`"X"`).
    pub instant: bool,
    pub cat: Category,
    pub name: &'static str,
    /// Recorder-assigned thread id (stable within a process run).
    pub tid: u64,
    /// Request id or [`NO_REQ`].
    pub req: u64,
    /// Layer index or [`NO_IDX`].
    pub layer: u32,
    /// Expert index or [`NO_IDX`].
    pub expert: u32,
}

/// Bounded per-thread event ring: oldest events are overwritten once
/// `cap` is reached and the overwrites are counted, never silently lost.
struct Ring {
    buf: Vec<Event>,
    cap: usize,
    head: usize,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Self { buf: Vec::new(), cap: cap.max(1), head: 0, dropped: 0 }
    }

    fn push(&mut self, ev: Event) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Remove and return the retained events oldest-first, plus the count
    /// of events that were overwritten since the last take.
    fn take(&mut self) -> (Vec<Event>, u64) {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        self.buf.clear();
        self.head = 0;
        let dropped = self.dropped;
        self.dropped = 0;
        (out, dropped)
    }
}

struct ThreadRing {
    tid: u64,
    name: String,
    ring: Arc<Mutex<Ring>>,
}

struct Shared {
    /// Monotonic zero point; all timestamps are offsets from here.
    anchor: Instant,
    cap: AtomicUsize,
    dir: Mutex<Option<PathBuf>>,
    rings: Mutex<Vec<ThreadRing>>,
    next_tid: AtomicU64,
    /// Events lost to `try_lock` contention with a concurrent drain.
    contended_drops: AtomicU64,
    /// Per-run-name write sequence, so two hosts in one process can both
    /// flush without clobbering each other's file.
    run_seq: Mutex<BTreeMap<String, u64>>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SHARED: OnceLock<Shared> = OnceLock::new();

fn shared() -> &'static Shared {
    SHARED.get_or_init(|| Shared {
        anchor: Instant::now(),
        cap: AtomicUsize::new(DEFAULT_CAPACITY),
        dir: Mutex::new(None),
        rings: Mutex::new(Vec::new()),
        next_tid: AtomicU64::new(1),
        contended_drops: AtomicU64::new(0),
        run_seq: Mutex::new(BTreeMap::new()),
    })
}

thread_local! {
    static LOCAL: std::cell::OnceCell<(u64, Arc<Mutex<Ring>>)> =
        const { std::cell::OnceCell::new() };
}

fn register_thread() -> (u64, Arc<Mutex<Ring>>) {
    let s = shared();
    let tid = s.next_tid.fetch_add(1, Ordering::Relaxed);
    let name = std::thread::current()
        .name()
        .map(|n| n.to_string())
        .unwrap_or_else(|| format!("thread-{tid}"));
    let ring = Arc::new(Mutex::new(Ring::new(s.cap.load(Ordering::Relaxed))));
    lock_recover(&s.rings).push(ThreadRing { tid, name, ring: Arc::clone(&ring) });
    (tid, ring)
}

fn record(mut ev: Event) {
    LOCAL.with(|cell| {
        let (tid, ring) = cell.get_or_init(register_thread);
        ev.tid = *tid;
        match ring.try_lock() {
            Ok(mut g) => g.push(ev),
            Err(TryLockError::Poisoned(p)) => p.into_inner().push(ev),
            Err(TryLockError::WouldBlock) => {
                shared().contended_drops.fetch_add(1, Ordering::Relaxed);
            }
        }
    });
}

fn ns_of(t: Instant) -> u64 {
    t.saturating_duration_since(shared().anchor).as_nanos() as u64
}

/// Is the recorder armed? One relaxed load — this is the entire cost of
/// every instrumentation point when tracing is off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Force the recorder on or off (benches measuring recorder overhead and
/// tests; normal runs arm it via [`init_from_env`]).
pub fn set_enabled(on: bool) {
    if on {
        let _ = shared(); // pin the clock anchor before the first event
    }
    ENABLED.store(on, Ordering::Release);
}

/// Ring capacity for threads that register *after* this call; existing
/// rings keep their size.
pub fn set_capacity(cap: usize) {
    shared().cap.store(cap.max(16), Ordering::Relaxed);
}

/// Arm the recorder from `TQM_TRACE_DIR` / `TQM_TRACE_BUF`. Idempotent;
/// a no-op when the dir knob is unset or the recorder is already armed.
pub fn init_from_env() -> Result<()> {
    if enabled() {
        return Ok(());
    }
    if let Some(dir) = env_parse_opt::<PathBuf>(TRACE_DIR_VAR)? {
        let cap = env_parse::<usize>(TRACE_BUF_VAR, DEFAULT_CAPACITY)?;
        let s = shared();
        s.cap.store(cap.max(16), Ordering::Relaxed);
        *lock_recover(&s.dir) = Some(dir);
        ENABLED.store(true, Ordering::Release);
    }
    Ok(())
}

struct Pending {
    t0: Instant,
    cat: Category,
    name: &'static str,
    req: u64,
    layer: u32,
    expert: u32,
}

impl Pending {
    fn start(cat: Category, name: &'static str) -> Option<Self> {
        if !enabled() {
            return None;
        }
        Some(Self { t0: Instant::now(), cat, name, req: NO_REQ, layer: NO_IDX, expert: NO_IDX })
    }

    fn event(&self, instant: bool) -> Event {
        Event {
            ts_ns: ns_of(self.t0),
            dur_ns: if instant { 0 } else { self.t0.elapsed().as_nanos() as u64 },
            instant,
            cat: self.cat,
            name: self.name,
            tid: 0, // assigned in record()
            req: self.req,
            layer: self.layer,
            expert: self.expert,
        }
    }
}

/// RAII span guard: records one complete event covering its lifetime when
/// dropped — including during panic unwinding, so spans cannot dangle.
/// When the recorder is off it is an empty shell and records nothing.
pub struct Span(Option<Pending>);

impl Span {
    pub fn req(mut self, req: u64) -> Self {
        if let Some(p) = &mut self.0 {
            p.req = req;
        }
        self
    }

    pub fn layer(mut self, layer: usize) -> Self {
        if let Some(p) = &mut self.0 {
            p.layer = layer as u32;
        }
        self
    }

    pub fn expert(mut self, expert: usize) -> Self {
        if let Some(p) = &mut self.0 {
            p.expert = expert as u32;
        }
        self
    }

    /// Retitle the span before it closes (e.g. to encode its outcome:
    /// `"decode"` → `"decode_admitted"`).
    pub fn rename(&mut self, name: &'static str) {
        if let Some(p) = &mut self.0 {
            p.name = name;
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(p) = self.0.take() {
            record(p.event(false));
        }
    }
}

/// Start a span; close it by dropping the guard.
pub fn span(cat: Category, name: &'static str) -> Span {
    Span(Pending::start(cat, name))
}

/// Instant-event builder: records a zero-duration marker when the
/// temporary drops (i.e. at the end of the statement that built it).
pub struct Mark(Option<Pending>);

impl Mark {
    pub fn req(mut self, req: u64) -> Self {
        if let Some(p) = &mut self.0 {
            p.req = req;
        }
        self
    }

    pub fn layer(mut self, layer: usize) -> Self {
        if let Some(p) = &mut self.0 {
            p.layer = layer as u32;
        }
        self
    }

    pub fn expert(mut self, expert: usize) -> Self {
        if let Some(p) = &mut self.0 {
            p.expert = expert as u32;
        }
        self
    }
}

impl Drop for Mark {
    fn drop(&mut self) {
        if let Some(p) = self.0.take() {
            record(p.event(true));
        }
    }
}

/// Record an instant marker. Used as a bare statement:
/// `trace::mark(Category::Cache, "evict").layer(l).expert(e);`
pub fn mark(cat: Category, name: &'static str) -> Mark {
    Mark(Pending::start(cat, name))
}

/// Record a complete span between two already-measured instants (e.g. a
/// request's queue window, whose start predates the span's recording).
pub fn span_between(cat: Category, name: &'static str, req: u64, begin: Instant, end: Instant) {
    if !enabled() {
        return;
    }
    record(Event {
        ts_ns: ns_of(begin),
        dur_ns: end.saturating_duration_since(begin).as_nanos() as u64,
        instant: false,
        cat,
        name,
        tid: 0,
        req,
        layer: NO_IDX,
        expert: NO_IDX,
    });
}

/// Everything drained from the rings at one point in time.
pub struct TraceBatch {
    /// All events, sorted by timestamp (then thread id).
    pub events: Vec<Event>,
    /// `(tid, thread name)` for every thread that contributed events.
    pub threads: Vec<(u64, String)>,
    /// Events lost to ring wrap or drain contention since the last drain.
    pub dropped: u64,
}

/// Collect and clear every thread's ring. Writers never block on a drain
/// (they count a drop instead), so this is safe to call while serving.
pub fn drain() -> TraceBatch {
    let s = shared();
    let mut dropped = s.contended_drops.swap(0, Ordering::Relaxed);
    let mut events = Vec::new();
    let mut threads = Vec::new();
    {
        let regs = lock_recover(&s.rings);
        for tr in regs.iter() {
            let (evs, d) = lock_recover(&tr.ring).take();
            dropped += d;
            if !evs.is_empty() {
                threads.push((tr.tid, tr.name.clone()));
            }
            events.extend(evs);
        }
    }
    events.sort_by(|a, b| a.ts_ns.cmp(&b.ts_ns).then(a.tid.cmp(&b.tid)));
    TraceBatch { events, threads, dropped }
}

/// Write a batch as `TRACE_<run>.json` into `TQM_TRACE_DIR` (suffixed
/// `-1`, `-2`, … when the same run name flushes more than once in one
/// process). Returns `None` when the dir knob is unset or the batch is
/// empty.
pub fn write_batch(batch: &TraceBatch, run: &str) -> Result<Option<PathBuf>> {
    let dir = lock_recover(&shared().dir).clone();
    let Some(dir) = dir else {
        return Ok(None);
    };
    if batch.events.is_empty() {
        return Ok(None);
    }
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating trace dir {}", dir.display()))?;
    let seq = {
        let mut seqs = lock_recover(&shared().run_seq);
        let n = seqs.entry(run.to_string()).or_insert(0);
        let cur = *n;
        *n += 1;
        cur
    };
    let file =
        if seq == 0 { format!("TRACE_{run}.json") } else { format!("TRACE_{run}-{seq}.json") };
    let path = dir.join(file);
    std::fs::write(&path, chrome::to_json(batch, run).to_string())
        .with_context(|| format!("writing {}", path.display()))?;
    eprintln!(
        "[trace] wrote {} ({} events, {} dropped)",
        path.display(),
        batch.events.len(),
        batch.dropped
    );
    Ok(Some(path))
}

/// Drain and write in one step. A no-op (rings untouched) when the
/// recorder is off or no trace dir is configured, so callers can invoke
/// it unconditionally at run boundaries.
pub fn write_run(run: &str) -> Result<Option<PathBuf>> {
    if !enabled() {
        return Ok(None);
    }
    if lock_recover(&shared().dir).is_none() {
        return Ok(None);
    }
    let batch = drain();
    write_batch(&batch, run)
}

static TEST_LOCK: Mutex<()> = Mutex::new(());

/// Serializes tests (and bench variants) that force-enable the global
/// recorder: drains stale events, enables recording, and on drop restores
/// the previous enabled state and drains again so nothing leaks into the
/// next acquirer.
pub struct TestGuard {
    _lock: MutexGuard<'static, ()>,
    prev: bool,
}

pub fn test_guard() -> TestGuard {
    let lock = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let prev = enabled();
    set_enabled(true);
    let _ = drain();
    TestGuard { _lock: lock, prev }
}

impl Drop for TestGuard {
    fn drop(&mut self) {
        set_enabled(self.prev);
        let _ = drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn ring_wrap_drops_oldest_keeps_order_and_counts() {
        // Property over random capacities and lengths: after n pushes into
        // a cap-k ring, exactly the last min(n, k) events remain in push
        // order and dropped == max(0, n - k).
        let mut rng = Rng::seed_from_u64(0x7ACE);
        for _ in 0..64 {
            let cap = rng.gen_range_usize(1, 33);
            let n = rng.gen_range_usize(0, 101);
            let mut ring = Ring::new(cap);
            for i in 0..n {
                let mut ev = template_event();
                ev.ts_ns = i as u64;
                ring.push(ev);
            }
            let (evs, dropped) = ring.take();
            assert_eq!(dropped, n.saturating_sub(cap) as u64);
            assert_eq!(evs.len(), n.min(cap));
            let expect_first = n.saturating_sub(cap) as u64;
            for (k, ev) in evs.iter().enumerate() {
                assert_eq!(ev.ts_ns, expect_first + k as u64, "cap={cap} n={n}");
            }
            // the ring is reusable after a take
            let mut ev = template_event();
            ev.ts_ns = 999;
            ring.push(ev);
            let (evs, dropped) = ring.take();
            assert_eq!((evs.len(), dropped), (1, 0));
        }
    }

    fn template_event() -> Event {
        Event {
            ts_ns: 0,
            dur_ns: 1,
            instant: false,
            cat: Category::Exec,
            name: "t",
            tid: 0,
            req: NO_REQ,
            layer: NO_IDX,
            expert: NO_IDX,
        }
    }

    #[test]
    fn spans_and_marks_record_ids_and_nonnegative_times() {
        let _g = test_guard();
        {
            let _s = span(Category::Exec, "unit_exec").req(7).layer(2).expert(5);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        mark(Category::Cache, "unit_evict").layer(1).expert(3);
        let batch = drain();
        let s = batch
            .events
            .iter()
            .find(|e| e.name == "unit_exec")
            .expect("span recorded");
        assert!(!s.instant);
        assert_eq!((s.req, s.layer, s.expert), (7, 2, 5));
        assert!(s.dur_ns >= 1_000_000, "span covered the sleep");
        let m = batch
            .events
            .iter()
            .find(|e| e.name == "unit_evict")
            .expect("mark recorded");
        assert!(m.instant);
        assert_eq!(m.dur_ns, 0);
        assert_eq!((m.layer, m.expert), (1, 3));
        assert_eq!(s.tid, m.tid, "same thread, same ring");
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let _g = test_guard();
        set_enabled(false);
        {
            let _s = span(Category::Exec, "off_span");
        }
        mark(Category::Cache, "off_mark");
        span_between(
            Category::Queue,
            "off_between",
            1,
            Instant::now(),
            Instant::now(),
        );
        set_enabled(true);
        let batch = drain();
        assert!(
            !batch.events.iter().any(|e| e.name.starts_with("off_")),
            "disabled recorder must not record"
        );
    }

    #[test]
    fn ring_wrap_through_public_api_reports_drops() {
        let _g = test_guard();
        set_capacity(32);
        let handle = std::thread::Builder::new()
            .name("trace-wrap-test".into())
            .spawn(|| {
                for _ in 0..100 {
                    mark(Category::Prefetch, "wrap_mark");
                }
            })
            .expect("spawn");
        handle.join().expect("join");
        set_capacity(DEFAULT_CAPACITY);
        let batch = drain();
        let kept: Vec<_> =
            batch.events.iter().filter(|e| e.name == "wrap_mark").collect();
        assert_eq!(kept.len(), 32, "ring keeps exactly its capacity");
        assert!(batch.dropped >= 68, "overwrites are counted, got {}", batch.dropped);
        for w in kept.windows(2) {
            assert!(w[0].ts_ns <= w[1].ts_ns, "retained events stay ordered");
        }
    }
}
