//! Critical-path latency attribution over a recorded trace: reconstruct
//! each request's waterfall (queue → plan → stall → exec → retry), pin
//! p50/p95/p99 per stage across requests, and diff two traces the way
//! `bench-report` diffs bench sets.
//!
//! Attribution model: a request's wall window is its `request` span on
//! the serving thread. Every stage span on the *same thread* contributes
//! its overlap with that window; what no stage claims is `other`. Because
//! the serving thread's stage spans (plan / demand-decode stall / exec /
//! retry backoff) are disjoint sections of the forward loop, the summed
//! stages plus `other` reconcile with the wall time *by construction* —
//! that identity is the acceptance gate for the recorder. Kernel spans
//! nest inside exec and prefetch work runs on other threads, so both are
//! reported separately instead of being double-counted into the path.

use std::collections::BTreeMap;

use crate::util::bench::Table;
use crate::util::stats;

use super::chrome::LoadedTrace;
use super::TraceBatch;

/// Stage categories charged against the request window, in report order.
const ATTRIBUTED: [&str; 4] = ["plan", "stall", "exec", "retry"];

/// Normalized event: one shape for live batches and loaded files.
#[derive(Clone, Debug)]
struct Ev {
    ts_us: f64,
    dur_us: f64,
    instant: bool,
    cat: String,
    name: String,
    tid: u64,
    req: Option<u64>,
}

/// One request's reconstructed timeline.
#[derive(Clone, Debug)]
pub struct RequestWaterfall {
    pub req: u64,
    /// Time in the host queue before the batch formed (outside the wall
    /// window, reported alongside it).
    pub queue_us: f64,
    /// The request span: batch admission to final token.
    pub wall_us: f64,
    /// Stage → attributed µs; keys are the [`ATTRIBUTED`] categories.
    pub stages: BTreeMap<String, f64>,
    /// Wall time no stage span claimed.
    pub other_us: f64,
}

impl RequestWaterfall {
    pub fn stage(&self, name: &str) -> f64 {
        self.stages.get(name).copied().unwrap_or(0.0)
    }

    /// Summed stage durations plus `other` — reconciles with `wall_us`
    /// up to f64 rounding; asserted by the integration tests.
    pub fn accounted_us(&self) -> f64 {
        self.stages.values().sum::<f64>() + self.other_us
    }
}

/// Distribution of one stage across all requests.
#[derive(Clone, Debug)]
pub struct StageStats {
    pub stage: String,
    pub total_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

/// Recorder health for the CI gate: all three must be zero for a clean
/// run (dropped events are tolerable under ring wrap, but reported).
#[derive(Clone, Copy, Debug, Default)]
pub struct Integrity {
    pub negative_durations: usize,
    pub open_spans: usize,
    pub dropped: u64,
}

#[derive(Clone, Debug)]
pub struct TraceReport {
    pub run: String,
    pub requests: Vec<RequestWaterfall>,
    /// Per-stage distributions: queue, the attributed stages, other, wall.
    pub stages: Vec<StageStats>,
    /// Prefetch decode time that was admitted to cache — latency hidden
    /// off the critical path.
    pub hidden_prefetch_us: f64,
    /// Kernel (qGEMV/qGEMM) time nested inside exec.
    pub kernel_us: f64,
    /// Instant-event counts keyed `cat/name` (evictions, retries, faults).
    pub counts: BTreeMap<String, u64>,
    pub integrity: Integrity,
}

pub fn from_loaded(t: &LoadedTrace) -> TraceReport {
    let evs: Vec<Ev> = t
        .events
        .iter()
        .map(|e| Ev {
            ts_us: e.ts_us,
            dur_us: e.dur_us.unwrap_or(0.0),
            instant: e.is_instant(),
            cat: e.cat.clone(),
            name: e.name.clone(),
            tid: e.tid,
            req: e.req,
        })
        .collect();
    build(&t.run, &evs, t.dropped, t.open_spans)
}

pub fn from_batch(b: &TraceBatch) -> TraceReport {
    build("live", &evs_of_batch(b), b.dropped, 0)
}

fn evs_of_batch(b: &TraceBatch) -> Vec<Ev> {
    b.events
        .iter()
        .map(|e| Ev {
            ts_us: e.ts_ns as f64 / 1000.0,
            dur_us: e.dur_ns as f64 / 1000.0,
            instant: e.instant,
            cat: e.cat.label().to_string(),
            name: e.name.to_string(),
            tid: e.tid,
            req: if e.req == super::NO_REQ { None } else { Some(e.req) },
        })
        .collect()
}

fn build(run: &str, evs: &[Ev], dropped: u64, open_spans: usize) -> TraceReport {
    let negative_durations = evs.iter().filter(|e| e.dur_us < 0.0).count();

    let mut requests = Vec::new();
    for r in evs.iter().filter(|e| !e.instant && e.cat == "request") {
        let Some(req) = r.req else { continue };
        let (lo, hi) = (r.ts_us, r.ts_us + r.dur_us);
        let queue_us: f64 = evs
            .iter()
            .filter(|e| !e.instant && e.cat == "queue" && e.req == Some(req))
            .map(|e| e.dur_us)
            .sum();
        let mut stages: BTreeMap<String, f64> =
            ATTRIBUTED.iter().map(|s| (s.to_string(), 0.0)).collect();
        for e in evs.iter().filter(|e| !e.instant && e.tid == r.tid) {
            let Some(acc) = stages.get_mut(e.cat.as_str()) else { continue };
            let overlap = (hi.min(e.ts_us + e.dur_us) - lo.max(e.ts_us)).max(0.0);
            *acc += overlap;
        }
        let attributed: f64 = stages.values().sum();
        requests.push(RequestWaterfall {
            req,
            queue_us,
            wall_us: r.dur_us,
            stages,
            other_us: r.dur_us - attributed,
        });
    }
    requests.sort_by_key(|w| w.req);

    let mut stage_rows: Vec<(&str, Vec<f64>)> = Vec::new();
    stage_rows.push(("queue", requests.iter().map(|w| w.queue_us).collect()));
    for s in ATTRIBUTED {
        stage_rows.push((s, requests.iter().map(|w| w.stage(s)).collect()));
    }
    stage_rows.push(("other", requests.iter().map(|w| w.other_us).collect()));
    stage_rows.push(("wall", requests.iter().map(|w| w.wall_us).collect()));
    let stages = stage_rows
        .into_iter()
        .map(|(name, mut xs)| {
            let total = xs.iter().sum();
            stats::sort_samples(&mut xs);
            StageStats {
                stage: name.to_string(),
                total_us: total,
                p50_us: stats::percentile(&xs, 50),
                p95_us: stats::percentile(&xs, 95),
                p99_us: stats::percentile(&xs, 99),
            }
        })
        .collect();

    let hidden_prefetch_us = evs
        .iter()
        .filter(|e| !e.instant && e.cat == "prefetch" && e.name == "decode_admitted")
        .map(|e| e.dur_us)
        .sum();
    let kernel_us =
        evs.iter().filter(|e| !e.instant && e.cat == "kernel").map(|e| e.dur_us).sum();
    let mut counts: BTreeMap<String, u64> = BTreeMap::new();
    for e in evs.iter().filter(|e| e.instant) {
        *counts.entry(format!("{}/{}", e.cat, e.name)).or_insert(0) += 1;
    }

    TraceReport {
        run: run.to_string(),
        requests,
        stages,
        hidden_prefetch_us,
        kernel_us,
        counts,
        integrity: Integrity { negative_durations, open_spans, dropped },
    }
}

fn ms(us: f64) -> String {
    format!("{:.3}", us / 1000.0)
}

/// The machine-greppable recorder-health line; CI gates on the zeros.
pub fn integrity_line(r: &TraceReport) -> String {
    format!(
        "integrity: {} negative-duration event(s), {} unclosed span(s), {} dropped event(s)",
        r.integrity.negative_durations, r.integrity.open_spans, r.integrity.dropped
    )
}

/// Render the full human report: stage attribution table, the first
/// `max_requests` per-request waterfalls, instant counts, and the
/// integrity line.
pub fn render(r: &TraceReport, max_requests: usize) -> String {
    let mut out = String::new();
    let mut t = Table::new(
        &format!("trace-report: stage attribution — {} request(s), run '{}'", r.requests.len(), r.run),
        &["stage", "total ms", "p50 ms", "p95 ms", "p99 ms"],
    );
    for s in &r.stages {
        t.row(vec![
            s.stage.clone(),
            ms(s.total_us),
            ms(s.p50_us),
            ms(s.p95_us),
            ms(s.p99_us),
        ]);
    }
    out.push_str(&t.render());

    let shown = r.requests.len().min(max_requests);
    let mut w = Table::new(
        &format!("per-request waterfalls (showing {shown} of {})", r.requests.len()),
        &["req", "queue ms", "plan ms", "stall ms", "exec ms", "retry ms", "other ms", "wall ms"],
    );
    for rq in r.requests.iter().take(max_requests) {
        w.row(vec![
            rq.req.to_string(),
            ms(rq.queue_us),
            ms(rq.stage("plan")),
            ms(rq.stage("stall")),
            ms(rq.stage("exec")),
            ms(rq.stage("retry")),
            ms(rq.other_us),
            ms(rq.wall_us),
        ]);
    }
    out.push_str(&w.render());

    if !r.counts.is_empty() {
        let mut c = Table::new("instant events", &["event", "count"]);
        for (k, v) in &r.counts {
            c.row(vec![k.clone(), v.to_string()]);
        }
        out.push_str(&c.render());
    }
    out.push_str(&format!(
        "\nhidden prefetch decode: {} ms (off critical path) | kernel time: {} ms\n",
        ms(r.hidden_prefetch_us),
        ms(r.kernel_us)
    ));
    out.push_str(&integrity_line(r));
    out.push('\n');
    out
}

/// Compact one-cell stage breakdown for the envelope/faults tables:
/// percentage of total request wall time per stage. `None` when the
/// batch contains no request spans.
pub fn compact_stage_breakdown(b: &TraceBatch) -> Option<String> {
    let r = from_batch(b);
    if r.requests.is_empty() {
        return None;
    }
    let wall: f64 = r.requests.iter().map(|w| w.wall_us).sum();
    if wall <= 0.0 {
        return None;
    }
    let mut parts = Vec::new();
    for s in ATTRIBUTED {
        let total: f64 = r.requests.iter().map(|w| w.stage(s)).sum();
        parts.push(format!("{s}:{:.0}%", 100.0 * total / wall));
    }
    let other: f64 = r.requests.iter().map(|w| w.other_us).sum();
    parts.push(format!("other:{:.0}%", 100.0 * other / wall));
    Some(parts.join(" "))
}

/// Like [`compact_stage_breakdown`] but attributed against the
/// scheduler's `forward_batch` step spans instead of request spans — for
/// cells (the chaos matrix) that drive the scheduler directly without a
/// serving host in front of it. `None` when no step spans were recorded.
pub fn compact_step_breakdown(b: &TraceBatch) -> Option<String> {
    let evs = evs_of_batch(b);
    let mut wall = 0.0f64;
    let mut stages: BTreeMap<&str, f64> = ATTRIBUTED.iter().map(|s| (*s, 0.0)).collect();
    for st in evs.iter().filter(|e| !e.instant && e.cat == "step") {
        wall += st.dur_us;
        let (lo, hi) = (st.ts_us, st.ts_us + st.dur_us);
        for e in evs.iter().filter(|e| !e.instant && e.tid == st.tid) {
            let Some(acc) = stages.get_mut(e.cat.as_str()) else { continue };
            let overlap = (hi.min(e.ts_us + e.dur_us) - lo.max(e.ts_us)).max(0.0);
            *acc += overlap;
        }
    }
    if wall <= 0.0 {
        return None;
    }
    let attributed: f64 = stages.values().sum();
    let mut parts = Vec::new();
    for s in ATTRIBUTED {
        parts.push(format!("{s}:{:.0}%", 100.0 * stages[s] / wall));
    }
    parts.push(format!("other:{:.0}%", 100.0 * (wall - attributed).max(0.0) / wall));
    Some(parts.join(" "))
}

/// Diff two reports by per-stage p95, `bench-report`-style: a stage is a
/// regression when its p95 grew beyond the noise threshold (plus a 1 µs
/// absolute floor so microsecond jitter on near-zero stages never
/// classifies). Returns the rendered diff and the regression count.
pub fn diff(base: &TraceReport, cur: &TraceReport, noise: f64) -> (String, usize) {
    const FLOOR_US: f64 = 1.0;
    let base_by: BTreeMap<&str, &StageStats> =
        base.stages.iter().map(|s| (s.stage.as_str(), s)).collect();
    let mut t = Table::new(
        &format!("trace diff (p95 per stage, noise ±{:.0}%)", noise * 100.0),
        &["stage", "base p95 ms", "cur p95 ms", "delta", "class"],
    );
    let (mut regressions, mut improvements, mut neutral) = (0usize, 0usize, 0usize);
    for s in &cur.stages {
        let Some(b) = base_by.get(s.stage.as_str()) else {
            t.row(vec![s.stage.clone(), "-".into(), ms(s.p95_us), "-".into(), "new".into()]);
            neutral += 1;
            continue;
        };
        let delta = s.p95_us - b.p95_us;
        let pct = if b.p95_us > 0.0 { 100.0 * delta / b.p95_us } else { 0.0 };
        let class = if delta > b.p95_us * noise + FLOOR_US {
            regressions += 1;
            "REGRESSION"
        } else if -delta > b.p95_us * noise + FLOOR_US {
            improvements += 1;
            "improvement"
        } else {
            neutral += 1;
            "neutral"
        };
        t.row(vec![
            s.stage.clone(),
            ms(b.p95_us),
            ms(s.p95_us),
            format!("{pct:+.1}%"),
            class.to_string(),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\nrequests: base {} -> cur {}\n{} regression(s), {} improvement(s), {} neutral\n",
        base.requests.len(),
        cur.requests.len(),
        regressions,
        improvements,
        neutral
    ));
    (out, regressions)
}

#[cfg(test)]
mod tests {
    use super::super::{drain, mark, span, span_between, test_guard, Category};
    use super::*;
    use std::time::{Duration, Instant};

    /// Request id no real host run reaches; lets the tests pick their
    /// own events out of a drain that may also contain spans recorded by
    /// instrumented code in concurrently running tests.
    const SYNTH_REQ: u64 = (1 << 40) + 3;

    fn synth_report(scale: f64) -> TraceReport {
        // one synthetic request with deterministic stage spans, built
        // through the real recorder so the whole pipeline is exercised
        let _g = test_guard();
        let t0 = Instant::now();
        {
            let _plan = span(Category::Plan, "layer_plan").layer(0);
            std::thread::sleep(Duration::from_micros((400.0 * scale) as u64));
        }
        {
            let _stall = span(Category::Stall, "demand_decode").layer(0).expert(1);
            std::thread::sleep(Duration::from_micros((800.0 * scale) as u64));
        }
        {
            let _exec = span(Category::Exec, "moe_exec").layer(0);
            std::thread::sleep(Duration::from_micros((600.0 * scale) as u64));
        }
        mark(Category::Fault, "quarantined").layer(0).expert(1);
        span_between(Category::Request, "request", SYNTH_REQ, t0, Instant::now());
        let mut batch = drain();
        // keep only this thread's events: another test's instrumented
        // code may record into its own ring while the recorder is armed
        let tid = batch
            .events
            .iter()
            .find(|e| e.req == SYNTH_REQ)
            .expect("synthetic request recorded")
            .tid;
        batch.events.retain(|e| e.tid == tid);
        from_batch(&batch)
    }

    #[test]
    fn waterfall_stages_reconcile_with_wall_by_construction() {
        let r = synth_report(1.0);
        assert_eq!(r.requests.len(), 1);
        let w = &r.requests[0];
        assert_eq!(w.req, SYNTH_REQ);
        assert!(w.stage("plan") > 0.0 && w.stage("stall") > 0.0 && w.stage("exec") > 0.0);
        assert!((w.accounted_us() - w.wall_us).abs() < 0.01, "stages + other == wall");
        assert!(w.other_us >= -0.01, "disjoint stages can never over-claim");
        assert_eq!(r.counts.get("fault/quarantined"), Some(&1));
        assert_eq!(r.integrity.negative_durations, 0);
        assert_eq!(r.integrity.open_spans, 0);
        let rendered = render(&r, 8);
        assert!(rendered.contains("stage attribution"));
        assert!(rendered.contains("0 negative-duration event(s)"));
    }

    #[test]
    fn self_diff_is_all_neutral_and_regressions_classify() {
        let base = synth_report(1.0);
        let (out, regressions) = diff(&base, &base, 0.10);
        assert_eq!(regressions, 0, "self-diff must be clean:\n{out}");
        assert!(out.contains("0 regression(s)"));
        let slow = synth_report(40.0);
        let (out, regressions) = diff(&base, &slow, 0.10);
        assert!(regressions >= 1, "40x slower stages must classify:\n{out}");
    }

    #[test]
    fn zero_completed_requests_render_an_empty_but_valid_report() {
        // an overload run can shed or reject every request before any
        // forward work: the trace then holds queue marks but not a
        // single request span — the report must render cleanly (no NaN
        // percentiles, zero waterfalls) with an intact integrity line
        let _g = test_guard();
        mark(Category::Queue, "shed").req(SYNTH_REQ);
        mark(Category::Queue, "rejected").req(SYNTH_REQ + 1);
        let batch = drain();
        let r = from_batch(&batch);
        assert!(r.requests.is_empty(), "marks alone must not fabricate waterfalls");
        assert_eq!(r.integrity.negative_durations, 0);
        assert_eq!(r.integrity.open_spans, 0);
        for s in &r.stages {
            assert!(
                s.p50_us.is_finite() && s.p95_us.is_finite() && s.p99_us.is_finite(),
                "stage {} percentile went non-finite on an empty run",
                s.stage
            );
        }
        let rendered = render(&r, 8);
        assert!(rendered.contains("0 request(s)"), "{rendered}");
        assert!(!rendered.contains("NaN"), "{rendered}");
        assert!(rendered.contains("0 negative-duration event(s)"), "{rendered}");
        assert!(rendered.contains("0 unclosed span(s)"), "{rendered}");
        assert!(rendered.contains("queue/shed"), "shed mark missing: {rendered}");
        // and a self-diff of the empty report is clean, not NaN noise
        let (out, regressions) = diff(&r, &r, 0.10);
        assert_eq!(regressions, 0, "{out}");
        assert!(out.contains("0 regression(s)"), "{out}");
    }

    #[test]
    fn compact_breakdown_covers_all_stages() {
        let _g = test_guard();
        let t0 = Instant::now();
        {
            let _exec = span(Category::Exec, "moe_exec");
            std::thread::sleep(Duration::from_millis(2));
        }
        span_between(Category::Request, "request", 0, t0, Instant::now());
        let batch = drain();
        let line = compact_stage_breakdown(&batch).expect("one request recorded");
        for key in ["plan:", "stall:", "exec:", "retry:", "other:"] {
            assert!(line.contains(key), "missing {key} in {line}");
        }
        let empty = TraceBatch { events: Vec::new(), threads: Vec::new(), dropped: 0 };
        assert!(compact_stage_breakdown(&empty).is_none());
    }

    #[test]
    fn compact_step_breakdown_attributes_against_forward_steps() {
        let _g = test_guard();
        {
            let _step = span(Category::Step, "forward_batch");
            let _exec = span(Category::Exec, "moe_exec").layer(0);
            std::thread::sleep(Duration::from_millis(2));
        }
        let batch = drain();
        let line = compact_step_breakdown(&batch).expect("one step recorded");
        for key in ["plan:", "stall:", "exec:", "retry:", "other:"] {
            assert!(line.contains(key), "missing {key} in {line}");
        }
        let empty = TraceBatch { events: Vec::new(), threads: Vec::new(), dropped: 0 };
        assert!(compact_step_breakdown(&empty).is_none());
    }
}
