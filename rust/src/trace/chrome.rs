//! Chrome trace-event JSON for [`TraceBatch`]es: the `{"traceEvents":
//! [...]}` object format that Perfetto and `chrome://tracing` load
//! directly. Spans are complete events (`ph: "X"`, `ts`/`dur` in
//! microseconds), instants are `ph: "i"` with thread scope, and thread
//! names ride along as `ph: "M"` metadata. A schema version plus the run
//! name and dropped-event count live in `otherData`, and [`from_json`]
//! refuses files from a different schema version instead of misreading
//! them.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Json;

use super::{TraceBatch, NO_IDX, NO_REQ, SCHEMA_VERSION};

/// Serialize a batch to the Chrome trace-event object format.
pub fn to_json(batch: &TraceBatch, run: &str) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(batch.events.len() + batch.threads.len());
    for (tid, name) in &batch.threads {
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(*tid as f64)),
            ("args", Json::obj(vec![("name", Json::str(name.clone()))])),
        ]));
    }
    for ev in &batch.events {
        let mut args = Vec::new();
        if ev.req != NO_REQ {
            args.push(("req", Json::num(ev.req as f64)));
        }
        if ev.layer != NO_IDX {
            args.push(("layer", Json::num(ev.layer as f64)));
        }
        if ev.expert != NO_IDX {
            args.push(("expert", Json::num(ev.expert as f64)));
        }
        let mut pairs = vec![
            ("ph", Json::str(if ev.instant { "i" } else { "X" })),
            ("name", Json::str(ev.name)),
            ("cat", Json::str(ev.cat.label())),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(ev.tid as f64)),
            ("ts", Json::num(ev.ts_ns as f64 / 1000.0)),
        ];
        if ev.instant {
            pairs.push(("s", Json::str("t")));
        } else {
            pairs.push(("dur", Json::num(ev.dur_ns as f64 / 1000.0)));
        }
        pairs.push(("args", Json::obj(args)));
        events.push(Json::obj(pairs));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ns")),
        (
            "otherData",
            Json::obj(vec![
                ("schema_version", Json::num(SCHEMA_VERSION as f64)),
                ("run", Json::str(run)),
                ("dropped_events", Json::num(batch.dropped as f64)),
            ]),
        ),
    ])
}

/// One event as read back from a trace file. Durations stay `Option` so
/// a malformed complete event (missing `dur`) is countable as unclosed
/// rather than silently becoming zero-length.
#[derive(Clone, Debug)]
pub struct LoadedEvent {
    /// Chrome phase: "X" complete or "i" instant.
    pub ph: String,
    pub ts_us: f64,
    pub dur_us: Option<f64>,
    pub cat: String,
    pub name: String,
    pub tid: u64,
    pub req: Option<u64>,
    pub layer: Option<u32>,
    pub expert: Option<u32>,
}

impl LoadedEvent {
    pub fn is_instant(&self) -> bool {
        self.ph == "i"
    }
}

/// A parsed trace file.
#[derive(Clone, Debug)]
pub struct LoadedTrace {
    pub run: String,
    /// Events dropped by the recorder (ring wrap / contention) at record
    /// time — reported, not reconstructable.
    pub dropped: u64,
    /// Complete ("X") and instant ("i") events only.
    pub events: Vec<LoadedEvent>,
    pub thread_names: BTreeMap<u64, String>,
    /// Dangling spans: unmatched "B" begins plus complete events with no
    /// duration. This recorder never emits "B"/"E" pairs, so any nonzero
    /// count means a corrupt or foreign file.
    pub open_spans: usize,
}

pub fn from_json(j: &Json) -> Result<LoadedTrace> {
    let other = j.get("otherData")?;
    let ver = other.get("schema_version")?.as_u32()?;
    if ver != SCHEMA_VERSION {
        bail!("unsupported trace schema version {ver} (this build reads {SCHEMA_VERSION})");
    }
    let run = other.get("run")?.as_str()?.to_string();
    let dropped = other.get("dropped_events")?.as_usize()? as u64;
    let mut events = Vec::new();
    let mut thread_names = BTreeMap::new();
    let mut open_begins: BTreeMap<(u64, String), i64> = BTreeMap::new();
    let mut missing_dur = 0usize;
    for ev in j.get("traceEvents")?.as_arr()? {
        let ph = ev.get("ph")?.as_str()?.to_string();
        match ph.as_str() {
            "M" => {
                if ev.get("name")?.as_str()? == "thread_name" {
                    let tid = ev.get("tid")?.as_usize()? as u64;
                    let name = ev.get("args")?.get("name")?.as_str()?.to_string();
                    thread_names.insert(tid, name);
                }
            }
            "B" | "E" => {
                // foreign begin/end pairs: track matching so dangling
                // begins surface in the integrity report
                let tid = ev.get("tid")?.as_usize()? as u64;
                let name = ev.get("name")?.as_str()?.to_string();
                let slot = open_begins.entry((tid, name)).or_insert(0);
                *slot += if ph == "B" { 1 } else { -1 };
            }
            "X" | "i" => {
                let dur_us = match ev.opt("dur") {
                    Some(d) => Some(d.as_f64()?),
                    None => None,
                };
                if ph == "X" && dur_us.is_none() {
                    missing_dur += 1;
                }
                let opt_u32 = |key: &str| -> Result<Option<u32>> {
                    match ev.get("args")?.opt(key) {
                        Some(v) => Ok(Some(v.as_u32()?)),
                        None => Ok(None),
                    }
                };
                events.push(LoadedEvent {
                    ph: ph.clone(),
                    ts_us: ev.get("ts")?.as_f64()?,
                    dur_us,
                    cat: ev.get("cat")?.as_str()?.to_string(),
                    name: ev.get("name")?.as_str()?.to_string(),
                    tid: ev.get("tid")?.as_usize()? as u64,
                    req: match ev.get("args")?.opt("req") {
                        Some(v) => Some(v.as_usize()? as u64),
                        None => None,
                    },
                    layer: opt_u32("layer")?,
                    expert: opt_u32("expert")?,
                });
            }
            _ => {} // other phases (counters, flows) are not ours; skip
        }
    }
    let unmatched: usize =
        open_begins.values().map(|&n| n.unsigned_abs() as usize).sum();
    Ok(LoadedTrace { run, dropped, events, thread_names, open_spans: unmatched + missing_dur })
}

pub fn load(path: &Path) -> Result<LoadedTrace> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
    from_json(&j).with_context(|| format!("decoding {}", path.display()))
}
