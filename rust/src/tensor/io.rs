//! TQW reader/writer — the python↔rust weight interchange format.
//!
//! Layout (little-endian, mirrored from `python/compile/tqw.py` — keep in
//! lockstep):
//!
//! ```text
//! magic  b"TQW1"
//! u32    n_tensors
//! repeated:
//!   u16      name_len, name utf-8
//!   u8       dtype (0 = f32, 1 = u8, 2 = i32)
//!   u8       ndim
//!   u32*ndim dims
//!   bytes    raw data (C-order)
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{numel, Tensor, U8Tensor};

const MAGIC: &[u8; 4] = b"TQW1";

/// A tensor as stored in a TQW file.
#[derive(Clone, Debug)]
pub enum TqwTensor {
    F32(Tensor),
    U8(U8Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl TqwTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            TqwTensor::F32(t) => &t.shape,
            TqwTensor::U8(t) => &t.shape,
            TqwTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            TqwTensor::F32(t) => Ok(t),
            _ => bail!("tensor is not f32"),
        }
    }
}

fn read_exact<const N: usize>(r: &mut impl Read) -> Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Read all tensors from a TQW file (name -> tensor, sorted by name).
pub fn read_tqw(path: impl AsRef<Path>) -> Result<BTreeMap<String, TqwTensor>> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let magic = read_exact::<4>(&mut f)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad TQW magic {magic:?}");
    }
    let n = u32::from_le_bytes(read_exact::<4>(&mut f)?) as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = u16::from_le_bytes(read_exact::<2>(&mut f)?) as usize;
        let mut name_buf = vec![0u8; name_len];
        f.read_exact(&mut name_buf)?;
        let name = String::from_utf8(name_buf).context("tensor name not utf-8")?;
        let [dtype, ndim] = read_exact::<2>(&mut f)?;
        let mut shape = Vec::with_capacity(ndim as usize);
        for _ in 0..ndim {
            shape.push(u32::from_le_bytes(read_exact::<4>(&mut f)?) as usize);
        }
        let count = numel(&shape);
        let tensor = match dtype {
            0 => {
                let mut bytes = vec![0u8; count * 4];
                f.read_exact(&mut bytes)?;
                let data = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                TqwTensor::F32(Tensor { shape, data })
            }
            1 => {
                let mut data = vec![0u8; count];
                f.read_exact(&mut data)?;
                TqwTensor::U8(U8Tensor { shape, data })
            }
            2 => {
                let mut bytes = vec![0u8; count * 4];
                f.read_exact(&mut bytes)?;
                let data = bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                TqwTensor::I32 { shape, data }
            }
            d => bail!("{path:?}: unknown TQW dtype {d}"),
        };
        out.insert(name, tensor);
    }
    Ok(out)
}

/// Write tensors to a TQW file (used by tests and the `tqm export` path).
pub fn write_tqw(path: impl AsRef<Path>, tensors: &BTreeMap<String, TqwTensor>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        let (dtype, shape): (u8, &[usize]) = match t {
            TqwTensor::F32(t) => (0, &t.shape),
            TqwTensor::U8(t) => (1, &t.shape),
            TqwTensor::I32 { shape, .. } => (2, shape),
        };
        f.write_all(&[dtype, shape.len() as u8])?;
        for d in shape {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        match t {
            TqwTensor::F32(t) => {
                for v in &t.data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            TqwTensor::U8(t) => f.write_all(&t.data)?,
            TqwTensor::I32 { data, .. } => {
                for v in data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BTreeMap<String, TqwTensor> {
        let mut m = BTreeMap::new();
        m.insert(
            "w".into(),
            TqwTensor::F32(Tensor::new(vec![2, 3], vec![1., -2., 3.5, 0., 1e-9, 7.]).unwrap()),
        );
        m.insert(
            "codes".into(),
            TqwTensor::U8(U8Tensor::new(vec![4], vec![0, 127, 255, 3]).unwrap()),
        );
        m.insert(
            "ids".into(),
            TqwTensor::I32 { shape: vec![2], data: vec![-5, 9] },
        );
        m
    }

    #[test]
    fn roundtrip() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("x.tqw");
        let m = sample();
        write_tqw(&p, &m).unwrap();
        let got = read_tqw(&p).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got["w"].as_f32().unwrap(), m["w"].as_f32().unwrap());
        match (&got["codes"], &m["codes"]) {
            (TqwTensor::U8(a), TqwTensor::U8(b)) => assert_eq!(a, b),
            _ => panic!(),
        }
        match &got["ids"] {
            TqwTensor::I32 { data, .. } => assert_eq!(data, &vec![-5, 9]),
            _ => panic!(),
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("bad.tqw");
        std::fs::write(&p, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(read_tqw(&p).is_err());
    }

    #[test]
    fn empty_file_ok() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("e.tqw");
        write_tqw(&p, &BTreeMap::new()).unwrap();
        assert!(read_tqw(&p).unwrap().is_empty());
    }
}
