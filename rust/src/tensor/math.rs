//! The small dense linear algebra GPTQ needs: Gram accumulation, Cholesky,
//! and triangular inversion. Dimensions are bounded by the largest layer
//! input (d_ff = 2064 for proxy-3b), so straightforward cache-friendly
//! loops are plenty; the serving hot path never touches this module.

use anyhow::{bail, Result};

/// Accumulate `g += x^T x` for a batch of rows. `x` is row-major `[n, k]`,
/// `g` is row-major `[k, k]`.
pub fn gram_accumulate(g: &mut [f64], x: &[f32], k: usize) {
    assert_eq!(g.len(), k * k);
    assert_eq!(x.len() % k, 0);
    for row in x.chunks_exact(k) {
        for i in 0..k {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            let gi = &mut g[i * k..(i + 1) * k];
            for (gij, &xj) in gi.iter_mut().zip(row.iter()) {
                *gij += xi * xj as f64;
            }
        }
    }
}

/// In-place lower Cholesky factorization of a symmetric positive-definite
/// row-major `[n, n]` matrix. Returns an error if the matrix is not PD
/// (callers add damping and retry).
pub fn cholesky_in_place(a: &mut [f64], n: usize) -> Result<()> {
    assert_eq!(a.len(), n * n);
    for j in 0..n {
        let mut d = a[j * n + j];
        for k in 0..j {
            let l = a[j * n + k];
            d -= l * l;
        }
        if d <= 0.0 {
            bail!("matrix not positive definite at pivot {j} (d = {d:.3e})");
        }
        let d = d.sqrt();
        a[j * n + j] = d;
        for i in (j + 1)..n {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = s / d;
        }
        // zero the upper triangle for cleanliness
        for i in 0..j {
            a[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Solve `L y = b` in place for lower-triangular `L` (row-major `[n, n]`).
pub fn forward_substitute(l: &[f64], b: &mut [f64], n: usize) {
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * b[k];
        }
        b[i] = s / l[i * n + i];
    }
}

/// Solve `L^T y = b` in place for lower-triangular `L`.
pub fn backward_substitute_t(l: &[f64], b: &mut [f64], n: usize) {
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in (i + 1)..n {
            s -= l[k * n + i] * b[k];
        }
        b[i] = s / l[i * n + i];
    }
}

/// Full inverse of an SPD matrix via its Cholesky factor: `a` row-major
/// `[n, n]`, overwritten with `a^{-1}`. Used by GPTQ to obtain `H^{-1}`.
pub fn spd_inverse(a: &mut Vec<f64>, n: usize) -> Result<()> {
    let mut l = a.clone();
    cholesky_in_place(&mut l, n)?;
    let mut inv = vec![0.0f64; n * n];
    let mut col = vec![0.0f64; n];
    for j in 0..n {
        col.iter_mut().for_each(|c| *c = 0.0);
        col[j] = 1.0;
        forward_substitute(&l, &mut col, n);
        backward_substitute_t(&l, &mut col, n);
        for i in 0..n {
            inv[i * n + j] = col[i];
        }
    }
    *a = inv;
    Ok(())
}

/// Upper Cholesky factor of the *inverse* of an SPD matrix — the exact
/// object the GPTQ recurrence consumes (`Hinv = U^T U`, it uses `U`).
pub fn cholesky_inverse_upper(mut h: Vec<f64>, n: usize) -> Result<Vec<f64>> {
    spd_inverse(&mut h, n)?;
    // upper factor of Hinv = transpose of lower factor of Hinv
    cholesky_in_place(&mut h, n)?;
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = h[i * n + j];
        }
    }
    Ok(u)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matmul(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
        let mut c = vec![0.0; n * n];
        for i in 0..n {
            for k in 0..n {
                let aik = a[i * n + k];
                for j in 0..n {
                    c[i * n + j] += aik * b[k * n + j];
                }
            }
        }
        c
    }

    fn random_spd(n: usize, seed: u64) -> Vec<f64> {
                let mut rng = crate::util::Rng::seed_from_u64(seed);
        let m: Vec<f64> = (0..n * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut a = vec![0.0; n * n];
        // a = m m^T + n * I
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let n = 8;
        let a = random_spd(n, 1);
        let mut l = a.clone();
        cholesky_in_place(&mut l, n).unwrap();
        // L L^T == A
        let mut lt = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                lt[i * n + j] = l[j * n + i];
            }
        }
        let rec = matmul(&l, &lt, n);
        for (x, y) in rec.iter().zip(&a) {
            assert!((x - y).abs() < 1e-9, "{x} vs {y}");
        }
    }

    #[test]
    fn cholesky_rejects_non_pd() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky_in_place(&mut a, 2).is_err());
    }

    #[test]
    fn spd_inverse_identity() {
        let n = 6;
        let a = random_spd(n, 2);
        let mut inv = a.clone();
        spd_inverse(&mut inv, n).unwrap();
        let prod = matmul(&a, &inv, n);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[i * n + j] - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn cholesky_inverse_upper_factorizes_inverse() {
        let n = 5;
        let a = random_spd(n, 3);
        let mut inv = a.clone();
        spd_inverse(&mut inv, n).unwrap();
        let u = cholesky_inverse_upper(a, n).unwrap();
        // U^T U == inv
        let mut ut = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                ut[i * n + j] = u[j * n + i];
            }
        }
        let rec = matmul(&ut, &u, n);
        for (x, y) in rec.iter().zip(&inv) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn gram_matches_naive() {
        let k = 4;
        let x: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 2.0).collect();
        let mut g = vec![0.0f64; k * k];
        gram_accumulate(&mut g, &x, k);
        for i in 0..k {
            for j in 0..k {
                let mut want = 0.0f64;
                for r in 0..3 {
                    want += x[r * k + i] as f64 * x[r * k + j] as f64;
                }
                assert!((g[i * k + j] - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn triangular_solves() {
        let n = 3;
        let l = vec![2.0, 0.0, 0.0, 1.0, 3.0, 0.0, 0.5, -1.0, 1.5];
        let mut b = vec![4.0, 7.0, 2.0];
        forward_substitute(&l, &mut b, n);
        // check L b' == [4,7,2]
        assert!((2.0 * b[0] - 4.0).abs() < 1e-12);
        assert!((1.0 * b[0] + 3.0 * b[1] - 7.0).abs() < 1e-12);
        assert!((0.5 * b[0] - 1.0 * b[1] + 1.5 * b[2] - 2.0).abs() < 1e-12);
    }
}
