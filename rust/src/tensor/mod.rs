//! Minimal dense-tensor substrate (S1).
//!
//! The coordinator needs just enough tensor machinery to quantize, measure
//! and ship weights: shaped `f32` / `u8` buffers, the TQW reader for the
//! python-trained checkpoints ([`io`]), and the small amount of linear
//! algebra GPTQ needs ([`math`]). Heavy compute belongs to the XLA
//! executables, not here.

pub mod io;
pub mod math;

use anyhow::{bail, Result};

/// Dense f32 tensor, C-order.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Dense u8 tensor, C-order (quantized codes, raw byte streams).
#[derive(Clone, Debug, PartialEq)]
pub struct U8Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        if numel(&shape) != data.len() {
            bail!("shape {:?} != data len {}", shape, data.len());
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = numel(&shape);
        Self { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Number of rows / row length for a 2-D view (errors otherwise).
    pub fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [r, c] => Ok((*r, *c)),
            s => bail!("expected 2-D tensor, got {:?}", s),
        }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let (_, c) = (self.shape[0], self.shape[1]);
        &self.data[r * c..(r + 1) * c]
    }

    /// Mean squared difference against another tensor of the same shape.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1) as f64;
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / n
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

impl U8Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<u8>) -> Result<Self> {
        if numel(&shape) != data.len() {
            bail!("shape {:?} != data len {}", shape, data.len());
        }
        Ok(Self { shape, data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(U8Tensor::new(vec![4], vec![0; 3]).is_err());
    }

    #[test]
    fn zeros_and_numel() {
        let t = Tensor::zeros(vec![3, 4]);
        assert_eq!(t.numel(), 12);
        assert_eq!(t.dims2().unwrap(), (3, 4));
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn mse_basics() {
        let a = Tensor::new(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![2], vec![1.0, 4.0]).unwrap();
        assert!((a.mse(&b) - 2.0).abs() < 1e-12);
        assert_eq!(a.mse(&a), 0.0);
    }

    #[test]
    fn row_view() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }
}
