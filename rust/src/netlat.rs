//! Network-latency simulator (S15): the paper's §5 aside measures a 697 ms
//! round trip to a hosted LLM ("I used the developer tools to measure
//! latency on safari") and argues on-device decompression beats it.
//! We make that comparison reproducible: a parameterized RTT model
//! (lognormal body + tail spikes, the standard shape for WAN latency)
//! against the measured local per-question / per-token latencies (E7).

use crate::util::{stats, Rng};

/// Round-trip model for a hosted-LLM request.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// Median round trip (seconds).
    pub median_s: f64,
    /// Lognormal sigma (spread of the body).
    pub sigma: f64,
    /// Probability of a tail event (retransmit / congestion).
    pub tail_p: f64,
    /// Multiplier applied on tail events.
    pub tail_mult: f64,
}

impl NetworkModel {
    /// Defaults anchored to the paper's 697 ms observation.
    pub fn paper_chatgpt() -> Self {
        Self { median_s: 0.697, sigma: 0.25, tail_p: 0.03, tail_mult: 3.5 }
    }

    /// A fast-fiber best case (stress-tests the paper's claim).
    pub fn fast_fiber() -> Self {
        Self { median_s: 0.120, sigma: 0.15, tail_p: 0.01, tail_mult: 2.0 }
    }

    /// Mobile / LTE worst case.
    pub fn mobile_lte() -> Self {
        Self { median_s: 1.100, sigma: 0.45, tail_p: 0.08, tail_mult: 4.0 }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let body = self.median_s * (self.sigma * rng.normal()).exp();
        if rng.gen_bool(self.tail_p) {
            body * self.tail_mult
        } else {
            body
        }
    }

    /// Monte-Carlo summary over `n` samples: (mean, p50, p95, p99).
    pub fn summarize(&self, n: usize, seed: u64) -> LatencySummary {
        let mut rng = Rng::seed_from_u64(seed);
        let mut xs: Vec<f64> = (0..n).map(|_| self.sample(&mut rng)).collect();
        // util::stats sorts with total_cmp: a degenerate model (sigma/tail
        // NaNs) must produce a garbage summary, not a panic mid-table
        let s = stats::summarize(&mut xs);
        LatencySummary { mean_s: s.mean, p50_s: s.p50, p95_s: s.p95, p99_s: s.p99 }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct LatencySummary {
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

/// The E7 comparison: how many local decode steps / decompression passes
/// fit inside one network round trip.
pub fn round_trips_worth(local_latency_s: f64, net: &LatencySummary) -> f64 {
    if local_latency_s <= 0.0 {
        return f64::INFINITY;
    }
    net.p50_s / local_latency_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_roughly_anchored() {
        let m = NetworkModel::paper_chatgpt();
        let s = m.summarize(20_000, 1);
        assert!((s.p50_s - 0.697).abs() < 0.05, "p50 {}", s.p50_s);
        assert!(s.p95_s > s.p50_s);
        assert!(s.p99_s >= s.p95_s);
    }

    #[test]
    fn deterministic_given_seed() {
        let m = NetworkModel::mobile_lte();
        let a = m.summarize(1000, 7);
        let b = m.summarize(1000, 7);
        assert_eq!(a.p50_s, b.p50_s);
    }

    #[test]
    fn percentiles_monotone_across_models_and_seeds() {
        // p50 <= p95 <= p99 (and mean positive) must hold for every model
        // shape and any seed — percentile extraction is order statistics,
        // not luck
        let models = [
            NetworkModel::paper_chatgpt(),
            NetworkModel::fast_fiber(),
            NetworkModel::mobile_lte(),
        ];
        for m in &models {
            for seed in 0..25u64 {
                let s = m.summarize(2000, seed);
                assert!(s.mean_s > 0.0, "seed {seed}");
                assert!(s.p50_s > 0.0, "seed {seed}");
                assert!(s.p50_s <= s.p95_s, "seed {seed}: p50 {} > p95 {}", s.p50_s, s.p95_s);
                assert!(s.p95_s <= s.p99_s, "seed {seed}: p95 {} > p99 {}", s.p95_s, s.p99_s);
            }
        }
    }

    #[test]
    fn fixed_seed_summaries_bit_identical() {
        // not just "close": every field of the summary must be the exact
        // same f64 bits run to run, for each model
        for (i, m) in [
            NetworkModel::paper_chatgpt(),
            NetworkModel::fast_fiber(),
            NetworkModel::mobile_lte(),
        ]
        .iter()
        .enumerate()
        {
            let seed = 1000 + i as u64;
            let a = m.summarize(5000, seed);
            let b = m.summarize(5000, seed);
            assert_eq!(a.mean_s.to_bits(), b.mean_s.to_bits());
            assert_eq!(a.p50_s.to_bits(), b.p50_s.to_bits());
            assert_eq!(a.p95_s.to_bits(), b.p95_s.to_bits());
            assert_eq!(a.p99_s.to_bits(), b.p99_s.to_bits());
            // a different seed moves at least one statistic
            let c = m.summarize(5000, seed + 1);
            assert!(
                a.mean_s.to_bits() != c.mean_s.to_bits()
                    || a.p99_s.to_bits() != c.p99_s.to_bits(),
                "model {i}: different seeds produced identical summaries"
            );
        }
    }

    #[test]
    fn tail_probability_and_multiplier_widen_p99_not_p50() {
        // the tail knobs must do what the docs claim: lift the far tail
        // while leaving the median essentially untouched
        let base = NetworkModel { tail_p: 0.0, ..NetworkModel::paper_chatgpt() };
        let spiky = NetworkModel { tail_p: 0.05, ..base.clone() };
        let spikier = NetworkModel { tail_p: 0.05, tail_mult: 8.0, ..base.clone() };
        let n = 40_000;
        let b = base.summarize(n, 13);
        let s1 = spiky.summarize(n, 13);
        let s2 = spikier.summarize(n, 13);
        assert!(s1.p99_s > b.p99_s, "tail events must widen p99");
        assert!(s2.p99_s > s1.p99_s, "a larger multiplier must widen p99 further");
        // median moves by at most a few percent (5% of samples are tails)
        assert!((s1.p50_s - b.p50_s).abs() / b.p50_s < 0.05);
    }

    #[test]
    fn round_trips_worth_math() {
        let s = LatencySummary { mean_s: 0.7, p50_s: 0.7, p95_s: 1.0, p99_s: 1.5 };
        assert!((round_trips_worth(0.07, &s) - 10.0).abs() < 1e-9);
    }
}
