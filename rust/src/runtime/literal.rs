//! Tensor <-> xla::Literal conversions for the stage argument contract.

use anyhow::Result;

use crate::tensor::Tensor;
use crate::xla;

fn as_i64(dims: &[usize]) -> Vec<i64> {
    dims.iter().map(|&d| d as i64).collect()
}

pub fn f32_literal(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    anyhow::ensure!(crate::tensor::numel(dims) == data.len(), "shape/data mismatch");
    let lit = xla::Literal::vec1(data);
    lit.reshape(&as_i64(dims))
        .map_err(|e| anyhow::anyhow!("reshape f32 literal: {e}"))
}

pub fn u8_literal(dims: &[usize], data: &[u8]) -> Result<xla::Literal> {
    anyhow::ensure!(crate::tensor::numel(dims) == data.len(), "shape/data mismatch");
    // u8 implements ArrayElement but not NativeType, so go via raw bytes
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, dims, data)
        .map_err(|e| anyhow::anyhow!("create u8 literal: {e}"))
}

pub fn i32_literal(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    anyhow::ensure!(crate::tensor::numel(dims) == data.len(), "shape/data mismatch");
    let lit = xla::Literal::vec1(data);
    lit.reshape(&as_i64(dims))
        .map_err(|e| anyhow::anyhow!("reshape i32 literal: {e}"))
}

pub fn tensor_literal(t: &Tensor) -> Result<xla::Literal> {
    f32_literal(&t.shape, &t.data)
}

pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to f32 vec: {e}"))
}

pub fn literal_shape(lit: &xla::Literal) -> Result<Vec<usize>> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
    Ok(shape.dims().iter().map(|&d| d as usize).collect())
}

pub fn to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    Ok(Tensor { shape: literal_shape(lit)?, data: to_f32_vec(lit)? })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.0, 0.0, 5.5, 9.0];
        let lit = f32_literal(&[2, 3], &data).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
        assert_eq!(literal_shape(&lit).unwrap(), vec![2, 3]);
    }

    #[test]
    fn u8_roundtrip() {
        let data = vec![0u8, 127, 255, 1];
        let lit = u8_literal(&[4], &data).unwrap();
        assert_eq!(lit.to_vec::<u8>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[2, 2], &[1.0; 3]).is_err());
        assert!(u8_literal(&[5], &[0; 4]).is_err());
        assert!(i32_literal(&[1], &[]).is_err());
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(vec![3, 2], vec![0.5; 6]).unwrap();
        let lit = tensor_literal(&t).unwrap();
        assert_eq!(to_tensor(&lit).unwrap(), t);
    }
}
