//! PJRT runtime (S8): loads the AOT-lowered HLO text stages and executes
//! them on the CPU PJRT client. This is the only place the `xla` crate is
//! touched; everything above deals in `Tensor`/`Literal` conversions from
//! [`literal`].
//!
//! Executables are compiled once per (stage, batch, seq) geometry and
//! cached — compilation is ~100 ms-scale, the decode hot loop must never
//! pay it.

pub mod literal;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::config::Manifest;
use crate::xla;

/// Whether this build can actually execute stages (the `pjrt` feature).
/// Runtime-gated tests combine this with the artifacts-present check so
/// they skip rather than panic on stub builds that do have artifacts.
pub fn backend_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Key into the executable cache.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct StageKey {
    pub stage: String,
    pub b: usize,
    pub t: usize,
}

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    model_dir: PathBuf,
    cache: Mutex<HashMap<StageKey, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// executables compiled (for metrics / tests)
    compiled: Mutex<usize>,
}

impl Runtime {
    pub fn new(artifacts_root: impl Into<PathBuf>, model: &str) -> Result<Self> {
        let root = artifacts_root.into();
        let manifest = Manifest::load(&root, model)?;
        let model_dir = manifest.model_dir(&root);
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            model_dir,
            cache: Mutex::new(HashMap::new()),
            compiled: Mutex::new(0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn n_compiled(&self) -> usize {
        *self.compiled.lock().unwrap()
    }

    /// Get (compiling + caching on first use) the executable for a stage
    /// geometry. The geometry must exist in the manifest.
    pub fn executable(
        &self,
        stage: &str,
        b: usize,
        t: usize,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = StageKey { stage: stage.to_string(), b, t };
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let entry = self
            .manifest
            .stage(stage, b, t)
            .ok_or_else(|| anyhow::anyhow!("no lowered geometry {stage} b={b} t={t}"))?;
        let path = self.model_dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e}"))?;
        let exe = std::sync::Arc::new(exe);
        *self.compiled.lock().unwrap() += 1;
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute a stage with literal inputs, returning output literals
    /// (the lowered functions always return a tuple; it is flattened here).
    pub fn run(
        &self,
        stage: &str,
        b: usize,
        t: usize,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = args.iter().collect();
        self.run_refs(stage, b, t, &refs)
    }

    /// Borrowed-argument variant: lets callers keep big weight literals
    /// cached across calls instead of re-creating them (§Perf change 1/2).
    pub fn run_refs(
        &self,
        stage: &str,
        b: usize,
        t: usize,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(stage, b, t)?;
        let result = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing {stage} b={b} t={t}: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e}"))?;
        let parts = out
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result: {e}"))?;
        Ok(parts)
    }

    /// Warm the cache for every geometry a serving session will touch.
    pub fn warmup(&self, stages: &[(&str, usize, usize)]) -> Result<()> {
        for (stage, b, t) in stages {
            self.executable(stage, *b, *t)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_artifacts_root;

    fn runtime() -> Option<Runtime> {
        if !backend_available() {
            eprintln!("skipping: pjrt backend not compiled in");
            return None;
        }
        let root = default_artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new(root, "tiny").unwrap())
    }

    #[test]
    fn compiles_and_caches() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.n_compiled(), 0);
        let _e1 = rt.executable("embed", 1, 16).unwrap();
        assert_eq!(rt.n_compiled(), 1);
        let _e2 = rt.executable("embed", 1, 16).unwrap();
        assert_eq!(rt.n_compiled(), 1, "second fetch must hit the cache");
    }

    #[test]
    fn unknown_geometry_rejected() {
        let Some(rt) = runtime() else { return };
        assert!(rt.executable("embed", 99, 7).is_err());
        assert!(rt.executable("bogus", 1, 16).is_err());
    }

    #[test]
    fn embed_stage_executes() {
        let Some(rt) = runtime() else { return };
        let cfg = rt.manifest.config.clone();
        let (v, d) = (cfg.vocab, cfg.d_model);
        let tokens = vec![3i32; 16];
        let table = vec![128u8; v * d];
        let scale = vec![0.01f32; v];
        let zero = vec![128.0f32; v];
        let args = vec![
            literal::i32_literal(&[1, 16], &tokens).unwrap(),
            literal::u8_literal(&[v, d], &table).unwrap(),
            literal::f32_literal(&[v], &scale).unwrap(),
            literal::f32_literal(&[v], &zero).unwrap(),
        ];
        let out = rt.run("embed", 1, 16, &args).unwrap();
        assert_eq!(out.len(), 1);
        let h = literal::to_f32_vec(&out[0]).unwrap();
        assert_eq!(h.len(), 16 * d);
        // (128 - 128) * 0.01 == 0 everywhere
        assert!(h.iter().all(|&x| x == 0.0));
    }
}
