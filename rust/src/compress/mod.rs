//! Lossless compression substrate (S4-S6): the paper's §2.2 / §4.
//!
//! * [`freqseq`] — the paper's actual codec (§4): a static dictionary of
//!   frequent fixed-length byte sequences with u16 codewords and an 0xFFFF
//!   escape. Two variants: `FreqSeq` is bit-faithful to the paper's
//!   listings (escaped raw bytes stored as u16 — yes, that expands), and
//!   `FreqSeqPacked` fixes the escape encoding (our ablation).
//! * [`lzw`] — LZW with variable-width codes (§2.2 names LZW as the
//!   schema family the paper builds on).
//! * [`huffman`] — canonical Huffman: the entropy-coding baseline that
//!   calibrates how much any dictionary scheme can possibly win.
//! * [`rle`], [`raw`] — trivial baselines.
//!
//! All codecs implement [`Codec`] and are **lossless**; property tests in
//! each module plus `rust/tests/proptest_compress.rs` enforce exact
//! roundtrips, because Tables 2-4's "Compressed" rows being identical to
//! "Quantized" accuracy depends on it.

pub mod freqseq;
pub mod huffman;
pub mod lzw;
pub mod raw;
pub mod rle;
pub mod stream;
pub mod stats;

use anyhow::Result;

/// Stable on-disk codec identifiers (TQM container field).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CodecId {
    Raw = 0,
    Rle = 1,
    Lzw = 2,
    Huffman = 3,
    /// Paper-faithful frequent-sequence table (§4 listings).
    FreqSeq = 4,
    /// Frequent-sequence table with packed escapes (our fix).
    FreqSeqPacked = 5,
}

impl CodecId {
    pub fn from_u32(v: u32) -> Result<Self> {
        Ok(match v {
            0 => CodecId::Raw,
            1 => CodecId::Rle,
            2 => CodecId::Lzw,
            3 => CodecId::Huffman,
            4 => CodecId::FreqSeq,
            5 => CodecId::FreqSeqPacked,
            _ => anyhow::bail!("unknown codec id {v}"),
        })
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "raw" => CodecId::Raw,
            "rle" => CodecId::Rle,
            "lzw" => CodecId::Lzw,
            "huffman" => CodecId::Huffman,
            "freqseq" => CodecId::FreqSeq,
            "freqseq-packed" => CodecId::FreqSeqPacked,
            _ => anyhow::bail!("unknown codec {s:?} (raw|rle|lzw|huffman|freqseq|freqseq-packed)"),
        })
    }
}

/// A lossless byte-stream codec with an optional model-global trained
/// dictionary. `train` sees sample streams (the model's quantized tensors)
/// and returns a serialized dictionary that `compress`/`decompress` share;
/// adaptive codecs return an empty dict.
pub trait Codec: Send + Sync {
    fn id(&self) -> CodecId;
    fn name(&self) -> &'static str;

    /// Build the shared dictionary from sample streams (may be empty).
    fn train(&self, samples: &[&[u8]]) -> Vec<u8>;

    /// Compress one stream under the trained dictionary.
    fn compress(&self, dict: &[u8], data: &[u8]) -> Result<Vec<u8>>;

    /// Decompress into `out` (cleared first); `expected_len` is the
    /// original stream length (stored by the container).
    fn decompress(
        &self,
        dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        out: &mut Vec<u8>,
    ) -> Result<()>;
}

pub fn codec(id: CodecId) -> Box<dyn Codec> {
    match id {
        CodecId::Raw => Box::new(raw::Raw),
        CodecId::Rle => Box::new(rle::Rle),
        CodecId::Lzw => Box::new(lzw::Lzw::default()),
        CodecId::Huffman => Box::new(huffman::Huffman),
        CodecId::FreqSeq => Box::new(freqseq::FreqSeq::paper()),
        CodecId::FreqSeqPacked => Box::new(freqseq::FreqSeq::packed()),
    }
}

pub fn all_codec_ids() -> [CodecId; 6] {
    [
        CodecId::Raw,
        CodecId::Rle,
        CodecId::Lzw,
        CodecId::Huffman,
        CodecId::FreqSeq,
        CodecId::FreqSeqPacked,
    ]
}

#[cfg(test)]
pub(crate) mod testutil {
    
    /// Byte streams with the regimes the codecs must handle: empty, tiny,
    /// constant, repetitive, quantized-gaussian-like, uniform-random.
    pub fn regimes() -> Vec<(&'static str, Vec<u8>)> {
        let mut rng = crate::util::Rng::seed_from_u64(42);
        let gauss: Vec<u8> = (0..20_000)
            .map(|_| (128.0 + 20.0 * rng.normal_f32()).clamp(0.0, 255.0) as u8)
            .collect();
        let uniform: Vec<u8> = (0..20_000).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let repetitive: Vec<u8> =
            (0..20_000).map(|i| [1u8, 2, 3, 4, 1, 2, 3, 4, 9, 9][i % 10]).collect();
        vec![
            ("empty", vec![]),
            ("one", vec![7]),
            ("three", vec![1, 2, 3]),
            ("constant", vec![88; 5000]),
            ("repetitive", repetitive),
            ("gauss8bit", gauss),
            ("uniform", uniform),
        ]
    }

    pub fn roundtrip_all_regimes(c: &dyn super::Codec) {
        let regs = regimes();
        let samples: Vec<&[u8]> = regs.iter().map(|(_, d)| d.as_slice()).collect();
        let dict = c.train(&samples);
        for (name, data) in &regs {
            let payload = c.compress(&dict, data).unwrap();
            let mut out = Vec::new();
            c.decompress(&dict, &payload, data.len(), &mut out).unwrap();
            assert_eq!(&out, data, "codec {} failed roundtrip on {name}", c.name());
        }
    }
}
