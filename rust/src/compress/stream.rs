//! Chunked compression (S4 extension): split a stream into fixed-size
//! chunks compressed independently, with a chunk index.
//!
//! Two serving-relevant properties the flat codecs lack:
//!
//! * **bounded decode memory / partial access** — a layer's codes can be
//!   decompressed range-by-range (the paper's phones have little headroom
//!   even for one layer);
//! * **parallel decode** — chunks are independent, so a multicore device
//!   can decompress with `std::thread::scope` fan-out (on this repo's
//!   1-vCPU testbed the parallel path degrades gracefully to serial).
//!
//! Framing: `u32 n_chunks | u32 chunk_len | n_chunks * (u64 offset into
//! payload, u64 raw_len)` then the concatenated chunk payloads.

use anyhow::Result;

use super::Codec;

pub const DEFAULT_CHUNK: usize = 256 * 1024;

/// Parsed, bounds-validated view of a chunk-framed payload's index.
///
/// All the decode paths (serial, range, parallel, and the TQM reader's
/// per-tensor fan-out) go through [`parse_chunk_index`], so a corrupt
/// index is rejected in one place before any body slicing happens.
#[derive(Clone, Debug)]
pub struct ChunkIndex {
    /// Per chunk: (byte offset into the body, uncompressed length).
    pub entries: Vec<(usize, usize)>,
    /// Uncompressed bytes per chunk (last chunk may be shorter).
    pub chunk_len: usize,
    /// Offset of the body (first chunk's compressed bytes) in the payload.
    pub body_start: usize,
}

impl ChunkIndex {
    /// The concatenated compressed chunk payloads.
    pub fn body<'a>(&self, payload: &'a [u8]) -> &'a [u8] {
        &payload[self.body_start..]
    }

    /// End offset (into the body) of chunk `i`'s compressed bytes.
    pub fn chunk_end(&self, i: usize, body_len: usize) -> usize {
        self.entries.get(i + 1).map(|&(o, _)| o).unwrap_or(body_len)
    }

    /// Total uncompressed length across all chunks.
    pub fn raw_len(&self) -> usize {
        self.entries.iter().map(|&(_, l)| l).sum()
    }
}

/// Parse and validate the chunk index of a chunk-framed payload.
///
/// Validation covers everything the decode loops assume: header and index
/// fit in the payload, chunk offsets are monotonically non-decreasing, and
/// every offset lands inside the body — so `body[off..end]` can never
/// slice out of bounds on a corrupt index (serial, range, or parallel).
pub fn parse_chunk_index(payload: &[u8]) -> Result<ChunkIndex> {
    anyhow::ensure!(payload.len() >= 8, "chunked: truncated header");
    let n = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
    let chunk_len = u32::from_le_bytes(payload[4..8].try_into().unwrap()) as usize;
    let idx_end = 8usize
        .checked_add(n.checked_mul(16).ok_or_else(|| anyhow::anyhow!("chunked: huge index"))?)
        .ok_or_else(|| anyhow::anyhow!("chunked: huge index"))?;
    anyhow::ensure!(payload.len() >= idx_end, "chunked: truncated index");
    anyhow::ensure!(n == 0 || chunk_len > 0, "chunked: zero chunk_len with {n} chunks");
    let body_len = payload.len() - idx_end;
    let mut entries = Vec::with_capacity(n);
    let mut prev = 0usize;
    for i in 0..n {
        let off = 8 + i * 16;
        let o = u64::from_le_bytes(payload[off..off + 8].try_into().unwrap()) as usize;
        let l = u64::from_le_bytes(payload[off + 8..off + 16].try_into().unwrap()) as usize;
        anyhow::ensure!(o >= prev, "chunked: non-monotone chunk offset {o} < {prev}");
        anyhow::ensure!(o <= body_len, "chunked: chunk offset {o} beyond body ({body_len})");
        // bound the decode-side allocation: no chunk expands past chunk_len
        anyhow::ensure!(
            l <= chunk_len,
            "chunked: chunk raw_len {l} exceeds chunk_len {chunk_len}"
        );
        prev = o;
        entries.push((o, l));
    }
    Ok(ChunkIndex { entries, chunk_len, body_start: idx_end })
}

pub struct Chunked<'a> {
    pub inner: &'a dyn Codec,
    pub chunk_len: usize,
}

impl<'a> Chunked<'a> {
    pub fn new(inner: &'a dyn Codec) -> Self {
        Self { inner, chunk_len: DEFAULT_CHUNK }
    }

    pub fn with_chunk_len(mut self, n: usize) -> Self {
        assert!(n > 0);
        self.chunk_len = n;
        self
    }

    pub fn compress(&self, dict: &[u8], data: &[u8]) -> Result<Vec<u8>> {
        let chunks: Vec<&[u8]> = data.chunks(self.chunk_len.max(1)).collect();
        let mut payloads = Vec::with_capacity(chunks.len());
        for c in &chunks {
            payloads.push(self.inner.compress(dict, c)?);
        }
        let mut out = Vec::new();
        out.extend_from_slice(&(chunks.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.chunk_len as u32).to_le_bytes());
        let mut offset = 0u64;
        for (c, p) in chunks.iter().zip(&payloads) {
            out.extend_from_slice(&offset.to_le_bytes());
            out.extend_from_slice(&(c.len() as u64).to_le_bytes());
            offset += p.len() as u64;
        }
        for p in &payloads {
            out.extend_from_slice(p);
        }
        Ok(out)
    }

    pub fn decompress(
        &self,
        dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        let idx = parse_chunk_index(payload)?;
        let body = idx.body(payload);
        out.clear();
        out.reserve(expected_len);
        let mut scratch = Vec::new();
        for (i, &(off, raw_len)) in idx.entries.iter().enumerate() {
            let end = idx.chunk_end(i, body.len());
            self.inner.decompress(dict, &body[off..end], raw_len, &mut scratch)?;
            out.extend_from_slice(&scratch);
        }
        anyhow::ensure!(out.len() == expected_len, "chunked: length mismatch");
        Ok(())
    }

    /// Decompress only the chunks covering byte range [start, start+len) —
    /// the partial-access primitive. Returns (bytes, offset of range start
    /// within them).
    pub fn decompress_range(
        &self,
        dict: &[u8],
        payload: &[u8],
        start: usize,
        len: usize,
    ) -> Result<(Vec<u8>, usize)> {
        let idx = parse_chunk_index(payload)?;
        let body = idx.body(payload);
        anyhow::ensure!(idx.chunk_len > 0, "chunked: zero chunk_len");
        let first = start / idx.chunk_len;
        let last = (start + len).saturating_sub(1) / idx.chunk_len;
        anyhow::ensure!(last < idx.entries.len(), "chunked: range beyond stream");
        let mut out = Vec::new();
        let mut scratch = Vec::new();
        for i in first..=last {
            let (off, raw_len) = idx.entries[i];
            let end = idx.chunk_end(i, body.len());
            self.inner.decompress(dict, &body[off..end], raw_len, &mut scratch)?;
            out.extend_from_slice(&scratch);
        }
        Ok((out, start - first * idx.chunk_len))
    }

    /// Parallel decompression across chunks using scoped threads.
    pub fn decompress_parallel(
        &self,
        dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        n_threads: usize,
    ) -> Result<Vec<u8>>
    where
        Self: Sync,
    {
        let idx = parse_chunk_index(payload)?;
        let body = idx.body(payload);
        let n = idx.entries.len();
        if n == 0 {
            anyhow::ensure!(expected_len == 0, "chunked: empty payload");
            return Ok(Vec::new());
        }
        let mut results: Vec<Result<Vec<u8>>> = (0..n).map(|_| Ok(Vec::new())).collect();
        let threads = n_threads.clamp(1, n);
        let stride = (n + threads - 1) / threads;
        std::thread::scope(|s| {
            for (tid, slot_chunk) in results.chunks_mut(stride).enumerate() {
                let idx = &idx;
                let inner = self.inner;
                s.spawn(move || {
                    for (j, slot) in slot_chunk.iter_mut().enumerate() {
                        let i = tid * stride + j;
                        let (off, raw_len) = idx.entries[i];
                        let end = idx.chunk_end(i, body.len());
                        let mut buf = Vec::new();
                        *slot = inner
                            .decompress(dict, &body[off..end], raw_len, &mut buf)
                            .map(|_| buf);
                    }
                });
            }
        });
        let mut out = Vec::with_capacity(expected_len);
        for r in results {
            out.extend_from_slice(&r?);
        }
        anyhow::ensure!(out.len() == expected_len, "chunked: length mismatch");
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{codec, CodecId};
    use crate::util::Rng;

    fn sample(n: usize) -> Vec<u8> {
        let mut rng = Rng::seed_from_u64(1);
        (0..n).map(|_| (128.0 + 20.0 * rng.normal_f32()).clamp(0.0, 255.0) as u8).collect()
    }

    #[test]
    fn roundtrip_all_codecs_and_sizes() {
        for id in crate::compress::all_codec_ids() {
            let inner = codec(id);
            let ch = Chunked::new(inner.as_ref()).with_chunk_len(1000);
            for n in [0usize, 1, 999, 1000, 1001, 5000] {
                let data = sample(n);
                let dict = inner.train(&[&data]);
                let payload = ch.compress(&dict, &data).unwrap();
                let mut out = Vec::new();
                ch.decompress(&dict, &payload, n, &mut out).unwrap();
                assert_eq!(out, data, "{id:?} n={n}");
            }
        }
    }

    #[test]
    fn range_access() {
        let inner = codec(CodecId::Huffman);
        let ch = Chunked::new(inner.as_ref()).with_chunk_len(512);
        let data = sample(4096);
        let dict = inner.train(&[&data]);
        let payload = ch.compress(&dict, &data).unwrap();
        for (start, len) in [(0usize, 10usize), (500, 100), (1000, 2000), (4000, 96)] {
            let (bytes, off) = ch.decompress_range(&dict, &payload, start, len).unwrap();
            assert_eq!(&bytes[off..off + len], &data[start..start + len]);
        }
        assert!(ch.decompress_range(&dict, &payload, 4095, 100).is_err());
    }

    #[test]
    fn parallel_matches_serial() {
        let inner = codec(CodecId::Lzw);
        let ch = Chunked::new(inner.as_ref()).with_chunk_len(777);
        let data = sample(10_000);
        let dict = inner.train(&[&data]);
        let payload = ch.compress(&dict, &data).unwrap();
        for threads in [1usize, 2, 4, 16] {
            let got = ch.decompress_parallel(&dict, &payload, data.len(), threads).unwrap();
            assert_eq!(got, data, "threads={threads}");
        }
    }

    #[test]
    fn corrupt_index_rejected() {
        let inner = codec(CodecId::Raw);
        let ch = Chunked::new(inner.as_ref());
        let mut out = Vec::new();
        assert!(ch.decompress(&[], &[1, 2, 3], 10, &mut out).is_err());
        let data = sample(100);
        let mut payload = ch.compress(&[], &data).unwrap();
        payload.truncate(10);
        assert!(ch.decompress(&[], &payload, 100, &mut out).is_err());
    }

    /// Overwrite chunk `i`'s body offset in a framed payload.
    fn poison_offset(payload: &mut [u8], i: usize, off: u64) {
        payload[8 + i * 16..8 + i * 16 + 8].copy_from_slice(&off.to_le_bytes());
    }

    #[test]
    fn corrupt_index_rejected_parallel_and_range() {
        // The serial path always validated offsets; the parallel and range
        // paths used to slice the body unchecked. Both must now reject a
        // corrupt index instead of panicking or reading out of bounds.
        let inner = codec(CodecId::Raw);
        let ch = Chunked::new(inner.as_ref()).with_chunk_len(256);
        let data = sample(1024);
        let payload = ch.compress(&[], &data).unwrap();

        // offset pointing far beyond the body
        let mut beyond = payload.clone();
        poison_offset(&mut beyond, 1, u64::MAX / 2);
        for threads in [1usize, 4] {
            assert!(ch.decompress_parallel(&[], &beyond, data.len(), threads).is_err());
        }
        assert!(ch.decompress_range(&[], &beyond, 300, 100).is_err());

        // non-monotone offsets (chunk 2 "starts" before chunk 1)
        let mut backwards = payload.clone();
        poison_offset(&mut backwards, 2, 0);
        for threads in [1usize, 4] {
            assert!(ch.decompress_parallel(&[], &backwards, data.len(), threads).is_err());
        }
        assert!(ch.decompress_range(&[], &backwards, 600, 100).is_err());

        // the untouched payload still decodes everywhere
        assert_eq!(ch.decompress_parallel(&[], &payload, data.len(), 4).unwrap(), data);
    }

    #[test]
    fn corrupt_raw_len_rejected_without_huge_allocation() {
        // a corrupt per-chunk raw_len must be rejected at index-parse time,
        // not passed to the codec where out.reserve(raw_len) would abort
        let inner = codec(CodecId::Raw);
        let ch = Chunked::new(inner.as_ref()).with_chunk_len(256);
        let data = sample(1024);
        let payload = ch.compress(&[], &data).unwrap();
        let mut huge = payload.clone();
        // raw_len of chunk 1 lives 8 bytes after its offset field
        huge[8 + 16 + 8..8 + 16 + 16].copy_from_slice(&(u64::MAX / 4).to_le_bytes());
        let mut out = Vec::new();
        assert!(ch.decompress(&[], &huge, data.len(), &mut out).is_err());
        for threads in [1usize, 4] {
            assert!(ch.decompress_parallel(&[], &huge, data.len(), threads).is_err());
        }
        assert!(ch.decompress_range(&[], &huge, 300, 100).is_err());
    }
}
