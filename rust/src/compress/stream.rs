//! Chunked compression (S4 extension): split a stream into fixed-size
//! chunks compressed independently, with a chunk index.
//!
//! Two serving-relevant properties the flat codecs lack:
//!
//! * **bounded decode memory / partial access** — a layer's codes can be
//!   decompressed range-by-range (the paper's phones have little headroom
//!   even for one layer);
//! * **parallel decode** — chunks are independent, so a multicore device
//!   can decompress with `std::thread::scope` fan-out (on this repo's
//!   1-vCPU testbed the parallel path degrades gracefully to serial).
//!
//! Framing: `u32 n_chunks | u32 chunk_len | n_chunks * (u64 offset into
//! payload, u64 raw_len)` then the concatenated chunk payloads.

use anyhow::Result;

use super::Codec;

pub const DEFAULT_CHUNK: usize = 256 * 1024;

pub struct Chunked<'a> {
    pub inner: &'a dyn Codec,
    pub chunk_len: usize,
}

impl<'a> Chunked<'a> {
    pub fn new(inner: &'a dyn Codec) -> Self {
        Self { inner, chunk_len: DEFAULT_CHUNK }
    }

    pub fn with_chunk_len(mut self, n: usize) -> Self {
        assert!(n > 0);
        self.chunk_len = n;
        self
    }

    pub fn compress(&self, dict: &[u8], data: &[u8]) -> Result<Vec<u8>> {
        let chunks: Vec<&[u8]> = data.chunks(self.chunk_len.max(1)).collect();
        let mut payloads = Vec::with_capacity(chunks.len());
        for c in &chunks {
            payloads.push(self.inner.compress(dict, c)?);
        }
        let mut out = Vec::new();
        out.extend_from_slice(&(chunks.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.chunk_len as u32).to_le_bytes());
        let mut offset = 0u64;
        for (c, p) in chunks.iter().zip(&payloads) {
            out.extend_from_slice(&offset.to_le_bytes());
            out.extend_from_slice(&(c.len() as u64).to_le_bytes());
            offset += p.len() as u64;
        }
        for p in &payloads {
            out.extend_from_slice(p);
        }
        Ok(out)
    }

    fn parse_index(payload: &[u8]) -> Result<(Vec<(usize, usize)>, usize, &[u8])> {
        anyhow::ensure!(payload.len() >= 8, "chunked: truncated header");
        let n = u32::from_le_bytes(payload[0..4].try_into().unwrap()) as usize;
        let chunk_len = u32::from_le_bytes(payload[4..8].try_into().unwrap()) as usize;
        let idx_end = 8 + n * 16;
        anyhow::ensure!(payload.len() >= idx_end, "chunked: truncated index");
        let mut index = Vec::with_capacity(n);
        for i in 0..n {
            let off = 8 + i * 16;
            let o = u64::from_le_bytes(payload[off..off + 8].try_into().unwrap()) as usize;
            let l = u64::from_le_bytes(payload[off + 8..off + 16].try_into().unwrap()) as usize;
            index.push((o, l));
        }
        Ok((index, chunk_len, &payload[idx_end..]))
    }

    pub fn decompress(
        &self,
        dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        let (index, _cl, body) = Self::parse_index(payload)?;
        out.clear();
        out.reserve(expected_len);
        let mut scratch = Vec::new();
        for (i, &(off, raw_len)) in index.iter().enumerate() {
            let end = index.get(i + 1).map(|&(o, _)| o).unwrap_or(body.len());
            anyhow::ensure!(off <= end && end <= body.len(), "chunked: bad index");
            self.inner.decompress(dict, &body[off..end], raw_len, &mut scratch)?;
            out.extend_from_slice(&scratch);
        }
        anyhow::ensure!(out.len() == expected_len, "chunked: length mismatch");
        Ok(())
    }

    /// Decompress only the chunks covering byte range [start, start+len) —
    /// the partial-access primitive. Returns (bytes, offset of range start
    /// within them).
    pub fn decompress_range(
        &self,
        dict: &[u8],
        payload: &[u8],
        start: usize,
        len: usize,
    ) -> Result<(Vec<u8>, usize)> {
        let (index, chunk_len, body) = Self::parse_index(payload)?;
        anyhow::ensure!(chunk_len > 0, "chunked: zero chunk_len");
        let first = start / chunk_len;
        let last = (start + len).saturating_sub(1) / chunk_len;
        anyhow::ensure!(last < index.len(), "chunked: range beyond stream");
        let mut out = Vec::new();
        let mut scratch = Vec::new();
        for i in first..=last {
            let (off, raw_len) = index[i];
            let end = index.get(i + 1).map(|&(o, _)| o).unwrap_or(body.len());
            self.inner.decompress(dict, &body[off..end], raw_len, &mut scratch)?;
            out.extend_from_slice(&scratch);
        }
        Ok((out, start - first * chunk_len))
    }

    /// Parallel decompression across chunks using scoped threads.
    pub fn decompress_parallel(
        &self,
        dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        n_threads: usize,
    ) -> Result<Vec<u8>>
    where
        Self: Sync,
    {
        let (index, _cl, body) = Self::parse_index(payload)?;
        let n = index.len();
        if n == 0 {
            anyhow::ensure!(expected_len == 0, "chunked: empty payload");
            return Ok(Vec::new());
        }
        let mut results: Vec<Result<Vec<u8>>> = (0..n).map(|_| Ok(Vec::new())).collect();
        let threads = n_threads.clamp(1, n);
        let stride = (n + threads - 1) / threads;
        std::thread::scope(|s| {
            for (tid, slot_chunk) in results.chunks_mut(stride).enumerate() {
                let index = &index;
                let inner = self.inner;
                s.spawn(move || {
                    for (j, slot) in slot_chunk.iter_mut().enumerate() {
                        let i = tid * stride + j;
                        let (off, raw_len) = index[i];
                        let end = index.get(i + 1).map(|&(o, _)| o).unwrap_or(body.len());
                        let mut buf = Vec::new();
                        *slot = inner
                            .decompress(dict, &body[off..end], raw_len, &mut buf)
                            .map(|_| buf);
                    }
                });
            }
        });
        let mut out = Vec::with_capacity(expected_len);
        for r in results {
            out.extend_from_slice(&r?);
        }
        anyhow::ensure!(out.len() == expected_len, "chunked: length mismatch");
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{codec, CodecId};
    use crate::util::Rng;

    fn sample(n: usize) -> Vec<u8> {
        let mut rng = Rng::seed_from_u64(1);
        (0..n).map(|_| (128.0 + 20.0 * rng.normal_f32()).clamp(0.0, 255.0) as u8).collect()
    }

    #[test]
    fn roundtrip_all_codecs_and_sizes() {
        for id in crate::compress::all_codec_ids() {
            let inner = codec(id);
            let ch = Chunked::new(inner.as_ref()).with_chunk_len(1000);
            for n in [0usize, 1, 999, 1000, 1001, 5000] {
                let data = sample(n);
                let dict = inner.train(&[&data]);
                let payload = ch.compress(&dict, &data).unwrap();
                let mut out = Vec::new();
                ch.decompress(&dict, &payload, n, &mut out).unwrap();
                assert_eq!(out, data, "{id:?} n={n}");
            }
        }
    }

    #[test]
    fn range_access() {
        let inner = codec(CodecId::Huffman);
        let ch = Chunked::new(inner.as_ref()).with_chunk_len(512);
        let data = sample(4096);
        let dict = inner.train(&[&data]);
        let payload = ch.compress(&dict, &data).unwrap();
        for (start, len) in [(0usize, 10usize), (500, 100), (1000, 2000), (4000, 96)] {
            let (bytes, off) = ch.decompress_range(&dict, &payload, start, len).unwrap();
            assert_eq!(&bytes[off..off + len], &data[start..start + len]);
        }
        assert!(ch.decompress_range(&dict, &payload, 4095, 100).is_err());
    }

    #[test]
    fn parallel_matches_serial() {
        let inner = codec(CodecId::Lzw);
        let ch = Chunked::new(inner.as_ref()).with_chunk_len(777);
        let data = sample(10_000);
        let dict = inner.train(&[&data]);
        let payload = ch.compress(&dict, &data).unwrap();
        for threads in [1usize, 2, 4, 16] {
            let got = ch.decompress_parallel(&dict, &payload, data.len(), threads).unwrap();
            assert_eq!(got, data, "threads={threads}");
        }
    }

    #[test]
    fn corrupt_index_rejected() {
        let inner = codec(CodecId::Raw);
        let ch = Chunked::new(inner.as_ref());
        let mut out = Vec::new();
        assert!(ch.decompress(&[], &[1, 2, 3], 10, &mut out).is_err());
        let data = sample(100);
        let mut payload = ch.compress(&[], &data).unwrap();
        payload.truncate(10);
        assert!(ch.decompress(&[], &payload, 100, &mut out).is_err());
    }
}
