//! Identity codec — the "Quantized" (uncompressed) baseline rows in the
//! paper's tables, and the fallback when a stream is incompressible.

use anyhow::Result;

use super::{Codec, CodecId};

pub struct Raw;

impl Codec for Raw {
    fn id(&self) -> CodecId {
        CodecId::Raw
    }

    fn name(&self) -> &'static str {
        "raw"
    }

    fn train(&self, _samples: &[&[u8]]) -> Vec<u8> {
        Vec::new()
    }

    fn compress(&self, _dict: &[u8], data: &[u8]) -> Result<Vec<u8>> {
        Ok(data.to_vec())
    }

    fn decompress(
        &self,
        _dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        anyhow::ensure!(payload.len() == expected_len, "raw length mismatch");
        out.clear();
        out.extend_from_slice(payload);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::roundtrip_all_regimes;

    #[test]
    fn roundtrips() {
        roundtrip_all_regimes(&Raw);
    }

    #[test]
    fn rejects_wrong_length() {
        let mut out = Vec::new();
        assert!(Raw.decompress(&[], &[1, 2, 3], 2, &mut out).is_err());
    }
}
