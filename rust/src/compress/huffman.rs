//! Canonical Huffman coding over bytes (S6) — the entropy-coding baseline.
//!
//! The role of this codec in the reproduction is calibration: a Huffman
//! coder achieves within ~1 bit/symbol of the stream's zeroth-order
//! entropy, so comparing it against the paper's dictionary codec exposes
//! how much of Table 1's claimed ratio could possibly come from symbol
//! skew versus longer-range structure.
//!
//! Self-contained payload: a 256-byte code-length header (canonical codes
//! are reconstructed from lengths on both sides), then the bit stream.
//! `train` is a no-op — per-tensor histograms beat a shared table here.

use anyhow::Result;

use super::{Codec, CodecId};

pub struct Huffman;

/// Build code lengths via the standard two-queue Huffman construction on
/// the byte histogram. Returns lengths[256] (0 = symbol absent).
fn code_lengths(hist: &[u64; 256]) -> [u8; 256] {
    #[derive(Clone)]
    struct Node {
        freq: u64,
        kind: NodeKind,
    }
    #[derive(Clone)]
    enum NodeKind {
        Leaf(u8),
        Internal(usize, usize),
    }

    let mut lengths = [0u8; 256];
    let mut nodes: Vec<Node> = Vec::new();
    let mut heap: Vec<usize> = Vec::new(); // indices into nodes, min-heap by freq
    for (sym, &f) in hist.iter().enumerate() {
        if f > 0 {
            nodes.push(Node { freq: f, kind: NodeKind::Leaf(sym as u8) });
            heap.push(nodes.len() - 1);
        }
    }
    match heap.len() {
        0 => return lengths,
        1 => {
            if let NodeKind::Leaf(s) = nodes[heap[0]].kind {
                lengths[s as usize] = 1;
            }
            return lengths;
        }
        _ => {}
    }
    // simple binary-heap via sort-each-pop is O(n log n) overall for 256 syms
    while heap.len() > 1 {
        heap.sort_unstable_by_key(|&i| std::cmp::Reverse(nodes[i].freq));
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        nodes.push(Node { freq: nodes[a].freq + nodes[b].freq, kind: NodeKind::Internal(a, b) });
        heap.push(nodes.len() - 1);
    }
    // walk depths iteratively
    let mut stack = vec![(heap[0], 0u8)];
    while let Some((idx, depth)) = stack.pop() {
        match nodes[idx].kind {
            NodeKind::Leaf(s) => lengths[s as usize] = depth.max(1),
            NodeKind::Internal(a, b) => {
                stack.push((a, depth + 1));
                stack.push((b, depth + 1));
            }
        }
    }
    lengths
}

/// Canonical codes from lengths: symbols sorted by (length, value).
/// Returns (code, length) per symbol; codes assigned MSB-first. Lengths
/// are internally produced (<= ~40 for 256 symbols), but this is also on
/// the decode path where the header may be corrupt — callers must have
/// validated `lengths <= 60` first (u64 arithmetic keeps us panic-free
/// for anything that passes that check).
fn canonical_codes(lengths: &[u8; 256]) -> [(u64, u8); 256] {
    let mut order: Vec<u8> = (0u16..256).map(|s| s as u8).collect();
    order.sort_by_key(|&s| (lengths[s as usize], s));
    let mut codes = [(0u64, 0u8); 256];
    let mut code: u64 = 0;
    let mut prev_len: u8 = 0;
    for &s in &order {
        let len = lengths[s as usize].min(63);
        if len == 0 {
            continue;
        }
        if prev_len != 0 {
            code = (code + 1) << (len - prev_len).min(63);
        } else {
            code = 0;
        }
        codes[s as usize] = (code, len);
        prev_len = len;
    }
    codes
}

impl Codec for Huffman {
    fn id(&self) -> CodecId {
        CodecId::Huffman
    }

    fn name(&self) -> &'static str {
        "huffman"
    }

    fn train(&self, _samples: &[&[u8]]) -> Vec<u8> {
        Vec::new()
    }

    fn compress(&self, _dict: &[u8], data: &[u8]) -> Result<Vec<u8>> {
        if data.is_empty() {
            return Ok(Vec::new());
        }
        let mut hist = [0u64; 256];
        for &b in data {
            hist[b as usize] += 1;
        }
        let lengths = code_lengths(&hist);
        let codes = canonical_codes(&lengths);
        let mut out = Vec::with_capacity(256 + data.len() / 2);
        out.extend_from_slice(&lengths);
        // MSB-first bit stream
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        for &b in data {
            let (code, len) = codes[b as usize];
            acc = (acc << len) | code as u64;
            nbits += len as u32;
            while nbits >= 8 {
                out.push(((acc >> (nbits - 8)) & 0xFF) as u8);
                nbits -= 8;
            }
        }
        if nbits > 0 {
            out.push(((acc << (8 - nbits)) & 0xFF) as u8);
        }
        Ok(out)
    }

    fn decompress(
        &self,
        _dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        out.clear();
        if expected_len == 0 {
            anyhow::ensure!(payload.is_empty(), "huffman: payload for empty stream");
            return Ok(());
        }
        anyhow::ensure!(payload.len() >= 256, "huffman: missing header");
        let mut lengths = [0u8; 256];
        lengths.copy_from_slice(&payload[..256]);
        // validate BEFORE building codes: a corrupt header could carry
        // absurd lengths (found by prop_corrupted_payloads_never_panic)
        let max_len = *lengths.iter().max().unwrap();
        anyhow::ensure!(max_len > 0 && max_len <= 60, "huffman: bad lengths");
        let codes = canonical_codes(&lengths);

        // canonical decode tables: first_code / first_index per length
        let mut order: Vec<u8> = (0u16..256)
            .map(|s| s as u8)
            .filter(|&s| lengths[s as usize] > 0)
            .collect();
        order.sort_by_key(|&s| (lengths[s as usize], s));
        let ml = max_len as usize;
        let mut first_code = vec![u64::MAX; ml + 1];
        let mut first_index = vec![0usize; ml + 1];
        let mut count = vec![0usize; ml + 1];
        for (i, &s) in order.iter().enumerate() {
            let l = lengths[s as usize] as usize;
            if first_code[l] == u64::MAX {
                first_code[l] = codes[s as usize].0 as u64;
                first_index[l] = i;
            }
            count[l] += 1;
        }

        // §Perf: 12-bit LUT fast path. Peeking LUT_BITS at once resolves
        // any code of length <= LUT_BITS in a single lookup (covers ~all
        // symbols on realistic histograms); longer codes fall back to the
        // canonical per-bit walk. Entries whose canonical code would fall
        // outside the table (possible only with corrupt, Kraft-violating
        // headers) are skipped — the fallback walk rejects them cleanly.
        const LUT_BITS: usize = 12;
        let lut_width = ml.min(LUT_BITS);
        let mut lut: Vec<(u8, u8)> = vec![(0, 0); 1 << lut_width]; // (symbol, len); len 0 = fallback
        for &s in &order {
            let (code, len) = codes[s as usize];
            let len_us = len as usize;
            if len_us == 0 || len_us > lut_width {
                continue;
            }
            let shift = lut_width - len_us;
            let base = (code as usize) << shift;
            let top = base + (1usize << shift);
            if top > lut.len() {
                continue; // corrupt header; handled by the fallback walk
            }
            for e in &mut lut[base..top] {
                *e = (s, len);
            }
        }

        out.reserve(expected_len);
        let body = &payload[256..];
        let total_bits = body.len() * 8;
        // MSB-aligned bit accumulator: the next `nbits` unconsumed bits
        // live in the TOP bits of `acc`.
        let mut acc: u64 = 0;
        let mut nbits: usize = 0;
        let mut next_byte: usize = 0;
        let mut consumed_bits: usize = 0;
        while out.len() < expected_len {
            // bulk refill: grab 4 bytes at once while there is room
            if nbits <= 32 && next_byte + 4 <= body.len() {
                let w = u32::from_be_bytes(body[next_byte..next_byte + 4].try_into().unwrap());
                acc |= (w as u64) << (32 - nbits);
                next_byte += 4;
                nbits += 32;
            }
            while nbits <= 56 && next_byte < body.len() {
                acc |= (body[next_byte] as u64) << (56 - nbits);
                next_byte += 1;
                nbits += 8;
            }
            anyhow::ensure!(consumed_bits < total_bits, "huffman: truncated stream");
            let idx = (acc >> (64 - lut_width)) as usize;
            let (sym, len) = lut[idx];
            if len != 0 {
                let len_us = len as usize;
                anyhow::ensure!(
                    consumed_bits + len_us <= total_bits,
                    "huffman: truncated stream"
                );
                out.push(sym);
                acc <<= len_us;
                nbits = nbits.saturating_sub(len_us);
                consumed_bits += len_us;
                continue;
            }
            // fallback: canonical per-bit walk for long / corrupt codes
            let mut code: u64 = 0;
            let mut len = 0usize;
            loop {
                anyhow::ensure!(consumed_bits < total_bits, "huffman: truncated stream");
                if nbits == 0 {
                    anyhow::bail!("huffman: truncated stream");
                }
                let bit = (acc >> 63) & 1;
                acc <<= 1;
                nbits -= 1;
                consumed_bits += 1;
                code = (code << 1) | bit;
                len += 1;
                anyhow::ensure!(len <= ml, "huffman: code too long");
                if first_code[len] != u64::MAX
                    && code >= first_code[len]
                    && (code - first_code[len]) < count[len] as u64
                {
                    let idx = first_index[len] + (code - first_code[len]) as usize;
                    out.push(order[idx]);
                    break;
                }
                // refill inside long walks too
                while nbits <= 56 && next_byte < body.len() {
                    acc |= (body[next_byte] as u64) << (56 - nbits);
                    next_byte += 1;
                    nbits += 8;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::roundtrip_all_regimes;

    #[test]
    fn roundtrips() {
        roundtrip_all_regimes(&Huffman);
    }

    #[test]
    fn near_entropy_on_skewed_stream() {
                let mut rng = crate::util::Rng::seed_from_u64(2);
        // two-symbol stream, p = (0.9, 0.1): H ~= 0.469 bits/byte
        let data: Vec<u8> =
            (0..100_000).map(|_| if rng.gen_bool(0.9) { 0u8 } else { 1 }).collect();
        let payload = Huffman.compress(&[], &data).unwrap();
        // huffman floor is 1 bit/symbol for a 2-symbol alphabet
        let bits_per_sym = (payload.len() - 256) as f64 * 8.0 / data.len() as f64;
        assert!(bits_per_sym < 1.05, "bits/sym {bits_per_sym}");
    }

    #[test]
    fn gaussian_codes_compress_some() {
        // 8-bit-quantized normal data: entropy ~ 5-6 bits -> ~1.3-1.6x
        let regs = crate::compress::testutil::regimes();
        let gauss = &regs.iter().find(|(n, _)| *n == "gauss8bit").unwrap().1;
        let payload = Huffman.compress(&[], gauss).unwrap();
        let ratio = gauss.len() as f64 / payload.len() as f64;
        assert!(ratio > 1.1 && ratio < 2.5, "ratio {ratio}");
    }

    #[test]
    fn single_symbol_stream() {
        let data = vec![200u8; 999];
        let payload = Huffman.compress(&[], &data).unwrap();
        let mut out = Vec::new();
        Huffman.decompress(&[], &payload, data.len(), &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn corrupt_header_rejected() {
        let mut out = Vec::new();
        assert!(Huffman.decompress(&[], &[0u8; 10], 5, &mut out).is_err());
    }

    #[test]
    fn canonical_codes_are_prefix_free() {
        let mut hist = [0u64; 256];
        for (i, h) in hist.iter_mut().enumerate() {
            *h = (i as u64 % 7) + 1;
        }
        let lengths = code_lengths(&hist);
        let codes = canonical_codes(&lengths);
        for a in 0..256 {
            for b in 0..256 {
                if a == b {
                    continue;
                }
                let (ca, la) = codes[a];
                let (cb, lb) = codes[b];
                if la == 0 || lb == 0 || la > lb {
                    continue;
                }
                // a must not be a prefix of b
                assert_ne!(cb >> (lb - la), ca, "prefix violation {a} {b}");
            }
        }
    }
}
