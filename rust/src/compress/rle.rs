//! Run-length encoding baseline: `(count, byte)` pairs, runs up to 255.
//!
//! Wins only on the highly clustered / ternary regimes (where QMoE-style
//! sparsity dominates); on 8-bit near-normal streams it roughly doubles
//! size — which is exactly the point of including it in the codec bench.

use anyhow::Result;

use super::{Codec, CodecId};

pub struct Rle;

impl Codec for Rle {
    fn id(&self) -> CodecId {
        CodecId::Rle
    }

    fn name(&self) -> &'static str {
        "rle"
    }

    fn train(&self, _samples: &[&[u8]]) -> Vec<u8> {
        Vec::new()
    }

    fn compress(&self, _dict: &[u8], data: &[u8]) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(data.len() / 2 + 8);
        let mut i = 0;
        while i < data.len() {
            let b = data[i];
            let mut run = 1usize;
            while run < 255 && i + run < data.len() && data[i + run] == b {
                run += 1;
            }
            out.push(run as u8);
            out.push(b);
            i += run;
        }
        Ok(out)
    }

    fn decompress(
        &self,
        _dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        anyhow::ensure!(payload.len() % 2 == 0, "rle payload must be pairs");
        out.clear();
        out.reserve(expected_len);
        for pair in payload.chunks_exact(2) {
            let (count, byte) = (pair[0] as usize, pair[1]);
            anyhow::ensure!(count > 0, "zero-length run");
            out.extend(std::iter::repeat(byte).take(count));
        }
        anyhow::ensure!(out.len() == expected_len, "rle length mismatch");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::roundtrip_all_regimes;

    #[test]
    fn roundtrips() {
        roundtrip_all_regimes(&Rle);
    }

    #[test]
    fn constant_compresses_well() {
        let data = vec![7u8; 10_000];
        let payload = Rle.compress(&[], &data).unwrap();
        assert!(payload.len() < data.len() / 100);
    }

    #[test]
    fn random_expands() {
                let mut rng = crate::util::Rng::seed_from_u64(1);
        let data: Vec<u8> = (0..10_000).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let payload = Rle.compress(&[], &data).unwrap();
        assert!(payload.len() > data.len());
    }

    #[test]
    fn rejects_corrupt() {
        let mut out = Vec::new();
        assert!(Rle.decompress(&[], &[1], 1, &mut out).is_err()); // odd len
        assert!(Rle.decompress(&[], &[0, 5], 0, &mut out).is_err()); // zero run
    }
}
