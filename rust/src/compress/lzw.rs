//! LZW with variable-width codes (S5) — the dictionary family the paper's
//! §2.2 describes ("LZW starts with a dictionary containing single
//! character substrings ... outputs its code, and adds a new substring").
//!
//! Implementation notes:
//! * codes start at 9 bits and widen as the dictionary grows, GIF-style;
//! * the dictionary is capped at 2^16 entries and **frozen** when full
//!   (static tail), which empirically beats resetting on weight streams;
//! * encoder dictionary is a `HashMap<(prefix, byte) -> code>`; decoder
//!   reconstructs strings lazily via parent chains (no O(n²) buffers),
//!   including the classic KwKwK corner case.

use std::collections::HashMap;

use anyhow::Result;

use super::{Codec, CodecId};

const MAX_CODE_BITS: u32 = 16;
const MAX_CODES: u32 = 1 << MAX_CODE_BITS;

pub struct Lzw {
    pub max_codes: u32,
}

impl Default for Lzw {
    fn default() -> Self {
        Self { max_codes: MAX_CODES }
    }
}

struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    fn new() -> Self {
        Self { out: Vec::new(), acc: 0, nbits: 0 }
    }

    fn write(&mut self, code: u32, width: u32) {
        self.acc |= (code as u64) << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xFF) as u8);
        }
        self.out
    }
}

struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> Self {
        Self { data, pos: 0, acc: 0, nbits: 0 }
    }

    fn read(&mut self, width: u32) -> Option<u32> {
        while self.nbits < width {
            if self.pos >= self.data.len() {
                return None;
            }
            self.acc |= (self.data[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        let code = (self.acc & ((1u64 << width) - 1)) as u32;
        self.acc >>= width;
        self.nbits -= width;
        Some(code)
    }
}

fn width_for(next_code: u32) -> u32 {
    // width needed to express the largest assigned code
    32 - (next_code.max(2) - 1).leading_zeros()
}

impl Codec for Lzw {
    fn id(&self) -> CodecId {
        CodecId::Lzw
    }

    fn name(&self) -> &'static str {
        "lzw"
    }

    fn train(&self, _samples: &[&[u8]]) -> Vec<u8> {
        Vec::new() // adaptive: the dictionary is implicit in the stream
    }

    fn compress(&self, _dict: &[u8], data: &[u8]) -> Result<Vec<u8>> {
        if data.is_empty() {
            return Ok(Vec::new());
        }
        let mut table: HashMap<(u32, u8), u32> = HashMap::new();
        let mut next_code: u32 = 256;
        let mut w = BitWriter::new();
        let mut prefix: u32 = data[0] as u32;
        for &b in &data[1..] {
            match table.get(&(prefix, b)) {
                Some(&code) => prefix = code,
                None => {
                    // emit at the width that covers codes assigned so far
                    w.write(prefix, width_for(next_code));
                    if next_code < self.max_codes {
                        table.insert((prefix, b), next_code);
                        next_code += 1;
                    }
                    prefix = b as u32;
                }
            }
        }
        w.write(prefix, width_for(next_code));
        Ok(w.finish())
    }

    fn decompress(
        &self,
        _dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        out.clear();
        if expected_len == 0 {
            anyhow::ensure!(payload.is_empty(), "lzw: payload for empty stream");
            return Ok(());
        }
        out.reserve(expected_len);
        // decoder table: code -> (parent, appended byte); roots are bytes
        let mut parent: Vec<u32> = Vec::new();
        let mut last_byte: Vec<u8> = Vec::new();
        let mut next_code: u32 = 256;
        let mut r = BitReader::new(payload);

        fn expand(
            code: u32,
            parent: &[u32],
            last_byte: &[u8],
            scratch: &mut Vec<u8>,
        ) -> u8 {
            scratch.clear();
            let mut c = code;
            while c >= 256 {
                let idx = (c - 256) as usize;
                scratch.push(last_byte[idx]);
                c = parent[idx];
            }
            scratch.push(c as u8);
            scratch.reverse();
            scratch[0]
        }

        let mut scratch = Vec::new();
        let first = r
            .read(width_for(next_code))
            .ok_or_else(|| anyhow::anyhow!("lzw: truncated stream"))?;
        anyhow::ensure!(first < 256, "lzw: first code must be a literal");
        out.push(first as u8);
        let mut prev = first;

        while out.len() < expected_len {
            // the encoder is one insertion ahead of us at read time, so it
            // may emit `next_code` itself (KwKwK) — unless the table is
            // frozen at the cap, where both sides stop growing
            let width = width_for((next_code + 1).min(self.max_codes));
            let code = r
                .read(width)
                .ok_or_else(|| anyhow::anyhow!("lzw: truncated stream at {}", out.len()))?;
            let kwkwk_ok = next_code < self.max_codes;
            anyhow::ensure!(
                code < next_code + kwkwk_ok as u32,
                "lzw: code {code} out of range (next {next_code})"
            );
            let first_byte = if code == next_code {
                // KwKwK: string = prev-string + first byte of prev-string
                let fb = expand(prev, &parent, &last_byte, &mut scratch);
                scratch.push(fb);
                scratch[0]
            } else {
                expand(code, &parent, &last_byte, &mut scratch)
            };
            out.extend_from_slice(&scratch);
            if next_code < self.max_codes {
                parent.push(prev);
                last_byte.push(first_byte);
                next_code += 1;
            }
            prev = code;
        }
        anyhow::ensure!(out.len() == expected_len, "lzw: length overshoot");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::roundtrip_all_regimes;

    #[test]
    fn roundtrips() {
        roundtrip_all_regimes(&Lzw::default());
    }

    #[test]
    fn kwkwk_case() {
        // "abababab..." exercises the code == next_code branch
        let data: Vec<u8> = std::iter::repeat([b'a', b'b']).take(500).flatten().collect();
        let c = Lzw::default();
        let payload = c.compress(&[], &data).unwrap();
        let mut out = Vec::new();
        c.decompress(&[], &payload, data.len(), &mut out).unwrap();
        assert_eq!(out, data);
        assert!(payload.len() < data.len() / 4);
    }

    #[test]
    fn repetitive_compresses_strongly() {
        let data: Vec<u8> = (0..100_000u32).map(|i| ((i / 7) % 5) as u8).collect();
        let c = Lzw::default();
        let payload = c.compress(&[], &data).unwrap();
        assert!(
            (data.len() as f64 / payload.len() as f64) > 5.0,
            "ratio {}",
            data.len() as f64 / payload.len() as f64
        );
    }

    #[test]
    fn truncated_payload_rejected() {
        let data = vec![1u8; 1000];
        let c = Lzw::default();
        let payload = c.compress(&[], &data).unwrap();
        let mut out = Vec::new();
        assert!(c
            .decompress(&[], &payload[..payload.len() / 2], data.len(), &mut out)
            .is_err());
    }

    #[test]
    fn single_byte() {
        let c = Lzw::default();
        let payload = c.compress(&[], &[42]).unwrap();
        let mut out = Vec::new();
        c.decompress(&[], &payload, 1, &mut out).unwrap();
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn dictionary_freeze_at_cap() {
        // small cap forces the frozen-dictionary path
        let c = Lzw { max_codes: 512 };
                let mut rng = crate::util::Rng::seed_from_u64(9);
        let data: Vec<u8> = (0..50_000).map(|_| rng.gen_range(0, 8) as u8).collect();
        let payload = c.compress(&[], &data).unwrap();
        let mut out = Vec::new();
        c.decompress(&[], &payload, data.len(), &mut out).unwrap();
        assert_eq!(out, data);
    }
}
