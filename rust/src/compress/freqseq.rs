//! The paper's §4 codec (S4): a static dictionary of frequent fixed-length
//! byte sequences with u16 codewords and an `0xFFFF` escape.
//!
//! Faithful mode (`FreqSeq::paper()`) reproduces the listings exactly,
//! including their costly choice of storing escaped raw *bytes* as u16
//! array elements (`compressed_param.extend(sequence)` into a `np.uint16`
//! buffer): every unknown 4-byte window costs 2 + 2*4 = 10 bytes. On
//! high-entropy streams this *expands* — the codec bench (E6) makes that
//! visible instead of hiding it.
//!
//! Packed mode (`FreqSeq::packed()`) is the one-line fix: escapes carry a
//! run length and raw bytes stay bytes (`0xFFFF, u16 n, n raw bytes`).
//!
//! The dictionary is trained once per model over all quantized tensors
//! (the paper builds one `compression_table` per model) and serialized
//! into the TQM container:
//!
//! ```text
//! dict := u32 seq_len | u32 n_entries | n_entries * seq_len bytes
//! ```
//! codeword k maps to the k-th sequence; `n_entries <= 0xFFFF` so the
//! escape never collides.

use std::collections::HashMap;

use anyhow::Result;

use super::{Codec, CodecId};

pub const ESCAPE: u16 = 0xFFFF;
pub const MAX_TABLE: usize = 0xFFFF; // codewords 0..=0xFFFE

/// Budget of windows examined during training (keeps dictionary building
/// linear-ish on multi-hundred-MB models by striding over the input).
const TRAIN_WINDOW_BUDGET: usize = 8_000_000;

#[derive(Clone, Debug)]
pub struct FreqSeq {
    pub seq_len: usize,
    pub packed_escapes: bool,
    pub max_entries: usize,
}

impl FreqSeq {
    /// Paper-faithful configuration (sequence_length=4, u16 escapes).
    pub fn paper() -> Self {
        Self { seq_len: 4, packed_escapes: false, max_entries: MAX_TABLE }
    }

    /// Escape-packed variant (our ablation fix).
    pub fn packed() -> Self {
        Self { seq_len: 4, packed_escapes: true, max_entries: MAX_TABLE }
    }

    pub fn with_seq_len(mut self, seq_len: usize) -> Self {
        assert!((1..=8).contains(&seq_len));
        self.seq_len = seq_len;
        self
    }

    pub fn with_max_entries(mut self, n: usize) -> Self {
        self.max_entries = n.min(MAX_TABLE);
        self
    }

    fn key(window: &[u8]) -> u64 {
        let mut k = 0u64;
        for &b in window {
            k = (k << 8) | b as u64;
        }
        k
    }
}

/// Parsed dictionary: sequence list + reverse lookup.
pub struct Table {
    pub seq_len: usize,
    pub sequences: Vec<u8>, // n_entries * seq_len
    lookup: HashMap<u64, u16>,
}

impl Table {
    pub fn parse(dict: &[u8]) -> Result<Self> {
        anyhow::ensure!(dict.len() >= 8, "freqseq: dict too short");
        let seq_len = u32::from_le_bytes(dict[0..4].try_into().unwrap()) as usize;
        let n = u32::from_le_bytes(dict[4..8].try_into().unwrap()) as usize;
        anyhow::ensure!((1..=8).contains(&seq_len), "freqseq: bad seq_len {seq_len}");
        anyhow::ensure!(n <= MAX_TABLE, "freqseq: table too large {n}");
        anyhow::ensure!(dict.len() == 8 + n * seq_len, "freqseq: dict length mismatch");
        let sequences = dict[8..].to_vec();
        let mut lookup = HashMap::with_capacity(n);
        for i in 0..n {
            lookup.insert(FreqSeq::key(&sequences[i * seq_len..(i + 1) * seq_len]), i as u16);
        }
        Ok(Self { seq_len, sequences, lookup })
    }

    pub fn n_entries(&self) -> usize {
        self.sequences.len() / self.seq_len.max(1)
    }

    #[inline]
    pub fn get(&self, window: &[u8]) -> Option<u16> {
        self.lookup.get(&FreqSeq::key(window)).copied()
    }

    #[inline]
    pub fn seq(&self, codeword: u16) -> &[u8] {
        let i = codeword as usize * self.seq_len;
        &self.sequences[i..i + self.seq_len]
    }
}

fn push_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct U16Reader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> U16Reader<'a> {
    fn next(&mut self) -> Result<u16> {
        anyhow::ensure!(self.pos + 2 <= self.data.len(), "freqseq: truncated payload");
        let v = u16::from_le_bytes([self.data[self.pos], self.data[self.pos + 1]]);
        self.pos += 2;
        Ok(v)
    }

    fn take_bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(self.pos + n <= self.data.len(), "freqseq: truncated raw run");
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn done(&self) -> bool {
        self.pos >= self.data.len()
    }
}

impl Codec for FreqSeq {
    fn id(&self) -> CodecId {
        if self.packed_escapes {
            CodecId::FreqSeqPacked
        } else {
            CodecId::FreqSeq
        }
    }

    fn name(&self) -> &'static str {
        if self.packed_escapes {
            "freqseq-packed"
        } else {
            "freqseq"
        }
    }

    /// Count non-overlapping windows (the same stride the encoder walks)
    /// across all sample streams; keep the most frequent `max_entries`.
    fn train(&self, samples: &[&[u8]]) -> Vec<u8> {
        let total_windows: usize =
            samples.iter().map(|s| s.len() / self.seq_len).sum::<usize>().max(1);
        let stride_factor = (total_windows / TRAIN_WINDOW_BUDGET).max(1);
        let stride = self.seq_len * stride_factor;
        let mut counts: HashMap<u64, u32> = HashMap::new();
        for s in samples {
            let mut i = 0;
            while i + self.seq_len <= s.len() {
                *counts.entry(Self::key(&s[i..i + self.seq_len])).or_insert(0) += 1;
                i += stride;
            }
        }
        let mut ranked: Vec<(u64, u32)> =
            counts.into_iter().filter(|&(_, c)| c >= 2).collect();
        ranked.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        ranked.truncate(self.max_entries);

        let mut dict = Vec::with_capacity(8 + ranked.len() * self.seq_len);
        dict.extend_from_slice(&(self.seq_len as u32).to_le_bytes());
        dict.extend_from_slice(&(ranked.len() as u32).to_le_bytes());
        for (key, _) in &ranked {
            for j in (0..self.seq_len).rev() {
                dict.push(((key >> (8 * j)) & 0xFF) as u8);
            }
        }
        dict
    }

    fn compress(&self, dict: &[u8], data: &[u8]) -> Result<Vec<u8>> {
        let table = Table::parse(dict)?;
        anyhow::ensure!(
            table.seq_len == self.seq_len,
            "freqseq: dict seq_len {} != codec seq_len {}",
            table.seq_len,
            self.seq_len
        );
        let sl = self.seq_len;
        let mut out = Vec::with_capacity(data.len() / 2 + 16);
        let mut i = 0;
        if self.packed_escapes {
            let mut raw_start: Option<usize> = None;
            let mut flush =
                |out: &mut Vec<u8>, raw_start: &mut Option<usize>, end: usize| {
                    if let Some(start) = raw_start.take() {
                        let mut j = start;
                        while j < end {
                            let n = (end - j).min(u16::MAX as usize - 1);
                            push_u16(out, ESCAPE);
                            push_u16(out, n as u16);
                            out.extend_from_slice(&data[j..j + n]);
                            j += n;
                        }
                    }
                };
            while i + sl <= data.len() {
                if let Some(cw) = table.get(&data[i..i + sl]) {
                    flush(&mut out, &mut raw_start, i);
                    push_u16(&mut out, cw);
                } else if raw_start.is_none() {
                    raw_start = Some(i);
                }
                i += sl;
            }
            let end = data.len();
            if raw_start.is_some() {
                flush(&mut out, &mut raw_start, end);
            } else if i < end {
                raw_start = Some(i);
                flush(&mut out, &mut raw_start, end);
            }
        } else {
            // paper-faithful: every escaped byte costs a full u16
            while i + sl <= data.len() {
                let window = &data[i..i + sl];
                match table.get(window) {
                    Some(cw) => push_u16(&mut out, cw),
                    None => {
                        push_u16(&mut out, ESCAPE);
                        for &b in window {
                            push_u16(&mut out, b as u16);
                        }
                    }
                }
                i += sl;
            }
            if i < data.len() {
                push_u16(&mut out, ESCAPE);
                for &b in &data[i..] {
                    push_u16(&mut out, b as u16);
                }
            }
        }
        Ok(out)
    }

    fn decompress(
        &self,
        dict: &[u8],
        payload: &[u8],
        expected_len: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        let table = Table::parse(dict)?;
        decode_with_table(&table, self.packed_escapes, payload, expected_len, out)
    }
}

/// Decode against a pre-parsed [`Table`] — the §Perf fast path used by the
/// TQM reader, which parses the model-global dictionary once instead of
/// per tensor (the parse builds a 64k-entry hash map).
pub fn decode_with_table(
    table: &Table,
    packed_escapes: bool,
    payload: &[u8],
    expected_len: usize,
    out: &mut Vec<u8>,
) -> Result<()> {
    {
        let sl = table.seq_len;
        out.clear();
        out.reserve(expected_len);
        let mut r = U16Reader { data: payload, pos: 0 };
        if packed_escapes {
            while out.len() < expected_len {
                let cw = r.next()?;
                if cw == ESCAPE {
                    let n = r.next()? as usize;
                    out.extend_from_slice(r.take_bytes(n)?);
                } else {
                    anyhow::ensure!(
                        (cw as usize) < table.n_entries(),
                        "freqseq: codeword {cw} out of table"
                    );
                    out.extend_from_slice(table.seq(cw));
                }
            }
        } else {
            while out.len() < expected_len {
                let cw = r.next()?;
                if cw == ESCAPE {
                    // a full window unless we're at the tail
                    let n = sl.min(expected_len - out.len());
                    for _ in 0..n {
                        let v = r.next()?;
                        anyhow::ensure!(v <= 0xFF, "freqseq: escaped byte {v} > 255");
                        out.push(v as u8);
                    }
                } else {
                    anyhow::ensure!(
                        (cw as usize) < table.n_entries(),
                        "freqseq: codeword {cw} out of table"
                    );
                    out.extend_from_slice(table.seq(cw));
                }
            }
        }
        anyhow::ensure!(out.len() == expected_len, "freqseq: length mismatch");
        anyhow::ensure!(r.done(), "freqseq: trailing payload bytes");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::testutil::{regimes, roundtrip_all_regimes};

    #[test]
    fn roundtrips_paper() {
        roundtrip_all_regimes(&FreqSeq::paper());
    }

    #[test]
    fn roundtrips_packed() {
        roundtrip_all_regimes(&FreqSeq::packed());
    }

    #[test]
    fn roundtrips_other_seq_lens() {
        for sl in [2usize, 3, 8] {
            roundtrip_all_regimes(&FreqSeq::paper().with_seq_len(sl));
            roundtrip_all_regimes(&FreqSeq::packed().with_seq_len(sl));
        }
    }

    #[test]
    fn repetitive_hits_near_2x_seqlen_over_2() {
        // fully table-covered stream: 2 bytes per seq_len bytes
        let data: Vec<u8> = (0..40_000).map(|i| [1u8, 2, 3, 4][i % 4]).collect();
        let c = FreqSeq::paper();
        let dict = c.train(&[&data]);
        let payload = c.compress(&dict, &data).unwrap();
        let ratio = data.len() as f64 / payload.len() as f64;
        assert!(ratio > 1.9, "ratio {ratio}"); // seq_len/2 = 2x
    }

    #[test]
    fn paper_escape_expands_on_random() {
                let mut rng = crate::util::Rng::seed_from_u64(3);
        let data: Vec<u8> = (0..40_000).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let c = FreqSeq::paper();
        let dict = c.train(&[&data]);
        let payload = c.compress(&dict, &data).unwrap();
        // mostly escapes: ~10 bytes per 4-byte window = 2.5x expansion
        assert!(payload.len() > data.len() * 2, "paper escape should expand");
        // packed variant must not blow up the same way
        let cp = FreqSeq::packed();
        let dictp = cp.train(&[&data]);
        let payloadp = cp.compress(&dictp, &data).unwrap();
        assert!(payloadp.len() < data.len() + data.len() / 8);
    }

    #[test]
    fn dict_trained_on_model_generalizes_to_tensor() {
        // one dict across streams, per-tensor compression (the paper's setup)
        let regs = regimes();
        let samples: Vec<&[u8]> = regs.iter().map(|(_, d)| d.as_slice()).collect();
        let c = FreqSeq::packed();
        let dict = c.train(&samples);
        for (name, data) in &regs {
            let payload = c.compress(&dict, data).unwrap();
            let mut out = Vec::new();
            c.decompress(&dict, &payload, data.len(), &mut out).unwrap();
            assert_eq!(&out, data, "{name}");
        }
    }

    #[test]
    fn table_capped_at_escape_space() {
        let c = FreqSeq::paper().with_max_entries(1 << 20);
        assert_eq!(c.max_entries, MAX_TABLE);
    }

    #[test]
    fn small_table_still_roundtrips() {
        let data: Vec<u8> = (0..10_000).map(|i| (i % 23) as u8).collect();
        let c = FreqSeq::packed().with_max_entries(4);
        let dict = c.train(&[&data]);
        let t = Table::parse(&dict).unwrap();
        assert!(t.n_entries() <= 4);
        let payload = c.compress(&dict, &data).unwrap();
        let mut out = Vec::new();
        c.decompress(&dict, &payload, data.len(), &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn corrupt_dict_rejected() {
        assert!(Table::parse(&[1, 2, 3]).is_err());
        let mut bad = Vec::new();
        bad.extend_from_slice(&9u32.to_le_bytes()); // seq_len 9 > 8
        bad.extend_from_slice(&0u32.to_le_bytes());
        assert!(Table::parse(&bad).is_err());
    }

    #[test]
    fn codeword_out_of_range_rejected() {
        let data = vec![1u8, 2, 3, 4];
        let c = FreqSeq::paper();
        let dict = c.train(&[&data[..]]);
        // payload with a huge (but non-escape) codeword
        let payload = 0x1234u16.to_le_bytes().to_vec();
        let mut out = Vec::new();
        assert!(c.decompress(&dict, &payload, 4, &mut out).is_err());
    }
}
