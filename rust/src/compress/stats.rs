//! Compression analytics: byte entropy (the information-theoretic bound a
//! zeroth-order coder faces), higher-order entropy estimates, and the
//! per-stream report the Table 1 / E6 benches print.

use super::{Codec, CodecId};

/// Zeroth-order Shannon entropy of a byte stream, bits per byte.
pub fn byte_entropy(data: &[u8]) -> f64 {
    if data.is_empty() {
        return 0.0;
    }
    let mut hist = [0u64; 256];
    for &b in data {
        hist[b as usize] += 1;
    }
    let n = data.len() as f64;
    hist.iter()
        .filter(|&&h| h > 0)
        .map(|&h| {
            let p = h as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Order-k conditional entropy estimate H(X_i | X_{i-k..i-1}) via k-gram
/// frequencies (k in 1..=3 practical). Gives the bound that context-aware
/// codecs like LZW chase.
pub fn conditional_entropy(data: &[u8], k: usize) -> f64 {
    assert!((1..=3).contains(&k));
    if data.len() <= k {
        return 0.0;
    }
    use std::collections::HashMap;
    let mut ctx_counts: HashMap<u32, u64> = HashMap::new();
    let mut joint_counts: HashMap<(u32, u8), u64> = HashMap::new();
    for w in data.windows(k + 1) {
        let mut ctx = 0u32;
        for &b in &w[..k] {
            ctx = (ctx << 8) | b as u32;
        }
        *ctx_counts.entry(ctx).or_insert(0) += 1;
        *joint_counts.entry((ctx, w[k])).or_insert(0) += 1;
    }
    let n = (data.len() - k) as f64;
    let mut h = 0.0;
    for (&(ctx, _), &jc) in &joint_counts {
        let cc = ctx_counts[&ctx] as f64;
        let p_joint = jc as f64 / n;
        let p_cond = jc as f64 / cc;
        h -= p_joint * p_cond.log2();
    }
    h
}

/// One codec's result on one stream.
#[derive(Clone, Debug)]
pub struct CodecResult {
    pub codec: CodecId,
    pub name: &'static str,
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
    pub dict_bytes: usize,
    pub compress_secs: f64,
    pub decompress_secs: f64,
}

impl CodecResult {
    /// Ratio counting the (amortizable) dictionary.
    pub fn ratio_with_dict(&self) -> f64 {
        self.raw_bytes as f64 / (self.compressed_bytes + self.dict_bytes).max(1) as f64
    }

    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes.max(1) as f64
    }

    pub fn decompress_mb_s(&self) -> f64 {
        self.raw_bytes as f64 / 1e6 / self.decompress_secs.max(1e-12)
    }
}

/// Run one codec end-to-end on a stream (train on the stream itself unless
/// a shared dict is supplied) and verify the roundtrip.
pub fn measure(
    c: &dyn Codec,
    data: &[u8],
    shared_dict: Option<&[u8]>,
) -> anyhow::Result<CodecResult> {
    let owned;
    let dict: &[u8] = match shared_dict {
        Some(d) => d,
        None => {
            owned = c.train(&[data]);
            &owned
        }
    };
    let t0 = std::time::Instant::now();
    let payload = c.compress(dict, data)?;
    let compress_secs = t0.elapsed().as_secs_f64();
    let mut out = Vec::new();
    let t1 = std::time::Instant::now();
    c.decompress(dict, &payload, data.len(), &mut out)?;
    let decompress_secs = t1.elapsed().as_secs_f64();
    anyhow::ensure!(out == data, "codec {} roundtrip mismatch", c.name());
    Ok(CodecResult {
        codec: c.id(),
        name: c.name(),
        raw_bytes: data.len(),
        compressed_bytes: payload.len(),
        dict_bytes: if shared_dict.is_some() { 0 } else { dict.len() },
        compress_secs,
        decompress_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_extremes() {
        assert_eq!(byte_entropy(&[]), 0.0);
        assert_eq!(byte_entropy(&[5; 1000]), 0.0);
        let all: Vec<u8> = (0..=255).collect();
        assert!((byte_entropy(&all) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn conditional_entropy_detects_structure() {
        // deterministic successor: H(X|prev) == 0
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 256) as u8).collect();
        assert!(byte_entropy(&data) > 7.9);
        assert!(conditional_entropy(&data, 1) < 0.01);
    }

    #[test]
    fn measure_reports_ratio() {
        let c = crate::compress::codec(CodecId::Rle);
        let data = vec![3u8; 10_000];
        let r = measure(c.as_ref(), &data, None).unwrap();
        assert!(r.ratio() > 50.0);
        assert_eq!(r.raw_bytes, 10_000);
    }
}
