//! Sampling / text generation (S14): greedy and top-k temperature sampling
//! on decode-step logits, plus the generation driver used by the serving
//! example and the coordinator.

use anyhow::Result;

use crate::pipeline::{Engine, Session};
use crate::util::Rng;

#[derive(Clone, Copy, Debug)]
pub enum SamplerKind {
    Greedy,
    TopK { k: usize, temperature: f32 },
}

pub struct Sampler {
    pub kind: SamplerKind,
    rng: Rng,
}

impl Sampler {
    pub fn greedy() -> Self {
        Self { kind: SamplerKind::Greedy, rng: Rng::seed_from_u64(0) }
    }

    pub fn top_k(k: usize, temperature: f32, seed: u64) -> Self {
        Self { kind: SamplerKind::TopK { k, temperature }, rng: Rng::seed_from_u64(seed) }
    }

    pub fn sample(&mut self, logits: &[f32]) -> u32 {
        match self.kind {
            SamplerKind::Greedy => argmax(logits),
            SamplerKind::TopK { k, temperature } => {
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
                idx.truncate(k.max(1));
                let t = temperature.max(1e-4);
                let m = logits[idx[0]];
                let weights: Vec<f64> =
                    idx.iter().map(|&i| (((logits[i] - m) / t) as f64).exp()).collect();
                let total: f64 = weights.iter().sum();
                let mut u = self.rng.f64() * total;
                for (j, w) in weights.iter().enumerate() {
                    u -= w;
                    if u <= 0.0 {
                        return idx[j] as u32;
                    }
                }
                idx[idx.len() - 1] as u32
            }
        }
    }
}

pub fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

/// Outcome of a generation call.
pub struct Generation {
    pub tokens: Vec<u32>,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub tokens_per_s: f64,
}

/// Generate `max_new` tokens from `prompt`, stopping at `stop_token`.
pub fn generate(
    engine: &Engine,
    prompt: &[u32],
    max_new: usize,
    sampler: &mut Sampler,
    stop_token: Option<u32>,
) -> Result<Generation> {
    let t0 = std::time::Instant::now();
    let (mut session, first_logits) = engine.prefill_session(prompt)?;
    let prefill_s = t0.elapsed().as_secs_f64();

    let mut out = Vec::with_capacity(max_new);
    let mut next = sampler.sample(&first_logits);
    let t1 = std::time::Instant::now();
    for _ in 0..max_new {
        out.push(next);
        if Some(next) == stop_token {
            break;
        }
        if session.pos + 1 >= engine.cfg().max_seq {
            break; // KV capacity reached
        }
        let logits = engine.decode_one(&mut session, next)?;
        next = sampler.sample(&logits);
    }
    let decode_s = t1.elapsed().as_secs_f64();
    Ok(Generation {
        tokens_per_s: out.len() as f64 / decode_s.max(1e-9),
        tokens: out,
        prefill_s,
        decode_s,
    })
}

/// Continue an existing session by `n` tokens (used by the coordinator's
/// batched loop for single sessions).
pub fn continue_session(
    engine: &Engine,
    session: &mut Session,
    first: u32,
    n: usize,
    sampler: &mut Sampler,
) -> Result<Vec<u32>> {
    let mut out = Vec::with_capacity(n);
    let mut next = first;
    for _ in 0..n {
        if session.pos + 1 >= engine.cfg().max_seq {
            break;
        }
        let logits = engine.decode_one(session, next)?;
        next = sampler.sample(&logits);
        out.push(next);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 5.0, -2.0]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }

    #[test]
    fn greedy_deterministic() {
        let mut s = Sampler::greedy();
        let logits = vec![0.0, 1.0, 9.0, 2.0];
        for _ in 0..5 {
            assert_eq!(s.sample(&logits), 2);
        }
    }

    #[test]
    fn top_k_respects_k() {
        let mut s = Sampler::top_k(2, 1.0, 7);
        let logits = vec![10.0, 9.0, -50.0, -50.0];
        for _ in 0..100 {
            let t = s.sample(&logits);
            assert!(t == 0 || t == 1, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn top_k_low_temperature_is_greedy_ish() {
        let mut s = Sampler::top_k(4, 0.01, 3);
        let logits = vec![1.0, 5.0, 4.9, 0.0];
        let picks: Vec<u32> = (0..50).map(|_| s.sample(&logits)).collect();
        assert!(picks.iter().filter(|&&t| t == 1).count() > 45);
    }

    #[test]
    fn top_k_seeded_reproducible() {
        let logits = vec![1.0, 1.1, 0.9, 1.05];
        let a: Vec<u32> =
            { let mut s = Sampler::top_k(4, 1.0, 42); (0..20).map(|_| s.sample(&logits)).collect() };
        let b: Vec<u32> =
            { let mut s = Sampler::top_k(4, 1.0, 42); (0..20).map(|_| s.sample(&logits)).collect() };
        assert_eq!(a, b);
    }
}
