//! Pure-Rust stand-in for the `xla` crate (active without the `pjrt`
//! feature).
//!
//! [`Literal`] is fully functional — it really holds typed, shaped data —
//! because the host side of this crate (literal conversion, layer
//! flattening, the decode fast path) is exercised by tests that must run
//! without the XLA toolchain. The PJRT client/executable types exist only
//! so the code compiles; constructing a client fails with an explicit
//! error, which is surfaced by `Runtime::new` long before any stage runs.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

/// Element dtypes used by the stage argument contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    S32,
    U8,
}

impl ElementType {
    pub fn byte_len(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeElement: Copy {
    const TY: ElementType;
    fn write_le(self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeElement for f32 {
    const TY: ElementType = ElementType::F32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeElement for i32 {
    const TY: ElementType = ElementType::S32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeElement for u8 {
    const TY: ElementType = ElementType::U8;
    fn write_le(self, out: &mut Vec<u8>) {
        out.push(self);
    }
    fn read_le(bytes: &[u8]) -> Self {
        bytes[0]
    }
}

/// Shaped, typed host buffer — mirrors the subset of `xla::Literal` the
/// crate uses.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

/// Array shape (dims only; dtype is queried via [`Literal::ty`]).
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

fn numel(dims: &[i64]) -> usize {
    dims.iter().product::<i64>() as usize
}

impl Literal {
    /// 1-D literal from a native slice.
    pub fn vec1<T: NativeElement>(data: &[T]) -> Self {
        let mut bytes = Vec::with_capacity(data.len() * std::mem::size_of::<T>());
        for &v in data {
            v.write_le(&mut bytes);
        }
        Self { ty: T::TY, dims: vec![data.len() as i64], data: bytes }
    }

    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Self, Error> {
        let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        if numel(&dims) * ty.byte_len() != data.len() {
            return Err(err(format!(
                "stub literal: {} bytes do not fill shape {dims:?} of {ty:?}",
                data.len()
            )));
        }
        Ok(Self { ty, dims, data: data.to_vec() })
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Self, Error> {
        if numel(dims) != numel(&self.dims) {
            return Err(err(format!(
                "stub literal: cannot reshape {:?} into {dims:?}",
                self.dims
            )));
        }
        Ok(Self { ty: self.ty, dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn to_vec<T: NativeElement>(&self) -> Result<Vec<T>, Error> {
        if self.ty != T::TY {
            return Err(err(format!("stub literal: dtype {:?} != requested {:?}", self.ty, T::TY)));
        }
        let w = self.ty.byte_len();
        Ok(self.data.chunks_exact(w).map(T::read_le).collect())
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn ty(&self) -> Result<ElementType, Error> {
        Ok(self.ty)
    }

    /// Stage results are tuples; the stub never executes stages, so there
    /// is nothing to untuple.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(err("stub literal: not a tuple (no PJRT backend)"))
    }
}

const NO_BACKEND: &str =
    "XLA PJRT backend not compiled in — rebuild with `--features pjrt` to execute stages";

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(err(NO_BACKEND))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(err(NO_BACKEND))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<std::path::Path>) -> Result<Self, Error> {
        Err(err(NO_BACKEND))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(err(NO_BACKEND))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(err(NO_BACKEND))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, -2.0, 3.5]);
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
        assert_eq!(lit.array_shape().unwrap().dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.0, 3.5]);
    }

    #[test]
    fn reshape_checks_numel() {
        let lit = Literal::vec1(&[0i32; 6]);
        assert!(lit.reshape(&[2, 3]).is_ok());
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn untyped_u8_checks_len() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::U8, &[2, 2], &[0; 4])
            .is_ok());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::U8, &[2, 2], &[0; 5])
            .is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let lit = Literal::vec1(&[1.0f32]);
        assert!(lit.to_vec::<u8>().is_err());
    }

    #[test]
    fn backend_entry_points_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope").is_err());
    }
}
