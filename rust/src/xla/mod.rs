//! Facade over the XLA PJRT bindings.
//!
//! Everything in this crate that touches XLA goes through `crate::xla`
//! (the four consumers are `runtime/`, `model/layer.rs` and
//! `pipeline/mod.rs`). With the `pjrt` feature enabled this module
//! re-exports the real `xla` crate unchanged; without it, [`stub`]
//! provides a data-holding `Literal` implementation (enough for every
//! host-side conversion and test) plus PJRT types whose entry points
//! return a clear "backend not compiled in" error.
//!
//! The split exists so `cargo build && cargo test` work on machines
//! without the XLA C++ toolchain: all container / codec / quantizer /
//! decode-path tests run for real, and only the stage-execution tests
//! (which already gate on built artifacts) are out of reach.

#[cfg(feature = "pjrt")]
pub use ::xla::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;
