//! Host-side MoE serving: the seam where the dynamic batcher hands the
//! expert scheduler *whole batches*.
//!
//! The XLA engine does not lower MoE block stages yet (ROADMAP), so the
//! MoE forward runs host-side — but the serving topology is the same as
//! the dense coordinator's: one dedicated thread per model, an mpsc
//! queue in front, and [`collect_batch`] grouping concurrent requests up
//! to the batch policy. Every forward step then routes **all** live
//! sequences together through [`ExpertScheduler::forward_batch`], which
//! is exactly where cross-request expert-decode dedup and router-logit
//! prefetch pay off: two users whose tokens route to the same expert
//! cost one decode, and the next layer's likely experts warm while the
//! current one computes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{MoeSpec, ServeOptions};
use crate::coordinator::batcher::{collect_batch_by, BatchPolicy};
use crate::faults::MoeError;
use crate::format::TqmReader;
use crate::model::moe::{load_routers, Router};
use crate::pipeline::{ExpertCache, ExpertScheduler, PipelineMetrics, SchedOptions};
use crate::trace::{self, Category};

/// Process-wide request id sequence — every submitted trace gets one, so
/// flight-recorder spans from different hosts never collide.
static NEXT_REQ_ID: AtomicU64 = AtomicU64::new(0);

/// How long past a request's deadline [`MoeHost::generate`] keeps waiting
/// before declaring the serving thread wedged. The serving loop answers
/// expired requests with a structured Timeout at the next step boundary;
/// a response further overdue than this means no step boundary is being
/// reached (a stuck decode, a deadlocked worker) and blocking longer
/// would just hang the client.
const WATCHDOG_GRACE: Duration = Duration::from_millis(500);

/// What a client submits: a trace of token vectors (one per decode step)
/// to forward through the MoE stack.
pub struct MoeTraceRequest {
    pub trace: Vec<Vec<f32>>,
}

/// Per-request result: the stack output for every step of the trace.
#[derive(Clone, Debug)]
pub struct MoeTraceResponse {
    pub outputs: Vec<Vec<f32>>,
    pub queue_s: f64,
    pub forward_s: f64,
}

struct Envelope {
    req: MoeTraceRequest,
    /// Flight-recorder request id (threads queue + request spans).
    req_id: u64,
    enqueued: Instant,
    /// Hard completion deadline (from `ServeOptions::deadline_ms`); past
    /// it the request is answered with [`MoeError::Timeout`] instead of
    /// stepping further.
    deadline: Option<Instant>,
    resp: mpsc::Sender<Result<MoeTraceResponse>>,
}

/// How to build the host: the compressed MoE container plus the serving
/// knobs (batcher policy, expert budget, prefetch slice/workers).
pub struct MoeHostSpec {
    pub reader: Arc<TqmReader>,
    pub n_layers: usize,
    pub moe: MoeSpec,
    pub serve: ServeOptions,
    /// Scheduler overrides; `None` derives them from `serve`.
    pub sched: Option<SchedOptions>,
}

/// Handle to one MoE serving thread.
pub struct MoeHost {
    tx: mpsc::Sender<Envelope>,
    /// Shared scheduler/cache metrics (dedup factor, prefetch hit/waste,
    /// expert stall) — live while the thread serves.
    pub metrics: Arc<PipelineMetrics>,
    /// Per-request completion budget (`ServeOptions::deadline_ms`; None
    /// when 0 = unbounded).
    deadline: Option<Duration>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl MoeHost {
    /// Start the serving thread. Routers load eagerly so container
    /// problems surface here, not on the first request.
    pub fn start(spec: MoeHostSpec) -> Result<Self> {
        anyhow::ensure!(
            !spec.reader.expert_entries().is_empty(),
            "container has no expert records (dense model?)"
        );
        // arm the flight recorder if TQM_TRACE_DIR is set; a malformed
        // knob fails host startup loudly rather than silently not tracing
        trace::init_from_env()?;
        let routers = load_routers(&spec.reader, spec.n_layers)?;
        let metrics = Arc::new(PipelineMetrics::default());
        // a chaos harness wants its injection tallies next to the
        // retry/quarantine counters they cause
        if let Some(plan) = spec.reader.fault_plan() {
            plan.bind_metrics(metrics.clone());
        }
        let cache = ExpertCache::from_options(spec.reader.clone(), metrics.clone(), &spec.serve);
        let sched_opts = spec
            .sched
            .clone()
            .unwrap_or_else(|| SchedOptions::from_serve(&spec.serve));
        let sched = ExpertScheduler::new(
            spec.reader.clone(),
            metrics.clone(),
            cache,
            spec.n_layers,
            spec.moe.n_experts,
            sched_opts,
        );
        let policy = BatchPolicy {
            max_batch: spec.serve.max_batch.max(1),
            max_wait: Duration::from_millis(spec.serve.max_wait_ms),
        };
        let moe = spec.moe.clone();
        let deadline =
            (spec.serve.deadline_ms > 0).then(|| Duration::from_millis(spec.serve.deadline_ms));
        let (tx, rx) = mpsc::channel::<Envelope>();
        let join = std::thread::Builder::new()
            .name("serve-moe-host".into())
            .spawn(move || serve_loop(rx, policy, sched, routers, moe))?;
        Ok(Self { tx, metrics, deadline, join: Some(join) })
    }

    /// Submit a trace; returns a receiver for the response. The request's
    /// deadline clock (when `ServeOptions::deadline_ms` is set) starts
    /// now — queueing time counts against it.
    pub fn submit(
        &self,
        req: MoeTraceRequest,
    ) -> Result<mpsc::Receiver<Result<MoeTraceResponse>>> {
        let deadline = self.deadline.map(|d| Instant::now() + d);
        self.submit_at(req, deadline)
    }

    fn submit_at(
        &self,
        req: MoeTraceRequest,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Result<MoeTraceResponse>>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let req_id = NEXT_REQ_ID.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Envelope { req, req_id, enqueued: Instant::now(), deadline, resp: resp_tx })
            .map_err(|_| anyhow::anyhow!("MoE serving thread is gone"))?;
        Ok(resp_rx)
    }

    /// Submit and block for the response, with a liveness watchdog: if
    /// the serving thread exits without answering, or a deadlined request
    /// is overdue past [`WATCHDOG_GRACE`] (the serving loop is wedged —
    /// no step boundary is being reached), this returns a structured
    /// [`MoeError::Aborted`] instead of hanging forever.
    pub fn generate(&self, req: MoeTraceRequest) -> Result<MoeTraceResponse> {
        let deadline = self.deadline.map(|d| Instant::now() + d);
        let rx = self.submit_at(req, deadline)?;
        loop {
            match rx.recv_timeout(Duration::from_millis(100)) {
                Ok(r) => return r,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("response channel closed")
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if self.join.as_ref().map(|j| j.is_finished()).unwrap_or(true) {
                        return Err(anyhow::Error::new(MoeError::Aborted(
                            "MoE serving thread exited without answering".into(),
                        )));
                    }
                    if let Some(d) = deadline {
                        if Instant::now() > d + WATCHDOG_GRACE {
                            return Err(anyhow::Error::new(MoeError::Aborted(
                                "response overdue past deadline + grace (serving loop wedged)"
                                    .into(),
                            )));
                        }
                    }
                }
            }
        }
    }

    /// Stop the serving thread (drains the queue first), then flush the
    /// run's observability artifacts: a `METRICS_moe_host.json` counter
    /// snapshot into `TQM_BENCH_DIR` and any recorded trace into
    /// `TQM_TRACE_DIR`. Both are no-ops when their knob is unset.
    pub fn shutdown(self) {
        let MoeHost { tx, join, metrics, .. } = self;
        drop(tx);
        if let Some(j) = join {
            let _ = j.join();
        }
        match crate::barometer::emit_named("METRICS_moe_host.json", &metrics.to_json()) {
            Ok(_) => {}
            Err(e) => eprintln!("warning: metrics snapshot not written: {e:#}"),
        }
        match trace::write_run("moe_host") {
            Ok(_) => {}
            Err(e) => eprintln!("warning: trace not written: {e:#}"),
        }
    }
}

/// One request mid-flight: its trace cursor and accumulated outputs.
struct ActiveTrace {
    env: Envelope,
    outputs: Vec<Vec<f32>>,
    cursor: usize,
    started: Instant,
}

fn serve_loop(
    rx: mpsc::Receiver<Envelope>,
    policy: BatchPolicy,
    sched: ExpertScheduler,
    routers: Vec<Router>,
    moe: MoeSpec,
) {
    loop {
        // the drain window shrinks to the earliest request deadline in
        // the forming batch — a request with little budget left must not
        // spend it queueing for batch-mates
        let batch = {
            let _drain = trace::span(Category::Drain, "batch_drain");
            collect_batch_by(&rx, policy, |env: &Envelope| env.deadline)
        };
        if batch.is_empty() {
            return; // disconnected and drained
        }
        serve_trace_batch(&sched, &routers, &moe, batch);
    }
}

fn serve_trace_batch(
    sched: &ExpertScheduler,
    routers: &[Router],
    moe: &MoeSpec,
    batch: Vec<Envelope>,
) {
    let now = Instant::now();
    let mut active: Vec<ActiveTrace> = batch
        .into_iter()
        .map(|env| ActiveTrace { env, outputs: Vec::new(), cursor: 0, started: now })
        .collect();
    for a in &active {
        // the queue window closed when the batch formed; its start
        // predates this thread seeing the envelope, so it is recorded
        // from the measured enqueue instant rather than a live guard
        trace::span_between(Category::Queue, "queue", a.env.req_id, a.env.enqueued, now);
    }
    // retire zero-length traces up front: they are already complete, but
    // they never enter `live`, so the retire loop below would drop their
    // response channel without ever answering (the client's recv() then
    // fails with "channel closed" instead of an empty Ok)
    for a in &active {
        if a.env.req.trace.is_empty() {
            let queue_s = (a.started - a.env.enqueued).as_secs_f64().max(0.0);
            trace::span_between(
                Category::Request,
                "request",
                a.env.req_id,
                a.started,
                Instant::now(),
            );
            let _ = a.env.resp.send(Ok(MoeTraceResponse {
                outputs: Vec::new(),
                queue_s,
                forward_s: a.started.elapsed().as_secs_f64(),
            }));
        }
    }
    loop {
        // deadline retirement: a trace past its deadline gets a
        // structured Timeout at this step boundary instead of consuming
        // more forward steps (partial outputs are dropped — a timed-out
        // request has no well-defined result)
        let now = Instant::now();
        for a in active.iter_mut() {
            if a.cursor >= a.env.req.trace.len() {
                continue;
            }
            if let Some(d) = a.env.deadline {
                if now >= d {
                    sched.metrics().record_deadline_timeout();
                    trace::mark(Category::Fault, "deadline_timeout").req(a.env.req_id);
                    trace::span_between(
                        Category::Request,
                        "request",
                        a.env.req_id,
                        a.started,
                        now,
                    );
                    let _ = a.env.resp.send(Err(anyhow::Error::new(MoeError::Timeout)));
                    a.cursor = a.env.req.trace.len(); // retire
                    a.outputs.clear();
                }
            }
        }
        let live: Vec<usize> = (0..active.len())
            .filter(|&i| active[i].cursor < active[i].env.req.trace.len())
            .collect();
        if live.is_empty() {
            break;
        }
        // the batcher's whole batch, one step at a time: every live
        // sequence's current vector goes to the scheduler together
        let xs: Vec<Vec<f32>> =
            live.iter().map(|&i| active[i].env.req.trace[active[i].cursor].clone()).collect();
        match sched.forward_batch(routers, moe, &xs) {
            Ok(outs) => {
                for (&i, y) in live.iter().zip(outs) {
                    let a = &mut active[i];
                    a.outputs.push(y);
                    a.cursor += 1;
                }
            }
            Err(e) => {
                let msg = format!("moe forward failed: {e}");
                let typed = e.downcast_ref::<MoeError>().cloned();
                for &i in &live {
                    // keep the typed error downcastable per trace (the
                    // context preserves the human-readable message)
                    let err = match &typed {
                        Some(me) => anyhow::Error::new(me.clone()).context(msg.clone()),
                        None => anyhow::anyhow!("{msg}"),
                    };
                    trace::mark(Category::Fault, "forward_error").req(active[i].env.req_id);
                    trace::span_between(
                        Category::Request,
                        "request",
                        active[i].env.req_id,
                        active[i].started,
                        Instant::now(),
                    );
                    let _ = active[i].env.resp.send(Err(err));
                    active[i].cursor = active[i].env.req.trace.len(); // retire
                    active[i].outputs.clear();
                }
                return;
            }
        }
        // retire finished traces immediately (short requests don't wait
        // for the longest one in the batch)
        for &i in &live {
            let a = &mut active[i];
            if a.cursor == a.env.req.trace.len() {
                let queue_s = (a.started - a.env.enqueued).as_secs_f64().max(0.0);
                trace::span_between(
                    Category::Request,
                    "request",
                    a.env.req_id,
                    a.started,
                    Instant::now(),
                );
                let _ = a.env.resp.send(Ok(MoeTraceResponse {
                    outputs: std::mem::take(&mut a.outputs),
                    queue_s,
                    forward_s: a.started.elapsed().as_secs_f64(),
                }));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::QuantizeOptions;
    use crate::model::moe::{
        clustered_trace, moe_demo_config, moe_stack_forward, quantize_moe_checkpoint,
        synth_moe_checkpoint, ExpertWeights,
    };
    use crate::util::TempDir;

    fn demo() -> (crate::config::ModelConfig, TempDir, Arc<TqmReader>) {
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, 77).unwrap();
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, "unit")
            .unwrap()
            .with_chunk_len(512);
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        (cfg, dir, Arc::new(TqmReader::open(&p).unwrap()))
    }

    #[test]
    fn concurrent_traces_batch_and_match_the_reference_forward() {
        let (cfg, _dir, reader) = demo();
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let host = MoeHost::start(MoeHostSpec {
            reader: reader.clone(),
            n_layers: cfg.n_layers,
            moe: spec.clone(),
            serve: ServeOptions {
                max_batch: 3,
                max_wait_ms: 100,
                n_threads: 1,
                ..Default::default()
            },
            sched: Some(SchedOptions {
                sync_prefetch: true,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 3, 6, 19);
        let rxs: Vec<_> = (0..3)
            .map(|_| host.submit(MoeTraceRequest { trace: trace.clone() }).unwrap())
            .collect();
        // reference: fully-resident per-sequence forward, fresh decodes
        let resident: Vec<Vec<Arc<ExpertWeights>>> = (0..cfg.n_layers)
            .map(|l| {
                (0..spec.n_experts)
                    .map(|e| Arc::new(ExpertWeights::load(&reader, l, e).unwrap()))
                    .collect()
            })
            .collect();
        let want: Vec<Vec<f32>> = trace
            .iter()
            .map(|x| {
                moe_stack_forward(&routers, &spec, x, |l, e| Ok(resident[l][e].clone()))
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.outputs, want, "hosted MoE forward diverged");
            assert!(resp.forward_s >= 0.0);
        }
        let m = host.metrics.clone();
        // every step planned through the scheduler; identical concurrent
        // traces can never fetch more than the per-sequence pick count
        assert!(m.sched_plans_count() > 0, "requests bypassed the scheduler");
        assert!(m.sched_planned_fetches() <= m.sched_routed_picks());
        host.shutdown();
    }

    #[test]
    fn empty_trace_completes_immediately() {
        let (cfg, _dir, reader) = demo();
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: cfg.moe.clone().unwrap(),
            serve: ServeOptions { max_wait_ms: 1, ..Default::default() },
            sched: None,
        })
        .unwrap();
        let resp = host.generate(MoeTraceRequest { trace: Vec::new() }).unwrap();
        assert!(resp.outputs.is_empty());
        host.shutdown();
    }

    #[test]
    fn empty_trace_in_a_mixed_batch_still_gets_a_response() {
        // regression: an empty trace never enters the step loop's `live`
        // set, so before the up-front retire it was dropped unanswered —
        // its client saw "response channel closed" instead of Ok
        let (cfg, _dir, reader) = demo();
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let host = MoeHost::start(MoeHostSpec {
            reader: reader.clone(),
            n_layers: cfg.n_layers,
            moe: spec.clone(),
            // long max_wait so both requests land in ONE batch
            serve: ServeOptions { max_batch: 2, max_wait_ms: 2000, ..Default::default() },
            sched: Some(SchedOptions {
                sync_prefetch: true,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 3, 4, 23);
        let rx_empty = host.submit(MoeTraceRequest { trace: Vec::new() }).unwrap();
        let rx_full = host.submit(MoeTraceRequest { trace: trace.clone() }).unwrap();

        let resp_empty = rx_empty.recv().unwrap().unwrap();
        assert!(resp_empty.outputs.is_empty());
        assert!(resp_empty.queue_s >= 0.0 && resp_empty.forward_s >= 0.0);

        let resident: Vec<Vec<Arc<ExpertWeights>>> = (0..cfg.n_layers)
            .map(|l| {
                (0..spec.n_experts)
                    .map(|e| Arc::new(ExpertWeights::load(&reader, l, e).unwrap()))
                    .collect()
            })
            .collect();
        let want: Vec<Vec<f32>> = trace
            .iter()
            .map(|x| {
                moe_stack_forward(&routers, &spec, x, |l, e| Ok(resident[l][e].clone()))
                    .unwrap()
            })
            .collect();
        let resp_full = rx_full.recv().unwrap().unwrap();
        assert_eq!(resp_full.outputs, want, "empty batchmate corrupted the full trace");
        host.shutdown();
    }

    #[test]
    fn mixed_length_traces_retire_early_with_correct_outputs() {
        let (cfg, _dir, reader) = demo();
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let host = MoeHost::start(MoeHostSpec {
            reader: reader.clone(),
            n_layers: cfg.n_layers,
            moe: spec.clone(),
            serve: ServeOptions { max_batch: 2, max_wait_ms: 2000, ..Default::default() },
            sched: Some(SchedOptions {
                sync_prefetch: true,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let base = clustered_trace(cfg.d_model, 2, 3, 6, 29);
        let short: Vec<Vec<f32>> = base[..2].to_vec();
        let long: Vec<Vec<f32>> = base.clone();
        let rx_short = host.submit(MoeTraceRequest { trace: short.clone() }).unwrap();
        let rx_long = host.submit(MoeTraceRequest { trace: long.clone() }).unwrap();

        let resident: Vec<Vec<Arc<ExpertWeights>>> = (0..cfg.n_layers)
            .map(|l| {
                (0..spec.n_experts)
                    .map(|e| Arc::new(ExpertWeights::load(&reader, l, e).unwrap()))
                    .collect()
            })
            .collect();
        let reference = |trace: &[Vec<f32>]| -> Vec<Vec<f32>> {
            trace
                .iter()
                .map(|x| {
                    moe_stack_forward(&routers, &spec, x, |l, e| Ok(resident[l][e].clone()))
                        .unwrap()
                })
                .collect()
        };

        let resp_short = rx_short.recv().unwrap().unwrap();
        let resp_long = rx_long.recv().unwrap().unwrap();
        assert_eq!(resp_short.outputs.len(), 2);
        assert_eq!(resp_long.outputs.len(), base.len());
        assert_eq!(resp_short.outputs, reference(&short), "short trace diverged");
        assert_eq!(resp_long.outputs, reference(&long), "long trace diverged");
        // the short trace retired at its own final step, not the batch's:
        // its response was sent strictly before the long trace finished
        assert!(
            resp_short.forward_s <= resp_long.forward_s,
            "short trace waited for the long one ({} > {})",
            resp_short.forward_s,
            resp_long.forward_s
        );
        assert!(resp_short.queue_s >= 0.0 && resp_long.queue_s >= 0.0);
        host.shutdown();
    }

    #[test]
    fn deadline_exceeded_is_answered_with_structured_timeout() {
        let (cfg, _dir, reader) = demo();
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: cfg.moe.clone().unwrap(),
            // deadline far below max_wait: the batcher dispatches at the
            // deadline and the serve loop's first boundary check retires
            // the request with Timeout — deterministic, no racing
            serve: ServeOptions {
                max_batch: 4,
                max_wait_ms: 2000,
                deadline_ms: 10,
                ..Default::default()
            },
            sched: None,
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 3, 4, 37);
        let err = host
            .generate(MoeTraceRequest { trace })
            .expect_err("expired request returned Ok");
        match err.downcast_ref::<MoeError>() {
            Some(MoeError::Timeout) => {}
            other => panic!("expected structured Timeout, got {other:?} ({err})"),
        }
        assert_eq!(host.metrics.deadline_timeouts_count(), 1);
        host.shutdown();
    }

    #[test]
    fn watchdog_aborts_instead_of_hanging_on_a_wedged_step() {
        // a record source that sleeps 200 ms per expert payload access:
        // one forward step takes >1 s, far past deadline + grace, and no
        // step boundary is reached meanwhile — generate()'s watchdog
        // must abort the wait instead of blocking on the wedged thread
        struct SlowSource;
        impl crate::faults::RecordSource for SlowSource {
            fn fetch<'a>(
                &self,
                name: &str,
                payload: &'a [u8],
            ) -> Result<std::borrow::Cow<'a, [u8]>> {
                if name.contains(".experts.") {
                    std::thread::sleep(Duration::from_millis(200));
                }
                Ok(std::borrow::Cow::Borrowed(payload))
            }
        }
        let (cfg, dir, _reader) = demo();
        let reader = Arc::new(
            TqmReader::open(dir.join("moe.tqm"))
                .unwrap()
                .with_record_source(Arc::new(SlowSource)),
        );
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: cfg.moe.clone().unwrap(),
            // deadline generous enough that the step *starts* (dispatch
            // happens at max_wait, well inside it), then wedges
            serve: ServeOptions {
                max_batch: 1,
                max_wait_ms: 1,
                deadline_ms: 150,
                ..Default::default()
            },
            sched: Some(SchedOptions {
                prefetch: false,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 1, 1, 41);
        let t0 = Instant::now();
        let err = host
            .generate(MoeTraceRequest { trace })
            .expect_err("wedged step returned Ok before its sleeps could finish");
        match err.downcast_ref::<MoeError>() {
            Some(MoeError::Aborted(_)) => {}
            other => panic!("expected structured Aborted, got {other:?} ({err})"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "watchdog took {:?}",
            t0.elapsed()
        );
        host.shutdown(); // joins: the wedged step finishes its sleeps
    }

    #[test]
    fn mid_batch_forward_error_reaches_every_still_live_trace() {
        use crate::format::{TqmMeta, TqmWriter};
        use crate::quant::{uniform, Bits, Granularity};
        use crate::tensor::Tensor;

        // a 1-layer container whose spec claims 8 experts but whose
        // records only hold experts 0..=6 — routing to expert 7 makes
        // forward_batch fail mid-trace, deterministically
        let mut cfg = moe_demo_config();
        cfg.n_layers = 1;
        let spec = cfg.moe.clone().unwrap();
        let ckpt = synth_moe_checkpoint(&cfg, 7).unwrap();
        // crafted router (shape [d_model, n_experts], row-major): a
        // one-hot e0 input routes to experts {0, 1}; a one-hot e1 input
        // routes to the missing {7, 6}
        let mut wr = vec![0.0f32; cfg.d_model * spec.n_experts];
        wr[0] = 10.0;
        wr[1] = 9.0;
        wr[spec.n_experts + 6] = 9.0;
        wr[spec.n_experts + 7] = 10.0;
        let router = Tensor::new(vec![cfg.d_model, spec.n_experts], wr).unwrap();
        let meta = TqmMeta {
            model_name: cfg.name.clone(),
            codec: CodecId::FreqSeqPacked,
            bits: Bits::B8,
            per_channel: false,
            quantizer: "naive".into(),
            source_checkpoint: "unit".into(),
        };
        let mut w = TqmWriter::new(meta).with_chunk_len(512);
        w.add_router(0, &router);
        for e in 0..spec.n_experts - 1 {
            for mat in ["w1", "w3", "w2"] {
                let t = ckpt.f32(&crate::format::expert_record_name(0, e, mat)).unwrap();
                w.add_expert_quantized(
                    0,
                    e,
                    mat,
                    &uniform::quantize(t, Bits::B8, Granularity::PerTensor).unwrap(),
                );
            }
        }
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe-missing-expert.tqm");
        w.write(&p).unwrap();
        let reader = Arc::new(TqmReader::open(&p).unwrap());

        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: 1,
            moe: spec.clone(),
            serve: ServeOptions { max_batch: 3, max_wait_ms: 2000, ..Default::default() },
            sched: Some(SchedOptions { prefetch: false, ..SchedOptions::default() }),
        })
        .unwrap();

        let mut x_a = vec![0.0f32; cfg.d_model];
        x_a[0] = 1.0; // routes to resident experts {0, 1}
        let mut x_b = vec![0.0f32; cfg.d_model];
        x_b[1] = 1.0; // routes to {7, 6} — expert 7 has no record

        // long hits the missing expert at step 2 (0-based); short retires
        // Ok after step 0; other is still live when the failure lands
        let long = vec![x_a.clone(), x_a.clone(), x_b, x_a.clone()];
        let short = vec![x_a.clone()];
        let other = vec![x_a.clone(), x_a.clone(), x_a.clone(), x_a];
        let rx_long = host.submit(MoeTraceRequest { trace: long }).unwrap();
        let rx_short = host.submit(MoeTraceRequest { trace: short }).unwrap();
        let rx_other = host.submit(MoeTraceRequest { trace: other }).unwrap();

        // the short trace finished before the poisoned step and must
        // still succeed
        let resp_short = rx_short.recv().unwrap().unwrap();
        assert_eq!(resp_short.outputs.len(), 1);

        // both still-live traces get the error — neither hangs, neither
        // sees a half-finished Ok
        let err_long = rx_long.recv().unwrap();
        let err_other = rx_other.recv().unwrap();
        for (who, r) in [("long", err_long), ("other", err_other)] {
            let e = r.expect_err("still-live trace got Ok past a failed forward");
            assert!(
                e.to_string().contains("moe forward failed"),
                "{who} got an unexpected error: {e}"
            );
        }
        host.shutdown();
    }
}
