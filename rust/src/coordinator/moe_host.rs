//! Host-side MoE serving: continuous batching with overload protection.
//!
//! The XLA engine does not lower MoE block stages yet (ROADMAP), so the
//! MoE forward runs host-side — one dedicated thread per model, an mpsc
//! queue in front. The serving loop batches **continuously**: sequences
//! join the live set the moment they arrive and leave the moment they
//! finish, instead of the whole batch stepping in lockstep until its
//! longest member retires. Every step routes the live sequences together
//! through [`ExpertScheduler::forward_batch`], which is where
//! cross-request expert-decode dedup and router-logit prefetch pay off;
//! per-sequence math is independent of batch composition, so joining or
//! leaving mid-decode never changes any sequence's outputs.
//!
//! In front of the loop sits a bounded [`AdmissionGate`]: a full queue
//! (or a tenant's fair share of it, under contention) answers
//! [`MoeError::Overloaded`] immediately instead of queueing work that
//! cannot be served. Behind it, a [`Backpressure`] controller watches
//! the expert cache — demand-miss stall fraction and eviction churn —
//! and shrinks the admitted step width (and optionally browns the cache
//! out to packed residency) when the cache is thrashing, growing back
//! additively once pressure clears. Requests that predictably cannot
//! meet their deadline are shed **before** any forward work
//! ([`MoeError::Shed`]), counted separately from timeouts, which are
//! charged only after work was actually spent.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{MoeSpec, ServeOptions};
use crate::coordinator::batcher::{collect_batch_by, BatchPolicy};
use crate::faults::MoeError;
use crate::format::TqmReader;
use crate::model::moe::{load_routers, Router};
use crate::pipeline::{ExpertCache, ExpertScheduler, PipelineMetrics, SchedOptions};
use crate::trace::{self, Category};

/// Process-wide request id sequence — every submitted trace gets one, so
/// flight-recorder spans from different hosts never collide.
static NEXT_REQ_ID: AtomicU64 = AtomicU64::new(0);

/// How long past a request's deadline [`MoeHost::generate`] keeps waiting
/// before declaring the serving thread wedged. The serving loop answers
/// expired requests with a structured Timeout at the next step boundary;
/// a response further overdue than this means no step boundary is being
/// reached (a stuck decode, a deadlocked worker) and blocking longer
/// would just hang the client.
const WATCHDOG_GRACE: Duration = Duration::from_millis(500);

/// After this many consecutive pressured steps the backpressure
/// controller browns the expert cache out to packed residency (when
/// `ServeOptions::brownout_packed` allows it): shrinking the step width
/// did not clear the thrash, so trade kernel speed for cache headroom.
const BROWNOUT_AFTER: u32 = 3;

/// Additive-increase cadence: one step of batch width regained per this
/// many consecutive healthy steps (the AI in AIMD; the halving on
/// pressure is the MD).
const GROW_EVERY: u32 = 4;

/// What a client submits: a trace of token vectors (one per decode step)
/// to forward through the MoE stack, tagged with the tenant it bills to.
pub struct MoeTraceRequest {
    pub trace: Vec<Vec<f32>>,
    /// Tenant id for admission accounting. Tenants index into
    /// `ServeOptions::tenant_weights` (ids past the end weigh 1); under
    /// contention each tenant is held to its weighted share of the
    /// admission queue, and `ServeOptions::tenant_quota` caps any one
    /// tenant's in-flight requests outright.
    pub tenant: u32,
}

impl MoeTraceRequest {
    /// A request billed to the default tenant 0.
    pub fn new(trace: Vec<Vec<f32>>) -> Self {
        Self { trace, tenant: 0 }
    }

    /// Bill this request to `tenant` instead.
    pub fn with_tenant(mut self, tenant: u32) -> Self {
        self.tenant = tenant;
        self
    }
}

/// Per-request result: the stack output for every step of the trace.
#[derive(Clone, Debug)]
pub struct MoeTraceResponse {
    pub outputs: Vec<Vec<f32>>,
    pub queue_s: f64,
    pub forward_s: f64,
}

struct Envelope {
    req: MoeTraceRequest,
    /// Flight-recorder request id (threads queue + request spans).
    req_id: u64,
    enqueued: Instant,
    /// Hard completion deadline (from `ServeOptions::deadline_ms`); past
    /// it the request is answered with [`MoeError::Timeout`] instead of
    /// stepping further.
    deadline: Option<Instant>,
    resp: mpsc::Sender<Result<MoeTraceResponse>>,
}

/// How to build the host: the compressed MoE container plus the serving
/// knobs (batcher policy, expert budget, prefetch slice/workers).
pub struct MoeHostSpec {
    pub reader: Arc<TqmReader>,
    pub n_layers: usize,
    pub moe: MoeSpec,
    pub serve: ServeOptions,
    /// Scheduler overrides; `None` derives them from `serve`.
    pub sched: Option<SchedOptions>,
}

/// In-flight bookkeeping behind the admission gate: one global count
/// plus per-tenant counts (a request is "in flight" from admission to
/// its answer — queued or actively decoding both count).
struct GateState {
    total: usize,
    per_tenant: HashMap<u32, usize>,
}

/// Bounded admission with per-tenant fairness. `try_admit` answers
/// structurally (`MoeError::Overloaded`) instead of queueing when the
/// queue is full, the tenant is over its hard quota, or — once the
/// queue is at least half full — the tenant is over its weighted fair
/// share. Shares are computed against the sum of **all configured**
/// tenant weights, so a configured tenant's slice of the queue stays
/// reserved even before its first request arrives; tenants beyond the
/// configured weights table weigh 1 and only count while present.
struct AdmissionGate {
    /// Queue bound (`ServeOptions::admission_queue`); 0 = unbounded.
    max_queue: usize,
    /// Hard per-tenant in-flight cap (`ServeOptions::tenant_quota`);
    /// 0 = off.
    tenant_quota: usize,
    weights: Vec<u32>,
    state: Mutex<GateState>,
    /// EWMA of forward-step wall time in microseconds, fed by the serve
    /// loop. Sizes `Overloaded::retry_after_ms` and the predictive-shed
    /// completion estimate. 0 until the first step completes.
    step_ewma_us: AtomicU64,
}

impl AdmissionGate {
    fn new(serve: &ServeOptions) -> Self {
        Self {
            max_queue: serve.admission_queue,
            tenant_quota: serve.tenant_quota,
            weights: serve.tenant_weights.clone(),
            state: Mutex::new(GateState { total: 0, per_tenant: HashMap::new() }),
            step_ewma_us: AtomicU64::new(0),
        }
    }

    fn weight(&self, tenant: u32) -> u32 {
        self.weights.get(tenant as usize).copied().unwrap_or(1).max(1)
    }

    /// Admit or reject `tenant`'s next request. One lock scope so the
    /// bound check and the increment are atomic against racing clients.
    fn try_admit(&self, tenant: u32) -> std::result::Result<(), MoeError> {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        if self.max_queue > 0 && st.total >= self.max_queue {
            return Err(self.overloaded(st.total));
        }
        let mine = st.per_tenant.get(&tenant).copied().unwrap_or(0);
        if self.tenant_quota > 0 && mine >= self.tenant_quota {
            return Err(self.overloaded(st.total));
        }
        // weighted fairness engages under contention (queue ≥ half
        // full): uncontended, any tenant may use the whole queue
        if self.max_queue > 0 && 2 * st.total >= self.max_queue {
            let mut total_w: u64 =
                self.weights.iter().map(|w| u64::from((*w).max(1))).sum();
            for (&t, &n) in &st.per_tenant {
                if n > 0 && t as usize >= self.weights.len() {
                    total_w += 1;
                }
            }
            if tenant as usize >= self.weights.len() && mine == 0 {
                total_w += 1; // the candidate itself, not yet present
            }
            let share = (self.max_queue as u64 * u64::from(self.weight(tenant))
                / total_w.max(1)) as usize;
            if mine >= share.max(1) {
                return Err(self.overloaded(st.total));
            }
        }
        st.total += 1;
        *st.per_tenant.entry(tenant).or_insert(0) += 1;
        Ok(())
    }

    /// The structured rejection: retry-after sized to the backlog ahead
    /// of the client times the observed step pace, clamped to [1, 1000]
    /// ms so a cold EWMA still tells the client to back off *some*.
    fn overloaded(&self, queued: usize) -> MoeError {
        let ewma_us = self.step_ewma_us.load(Ordering::Relaxed);
        let retry_after_ms = ((queued as u64 + 1) * ewma_us / 1000).clamp(1, 1000);
        MoeError::Overloaded { retry_after_ms }
    }

    /// Settle one in-flight request (answered: completed, timed out,
    /// shed, or aborted — every admit must be matched by exactly one
    /// release, or the gate leaks capacity).
    fn release(&self, tenant: u32) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.total = st.total.saturating_sub(1);
        if let Some(n) = st.per_tenant.get_mut(&tenant) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                st.per_tenant.remove(&tenant);
            }
        }
    }

    fn observe_step(&self, wall: Duration) {
        let us = (wall.as_micros() as u64).max(1);
        let old = self.step_ewma_us.load(Ordering::Relaxed);
        let next = if old == 0 { us } else { (old * 4 + us) / 5 };
        self.step_ewma_us.store(next, Ordering::Relaxed);
    }

    fn step_ewma(&self) -> Duration {
        Duration::from_micros(self.step_ewma_us.load(Ordering::Relaxed))
    }
}

/// The overload-protection knobs the serve loop consults per step,
/// cloned out of `ServeOptions` at startup.
#[derive(Clone)]
struct OverloadKnobs {
    shed_predictive: bool,
    shrink_stall_frac: f64,
    shrink_evictions_per_step: u64,
    brownout_packed: bool,
}

impl OverloadKnobs {
    fn from_serve(serve: &ServeOptions) -> Self {
        Self {
            shed_predictive: serve.shed_predictive,
            shrink_stall_frac: serve.shrink_stall_frac,
            shrink_evictions_per_step: serve.shrink_evictions_per_step,
            brownout_packed: serve.brownout_packed,
        }
    }
}

/// AIMD step-width controller wired to the expert cache: per-step
/// deltas of demand-miss stall fraction and eviction churn against the
/// configured thresholds. Pressure halves the effective batch (and,
/// sustained, browns out to packed residency); [`GROW_EVERY`] healthy
/// steps regain one slot up to the configured maximum.
struct Backpressure {
    max: usize,
    eff: usize,
    knobs: OverloadKnobs,
    metrics: Arc<PipelineMetrics>,
    last_stall_s: f64,
    last_wall_s: f64,
    last_evictions: u64,
    pressured_streak: u32,
    healthy_streak: u32,
}

impl Backpressure {
    fn new(max: usize, knobs: OverloadKnobs, metrics: Arc<PipelineMetrics>) -> Self {
        Self {
            max: max.max(1),
            eff: max.max(1),
            knobs,
            metrics,
            last_stall_s: 0.0,
            last_wall_s: 0.0,
            last_evictions: 0,
            pressured_streak: 0,
            healthy_streak: 0,
        }
    }

    fn enabled(&self) -> bool {
        self.knobs.shrink_stall_frac > 0.0 || self.knobs.shrink_evictions_per_step > 0
    }

    /// Step width the loop may admit right now.
    fn effective(&self) -> usize {
        self.eff
    }

    /// Called after each successful forward step.
    fn observe(&mut self, sched: &ExpertScheduler) {
        if !self.enabled() {
            return;
        }
        let stall = self.metrics.expert_stall_secs();
        let wall = self.metrics.forward_wall_secs();
        let evictions = self.metrics.expert_evictions_count();
        let d_stall = (stall - self.last_stall_s).max(0.0);
        let d_wall = (wall - self.last_wall_s).max(0.0);
        let d_ev = evictions.saturating_sub(self.last_evictions);
        self.last_stall_s = stall;
        self.last_wall_s = wall;
        self.last_evictions = evictions;
        let stalled = self.knobs.shrink_stall_frac > 0.0
            && d_wall > 0.0
            && d_stall / d_wall > self.knobs.shrink_stall_frac;
        let churning = self.knobs.shrink_evictions_per_step > 0
            && d_ev > self.knobs.shrink_evictions_per_step;
        if stalled || churning {
            self.healthy_streak = 0;
            self.pressured_streak += 1;
            if self.eff > 1 {
                self.eff = (self.eff / 2).max(1);
                self.metrics.record_batch_shrink();
                trace::mark(Category::Step, "batch_shrink");
            }
            if self.knobs.brownout_packed && self.pressured_streak >= BROWNOUT_AFTER {
                // idempotent: records the brownout metric and mark only
                // on the actual residency flip
                sched.brownout_to_packed();
            }
        } else {
            self.pressured_streak = 0;
            self.healthy_streak += 1;
            if self.healthy_streak % GROW_EVERY == 0 && self.eff < self.max {
                self.eff += 1;
            }
        }
    }
}

/// Handle to one MoE serving thread.
pub struct MoeHost {
    tx: mpsc::Sender<Envelope>,
    /// Shared scheduler/cache metrics (dedup factor, prefetch hit/waste,
    /// expert stall) — live while the thread serves.
    pub metrics: Arc<PipelineMetrics>,
    /// Per-request completion budget (`ServeOptions::deadline_ms`; None
    /// when 0 = unbounded).
    deadline: Option<Duration>,
    /// Bounded admission + per-tenant fairness; shared with the serving
    /// thread, which releases slots as requests are answered.
    gate: Arc<AdmissionGate>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl MoeHost {
    /// Start the serving thread. Routers load eagerly so container
    /// problems surface here, not on the first request.
    pub fn start(spec: MoeHostSpec) -> Result<Self> {
        anyhow::ensure!(
            !spec.reader.expert_entries().is_empty(),
            "container has no expert records (dense model?)"
        );
        // arm the flight recorder if TQM_TRACE_DIR is set; a malformed
        // knob fails host startup loudly rather than silently not tracing
        trace::init_from_env()?;
        let routers = load_routers(&spec.reader, spec.n_layers)?;
        let metrics = Arc::new(PipelineMetrics::default());
        // a chaos harness wants its injection tallies next to the
        // retry/quarantine counters they cause
        if let Some(plan) = spec.reader.fault_plan() {
            plan.bind_metrics(metrics.clone());
        }
        let cache = ExpertCache::from_options(spec.reader.clone(), metrics.clone(), &spec.serve);
        let sched_opts = spec
            .sched
            .clone()
            .unwrap_or_else(|| SchedOptions::from_serve(&spec.serve));
        let sched = ExpertScheduler::new(
            spec.reader.clone(),
            metrics.clone(),
            cache,
            spec.n_layers,
            spec.moe.n_experts,
            sched_opts,
        );
        let policy = BatchPolicy {
            max_batch: spec.serve.max_batch.max(1),
            max_wait: Duration::from_millis(spec.serve.max_wait_ms),
        };
        let moe = spec.moe.clone();
        let deadline =
            (spec.serve.deadline_ms > 0).then(|| Duration::from_millis(spec.serve.deadline_ms));
        let gate = Arc::new(AdmissionGate::new(&spec.serve));
        let knobs = OverloadKnobs::from_serve(&spec.serve);
        let (tx, rx) = mpsc::channel::<Envelope>();
        let loop_gate = gate.clone();
        let join = std::thread::Builder::new()
            .name("serve-moe-host".into())
            .spawn(move || serve_loop(rx, policy, sched, routers, moe, loop_gate, knobs))?;
        Ok(Self { tx, metrics, deadline, gate, join: Some(join) })
    }

    /// Submit a trace; returns a receiver for the response. The request's
    /// deadline clock (when `ServeOptions::deadline_ms` is set) starts
    /// now — queueing time counts against it.
    pub fn submit(
        &self,
        req: MoeTraceRequest,
    ) -> Result<mpsc::Receiver<Result<MoeTraceResponse>>> {
        let deadline = self.deadline.map(|d| Instant::now() + d);
        self.submit_at(req, deadline)
    }

    fn submit_at(
        &self,
        req: MoeTraceRequest,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<Result<MoeTraceResponse>>> {
        let tenant = req.tenant;
        let req_id = NEXT_REQ_ID.fetch_add(1, Ordering::Relaxed);
        self.metrics.record_submitted();
        if let Err(e) = self.gate.try_admit(tenant) {
            self.metrics.record_rejected();
            trace::mark(Category::Queue, "rejected").req(req_id);
            return Err(anyhow::Error::new(e));
        }
        self.metrics.record_admitted();
        let (resp_tx, resp_rx) = mpsc::channel();
        let env =
            Envelope { req, req_id, enqueued: Instant::now(), deadline, resp: resp_tx };
        if self.tx.send(env).is_err() {
            // admitted but unservable: settle the books as aborted so
            // the admission identity still reconciles
            self.metrics.record_request_aborted();
            self.gate.release(tenant);
            anyhow::bail!("MoE serving thread is gone");
        }
        Ok(resp_rx)
    }

    /// Submit and block for the response, with a liveness watchdog: if
    /// the serving thread exits without answering, or a deadlined request
    /// is overdue past [`WATCHDOG_GRACE`] (the serving loop is wedged —
    /// no step boundary is being reached), this returns a structured
    /// [`MoeError::Aborted`] instead of hanging forever.
    pub fn generate(&self, req: MoeTraceRequest) -> Result<MoeTraceResponse> {
        let deadline = self.deadline.map(|d| Instant::now() + d);
        let rx = self.submit_at(req, deadline)?;
        loop {
            match rx.recv_timeout(Duration::from_millis(100)) {
                Ok(r) => return r,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("response channel closed")
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if self.join.as_ref().map(|j| j.is_finished()).unwrap_or(true) {
                        return Err(anyhow::Error::new(MoeError::Aborted(
                            "MoE serving thread exited without answering".into(),
                        )));
                    }
                    if let Some(d) = deadline {
                        if Instant::now() > d + WATCHDOG_GRACE {
                            return Err(anyhow::Error::new(MoeError::Aborted(
                                "response overdue past deadline + grace (serving loop wedged)"
                                    .into(),
                            )));
                        }
                    }
                }
            }
        }
    }

    /// Stop the serving thread (drains the queue first), then flush the
    /// run's observability artifacts: a `METRICS_moe_host.json` counter
    /// snapshot into `TQM_BENCH_DIR` and any recorded trace into
    /// `TQM_TRACE_DIR`. Both are no-ops when their knob is unset.
    pub fn shutdown(self) {
        let MoeHost { tx, join, metrics, .. } = self;
        drop(tx);
        if let Some(j) = join {
            let _ = j.join();
        }
        match crate::barometer::emit_named("METRICS_moe_host.json", &metrics.to_json()) {
            Ok(_) => {}
            Err(e) => eprintln!("warning: metrics snapshot not written: {e:#}"),
        }
        match trace::write_run("moe_host") {
            Ok(_) => {}
            Err(e) => eprintln!("warning: trace not written: {e:#}"),
        }
    }
}

/// One request mid-flight: its trace cursor and accumulated outputs.
struct ActiveTrace {
    env: Envelope,
    outputs: Vec<Vec<f32>>,
    cursor: usize,
    started: Instant,
}

fn serve_loop(
    rx: mpsc::Receiver<Envelope>,
    policy: BatchPolicy,
    sched: ExpertScheduler,
    routers: Vec<Router>,
    moe: MoeSpec,
    gate: Arc<AdmissionGate>,
    knobs: OverloadKnobs,
) {
    let mut ctl = Backpressure::new(policy.max_batch, knobs, sched.metrics().clone());
    let mut active: Vec<ActiveTrace> = Vec::new();
    loop {
        if active.is_empty() {
            // idle: block on the batcher; the drain window shrinks to
            // the earliest request deadline in the forming batch — a
            // request with little budget left must not spend it queueing
            // for batch-mates
            let batch = {
                let _drain = trace::span(Category::Drain, "batch_drain");
                let p = BatchPolicy { max_batch: ctl.effective(), max_wait: policy.max_wait };
                collect_batch_by(&rx, p, |env: &Envelope| env.deadline)
            };
            if batch.is_empty() {
                return; // disconnected and drained
            }
            join_arrivals(batch, &mut active, &gate, &ctl);
        } else {
            // continuous batching: between steps, pull whatever has
            // arrived without blocking (decoding sequences must not
            // stall on a drain window) up to the effective step width
            let mut room = ctl.effective().saturating_sub(active.len());
            let mut arrivals = Vec::new();
            while room > 0 {
                match rx.try_recv() {
                    Ok(env) => {
                        arrivals.push(env);
                        room -= 1;
                    }
                    Err(_) => break, // empty or disconnected: step on
                }
            }
            if !arrivals.is_empty() {
                join_arrivals(arrivals, &mut active, &gate, &ctl);
            }
        }
        step_once(&sched, &routers, &moe, &gate, &mut ctl, &mut active);
    }
}

/// Fold newly arrived envelopes into the live set. Zero-length traces
/// are answered here (they never enter the step loop, so the retire
/// path would drop their channel unanswered), and — with predictive
/// shedding on — requests whose EWMA-projected completion already
/// overshoots their deadline are answered [`MoeError::Shed`] before any
/// forward work is spent on them.
fn join_arrivals(
    batch: Vec<Envelope>,
    active: &mut Vec<ActiveTrace>,
    gate: &AdmissionGate,
    ctl: &Backpressure,
) {
    let now = Instant::now();
    for env in batch {
        // the queue window closed on arrival here; its start predates
        // this thread seeing the envelope, so it is recorded from the
        // measured enqueue instant rather than a live guard
        trace::span_between(Category::Queue, "queue", env.req_id, env.enqueued, now);
        let queue_s = (now - env.enqueued).as_secs_f64().max(0.0);
        if env.req.trace.is_empty() {
            trace::span_between(Category::Request, "request", env.req_id, now, Instant::now());
            ctl.metrics.record_request_completed();
            gate.release(env.req.tenant);
            let _ = env.resp.send(Ok(MoeTraceResponse {
                outputs: Vec::new(),
                queue_s,
                forward_s: 0.0,
            }));
            continue;
        }
        if ctl.knobs.shed_predictive {
            if let Some(d) = env.deadline {
                let ewma = gate.step_ewma();
                // a cold EWMA (no step observed yet) predicts nothing —
                // admit and let the deadline boundary handle it
                if !ewma.is_zero() {
                    let predicted = ewma.saturating_mul(env.req.trace.len() as u32);
                    if now + predicted > d {
                        ctl.metrics.record_shed();
                        trace::mark(Category::Queue, "shed").req(env.req_id);
                        gate.release(env.req.tenant);
                        let _ = env.resp.send(Err(anyhow::Error::new(MoeError::Shed {
                            predicted_ms: predicted.as_millis() as u64,
                        })));
                        continue;
                    }
                }
            }
        }
        active.push(ActiveTrace { env, outputs: Vec::new(), cursor: 0, started: now });
    }
}

/// One continuous-batching step: retire expired requests, forward the
/// first `effective()` live sequences together, retire the finished,
/// and feed the backpressure controller.
fn step_once(
    sched: &ExpertScheduler,
    routers: &[Router],
    moe: &MoeSpec,
    gate: &AdmissionGate,
    ctl: &mut Backpressure,
    active: &mut Vec<ActiveTrace>,
) {
    // deadline retirement first: a trace past its deadline gets a
    // structured Timeout at this step boundary instead of consuming
    // more forward steps (partial outputs are dropped — a timed-out
    // request has no well-defined result)
    let now = Instant::now();
    active.retain_mut(|a| match a.env.deadline {
        Some(d) if now >= d => {
            sched.metrics().record_deadline_timeout();
            trace::mark(Category::Fault, "deadline_timeout").req(a.env.req_id);
            trace::span_between(Category::Request, "request", a.env.req_id, a.started, now);
            gate.release(a.env.req.tenant);
            let _ = a.env.resp.send(Err(anyhow::Error::new(MoeError::Timeout)));
            false
        }
        _ => true,
    });
    if active.is_empty() {
        return;
    }
    // step the oldest `n` sequences together (FIFO keeps head-of-line
    // latency bounded when backpressure shrinks the width below the
    // live count); their current vectors go to the scheduler as one
    // batch, which is where cross-request expert-decode dedup pays off
    let n = ctl.effective().min(active.len());
    let xs: Vec<Vec<f32>> =
        active[..n].iter().map(|a| a.env.req.trace[a.cursor].clone()).collect();
    let t0 = Instant::now();
    match sched.forward_batch(routers, moe, &xs) {
        Ok(outs) => {
            gate.observe_step(t0.elapsed());
            for (a, y) in active[..n].iter_mut().zip(outs) {
                a.outputs.push(y);
                a.cursor += 1;
            }
            // retire finished traces immediately (short requests don't
            // wait for the longest one in the live set)
            let metrics = sched.metrics().clone();
            active.retain_mut(|a| {
                if a.cursor < a.env.req.trace.len() {
                    return true;
                }
                let queue_s = (a.started - a.env.enqueued).as_secs_f64().max(0.0);
                trace::span_between(
                    Category::Request,
                    "request",
                    a.env.req_id,
                    a.started,
                    Instant::now(),
                );
                metrics.record_request_completed();
                gate.release(a.env.req.tenant);
                let _ = a.env.resp.send(Ok(MoeTraceResponse {
                    outputs: std::mem::take(&mut a.outputs),
                    queue_s,
                    forward_s: a.started.elapsed().as_secs_f64(),
                }));
                false
            });
            ctl.observe(sched);
        }
        Err(e) => {
            // a failed forward poisons the step for everyone currently
            // live (stepped or not): answer all of them structurally —
            // aborted, not timed out — and keep serving new arrivals
            let msg = format!("moe forward failed: {e}");
            let typed = e.downcast_ref::<MoeError>().cloned();
            for a in active.drain(..) {
                // keep the typed error downcastable per trace (the
                // context preserves the human-readable message)
                let err = match &typed {
                    Some(me) => anyhow::Error::new(me.clone()).context(msg.clone()),
                    None => anyhow::anyhow!("{msg}"),
                };
                trace::mark(Category::Fault, "forward_error").req(a.env.req_id);
                trace::span_between(
                    Category::Request,
                    "request",
                    a.env.req_id,
                    a.started,
                    Instant::now(),
                );
                sched.metrics().record_request_aborted();
                gate.release(a.env.req.tenant);
                let _ = a.env.resp.send(Err(err));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::QuantizeOptions;
    use crate::model::moe::{
        clustered_trace, moe_demo_config, moe_stack_forward, quantize_moe_checkpoint,
        synth_moe_checkpoint, ExpertWeights,
    };
    use crate::util::TempDir;

    fn demo() -> (crate::config::ModelConfig, TempDir, Arc<TqmReader>) {
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, 77).unwrap();
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, "unit")
            .unwrap()
            .with_chunk_len(512);
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        (cfg, dir, Arc::new(TqmReader::open(&p).unwrap()))
    }

    #[test]
    fn concurrent_traces_batch_and_match_the_reference_forward() {
        let (cfg, _dir, reader) = demo();
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let host = MoeHost::start(MoeHostSpec {
            reader: reader.clone(),
            n_layers: cfg.n_layers,
            moe: spec.clone(),
            serve: ServeOptions {
                max_batch: 3,
                max_wait_ms: 100,
                n_threads: 1,
                ..Default::default()
            },
            sched: Some(SchedOptions {
                sync_prefetch: true,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 3, 6, 19);
        let rxs: Vec<_> = (0..3)
            .map(|_| host.submit(MoeTraceRequest::new(trace.clone())).unwrap())
            .collect();
        // reference: fully-resident per-sequence forward, fresh decodes
        let resident: Vec<Vec<Arc<ExpertWeights>>> = (0..cfg.n_layers)
            .map(|l| {
                (0..spec.n_experts)
                    .map(|e| Arc::new(ExpertWeights::load(&reader, l, e).unwrap()))
                    .collect()
            })
            .collect();
        let want: Vec<Vec<f32>> = trace
            .iter()
            .map(|x| {
                moe_stack_forward(&routers, &spec, x, |l, e| Ok(resident[l][e].clone()))
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.outputs, want, "hosted MoE forward diverged");
            assert!(resp.forward_s >= 0.0);
        }
        let m = host.metrics.clone();
        // every step planned through the scheduler; identical concurrent
        // traces can never fetch more than the per-sequence pick count
        assert!(m.sched_plans_count() > 0, "requests bypassed the scheduler");
        assert!(m.sched_planned_fetches() <= m.sched_routed_picks());
        host.shutdown();
    }

    #[test]
    fn empty_trace_completes_immediately() {
        let (cfg, _dir, reader) = demo();
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: cfg.moe.clone().unwrap(),
            serve: ServeOptions { max_wait_ms: 1, ..Default::default() },
            sched: None,
        })
        .unwrap();
        let resp = host.generate(MoeTraceRequest::new(Vec::new())).unwrap();
        assert!(resp.outputs.is_empty());
        host.shutdown();
    }

    #[test]
    fn empty_trace_in_a_mixed_batch_still_gets_a_response() {
        // regression: an empty trace never enters the step loop's `live`
        // set, so before the up-front retire it was dropped unanswered —
        // its client saw "response channel closed" instead of Ok
        let (cfg, _dir, reader) = demo();
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let host = MoeHost::start(MoeHostSpec {
            reader: reader.clone(),
            n_layers: cfg.n_layers,
            moe: spec.clone(),
            // long max_wait so both requests land in ONE batch
            serve: ServeOptions { max_batch: 2, max_wait_ms: 2000, ..Default::default() },
            sched: Some(SchedOptions {
                sync_prefetch: true,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 3, 4, 23);
        let rx_empty = host.submit(MoeTraceRequest::new(Vec::new())).unwrap();
        let rx_full = host.submit(MoeTraceRequest::new(trace.clone())).unwrap();

        let resp_empty = rx_empty.recv().unwrap().unwrap();
        assert!(resp_empty.outputs.is_empty());
        assert!(resp_empty.queue_s >= 0.0 && resp_empty.forward_s >= 0.0);

        let resident: Vec<Vec<Arc<ExpertWeights>>> = (0..cfg.n_layers)
            .map(|l| {
                (0..spec.n_experts)
                    .map(|e| Arc::new(ExpertWeights::load(&reader, l, e).unwrap()))
                    .collect()
            })
            .collect();
        let want: Vec<Vec<f32>> = trace
            .iter()
            .map(|x| {
                moe_stack_forward(&routers, &spec, x, |l, e| Ok(resident[l][e].clone()))
                    .unwrap()
            })
            .collect();
        let resp_full = rx_full.recv().unwrap().unwrap();
        assert_eq!(resp_full.outputs, want, "empty batchmate corrupted the full trace");
        host.shutdown();
    }

    #[test]
    fn mixed_length_traces_retire_early_with_correct_outputs() {
        let (cfg, _dir, reader) = demo();
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&reader, cfg.n_layers).unwrap();
        let host = MoeHost::start(MoeHostSpec {
            reader: reader.clone(),
            n_layers: cfg.n_layers,
            moe: spec.clone(),
            serve: ServeOptions { max_batch: 2, max_wait_ms: 2000, ..Default::default() },
            sched: Some(SchedOptions {
                sync_prefetch: true,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let base = clustered_trace(cfg.d_model, 2, 3, 6, 29);
        let short: Vec<Vec<f32>> = base[..2].to_vec();
        let long: Vec<Vec<f32>> = base.clone();
        let rx_short = host.submit(MoeTraceRequest::new(short.clone())).unwrap();
        let rx_long = host.submit(MoeTraceRequest::new(long.clone())).unwrap();

        let resident: Vec<Vec<Arc<ExpertWeights>>> = (0..cfg.n_layers)
            .map(|l| {
                (0..spec.n_experts)
                    .map(|e| Arc::new(ExpertWeights::load(&reader, l, e).unwrap()))
                    .collect()
            })
            .collect();
        let reference = |trace: &[Vec<f32>]| -> Vec<Vec<f32>> {
            trace
                .iter()
                .map(|x| {
                    moe_stack_forward(&routers, &spec, x, |l, e| Ok(resident[l][e].clone()))
                        .unwrap()
                })
                .collect()
        };

        let resp_short = rx_short.recv().unwrap().unwrap();
        let resp_long = rx_long.recv().unwrap().unwrap();
        assert_eq!(resp_short.outputs.len(), 2);
        assert_eq!(resp_long.outputs.len(), base.len());
        assert_eq!(resp_short.outputs, reference(&short), "short trace diverged");
        assert_eq!(resp_long.outputs, reference(&long), "long trace diverged");
        // the short trace retired at its own final step, not the batch's:
        // its response was sent strictly before the long trace finished
        assert!(
            resp_short.forward_s <= resp_long.forward_s,
            "short trace waited for the long one ({} > {})",
            resp_short.forward_s,
            resp_long.forward_s
        );
        assert!(resp_short.queue_s >= 0.0 && resp_long.queue_s >= 0.0);
        host.shutdown();
    }

    #[test]
    fn deadline_exceeded_is_answered_with_structured_timeout() {
        let (cfg, _dir, reader) = demo();
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: cfg.moe.clone().unwrap(),
            // deadline far below max_wait: the batcher dispatches at the
            // deadline and the serve loop's first boundary check retires
            // the request with Timeout — deterministic, no racing
            serve: ServeOptions {
                max_batch: 4,
                max_wait_ms: 2000,
                deadline_ms: 10,
                ..Default::default()
            },
            sched: None,
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 3, 4, 37);
        let err = host
            .generate(MoeTraceRequest::new(trace))
            .expect_err("expired request returned Ok");
        match err.downcast_ref::<MoeError>() {
            Some(MoeError::Timeout) => {}
            other => panic!("expected structured Timeout, got {other:?} ({err})"),
        }
        assert_eq!(host.metrics.deadline_timeouts_count(), 1);
        host.shutdown();
    }

    #[test]
    fn watchdog_aborts_instead_of_hanging_on_a_wedged_step() {
        // a record source that sleeps 200 ms per expert payload access:
        // one forward step takes >1 s, far past deadline + grace, and no
        // step boundary is reached meanwhile — generate()'s watchdog
        // must abort the wait instead of blocking on the wedged thread
        struct SlowSource;
        impl crate::faults::RecordSource for SlowSource {
            fn fetch<'a>(
                &self,
                name: &str,
                payload: &'a [u8],
            ) -> Result<std::borrow::Cow<'a, [u8]>> {
                if name.contains(".experts.") {
                    std::thread::sleep(Duration::from_millis(200));
                }
                Ok(std::borrow::Cow::Borrowed(payload))
            }
        }
        let (cfg, dir, _reader) = demo();
        let reader = Arc::new(
            TqmReader::open(dir.join("moe.tqm"))
                .unwrap()
                .with_record_source(Arc::new(SlowSource)),
        );
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: cfg.moe.clone().unwrap(),
            // deadline generous enough that the step *starts* (dispatch
            // happens at max_wait, well inside it), then wedges
            serve: ServeOptions {
                max_batch: 1,
                max_wait_ms: 1,
                deadline_ms: 150,
                ..Default::default()
            },
            sched: Some(SchedOptions {
                prefetch: false,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 1, 1, 41);
        let t0 = Instant::now();
        let err = host
            .generate(MoeTraceRequest::new(trace))
            .expect_err("wedged step returned Ok before its sleeps could finish");
        match err.downcast_ref::<MoeError>() {
            Some(MoeError::Aborted(_)) => {}
            other => panic!("expected structured Aborted, got {other:?} ({err})"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "watchdog took {:?}",
            t0.elapsed()
        );
        host.shutdown(); // joins: the wedged step finishes its sleeps
    }

    #[test]
    fn mid_batch_forward_error_reaches_every_still_live_trace() {
        use crate::format::{TqmMeta, TqmWriter};
        use crate::quant::{uniform, Bits, Granularity};
        use crate::tensor::Tensor;

        // a 1-layer container whose spec claims 8 experts but whose
        // records only hold experts 0..=6 — routing to expert 7 makes
        // forward_batch fail mid-trace, deterministically
        let mut cfg = moe_demo_config();
        cfg.n_layers = 1;
        let spec = cfg.moe.clone().unwrap();
        let ckpt = synth_moe_checkpoint(&cfg, 7).unwrap();
        // crafted router (shape [d_model, n_experts], row-major): a
        // one-hot e0 input routes to experts {0, 1}; a one-hot e1 input
        // routes to the missing {7, 6}
        let mut wr = vec![0.0f32; cfg.d_model * spec.n_experts];
        wr[0] = 10.0;
        wr[1] = 9.0;
        wr[spec.n_experts + 6] = 9.0;
        wr[spec.n_experts + 7] = 10.0;
        let router = Tensor::new(vec![cfg.d_model, spec.n_experts], wr).unwrap();
        let meta = TqmMeta {
            model_name: cfg.name.clone(),
            codec: CodecId::FreqSeqPacked,
            bits: Bits::B8,
            per_channel: false,
            quantizer: "naive".into(),
            source_checkpoint: "unit".into(),
        };
        let mut w = TqmWriter::new(meta).with_chunk_len(512);
        w.add_router(0, &router);
        for e in 0..spec.n_experts - 1 {
            for mat in ["w1", "w3", "w2"] {
                let t = ckpt.f32(&crate::format::expert_record_name(0, e, mat)).unwrap();
                w.add_expert_quantized(
                    0,
                    e,
                    mat,
                    &uniform::quantize(t, Bits::B8, Granularity::PerTensor).unwrap(),
                );
            }
        }
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe-missing-expert.tqm");
        w.write(&p).unwrap();
        let reader = Arc::new(TqmReader::open(&p).unwrap());

        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: 1,
            moe: spec.clone(),
            serve: ServeOptions { max_batch: 3, max_wait_ms: 2000, ..Default::default() },
            sched: Some(SchedOptions { prefetch: false, ..SchedOptions::default() }),
        })
        .unwrap();

        let mut x_a = vec![0.0f32; cfg.d_model];
        x_a[0] = 1.0; // routes to resident experts {0, 1}
        let mut x_b = vec![0.0f32; cfg.d_model];
        x_b[1] = 1.0; // routes to {7, 6} — expert 7 has no record

        // long hits the missing expert at step 2 (0-based); short retires
        // Ok after step 0; other is still live when the failure lands
        let long = vec![x_a.clone(), x_a.clone(), x_b, x_a.clone()];
        let short = vec![x_a.clone()];
        let other = vec![x_a.clone(), x_a.clone(), x_a.clone(), x_a];
        let rx_long = host.submit(MoeTraceRequest::new(long)).unwrap();
        let rx_short = host.submit(MoeTraceRequest::new(short)).unwrap();
        let rx_other = host.submit(MoeTraceRequest::new(other)).unwrap();

        // the short trace finished before the poisoned step and must
        // still succeed
        let resp_short = rx_short.recv().unwrap().unwrap();
        assert_eq!(resp_short.outputs.len(), 1);

        // both still-live traces get the error — neither hangs, neither
        // sees a half-finished Ok
        let err_long = rx_long.recv().unwrap();
        let err_other = rx_other.recv().unwrap();
        for (who, r) in [("long", err_long), ("other", err_other)] {
            let e = r.expect_err("still-live trace got Ok past a failed forward");
            assert!(
                e.to_string().contains("moe forward failed"),
                "{who} got an unexpected error: {e}"
            );
        }
        host.shutdown();
    }

    #[test]
    fn admission_gate_enforces_global_bound_and_weighted_shares() {
        let gate = AdmissionGate::new(&ServeOptions {
            admission_queue: 8,
            tenant_weights: vec![3, 1],
            ..Default::default()
        });
        // uncontended (queue under half full): tenant 0 admits freely
        for _ in 0..4 {
            gate.try_admit(0).unwrap();
        }
        // contended: weights [3, 1] give tenant 0 a share of 8*3/4 = 6
        gate.try_admit(0).unwrap();
        gate.try_admit(0).unwrap();
        let err = gate.try_admit(0).unwrap_err();
        assert!(
            matches!(err, MoeError::Overloaded { retry_after_ms } if retry_after_ms >= 1),
            "{err:?}"
        );
        // tenant 1's share (8*1/4 = 2) stayed reserved even though it
        // arrived after tenant 0 filled everything it could
        gate.try_admit(1).unwrap();
        gate.try_admit(1).unwrap();
        assert!(gate.try_admit(1).is_err(), "tenant 1 exceeded its share");
        // queue is now full (8): even a fresh tenant is bounced globally
        assert!(gate.try_admit(9).is_err(), "global bound did not hold");
        // a release restores capacity to the releasing tenant
        gate.release(0);
        gate.try_admit(0).unwrap();
    }

    #[test]
    fn admission_gate_tenant_quota_caps_inflight_regardless_of_queue_room() {
        let gate = AdmissionGate::new(&ServeOptions {
            admission_queue: 100,
            tenant_quota: 2,
            ..Default::default()
        });
        gate.try_admit(5).unwrap();
        gate.try_admit(5).unwrap();
        assert!(gate.try_admit(5).is_err(), "quota did not cap tenant 5");
        gate.try_admit(6).unwrap(); // other tenants unaffected
        gate.release(5);
        gate.try_admit(5).unwrap();
    }

    #[test]
    fn admission_gate_retry_after_tracks_backlog_times_step_pace() {
        let gate =
            AdmissionGate::new(&ServeOptions { admission_queue: 4, ..Default::default() });
        // cold EWMA still tells the client to back off a minimum amount
        match gate.overloaded(3) {
            MoeError::Overloaded { retry_after_ms } => assert_eq!(retry_after_ms, 1),
            other => panic!("{other:?}"),
        }
        gate.observe_step(Duration::from_millis(10));
        // backlog of 4 ahead at 10 ms per step: retry after ~50 ms
        match gate.overloaded(4) {
            MoeError::Overloaded { retry_after_ms } => assert_eq!(retry_after_ms, 50),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn backpressure_halves_on_churn_and_regrows_additively() {
        let (cfg, _dir, reader) = demo();
        let spec = cfg.moe.clone().unwrap();
        let metrics = Arc::new(PipelineMetrics::default());
        let cache =
            ExpertCache::from_options(reader.clone(), metrics.clone(), &ServeOptions::default());
        let sched = ExpertScheduler::new(
            reader,
            metrics.clone(),
            cache,
            cfg.n_layers,
            spec.n_experts,
            SchedOptions::default(),
        );
        let knobs = OverloadKnobs {
            shed_predictive: false,
            shrink_stall_frac: 0.0,
            shrink_evictions_per_step: 2,
            brownout_packed: false,
        };
        let mut ctl = Backpressure::new(8, knobs, metrics.clone());
        assert_eq!(ctl.effective(), 8);
        // a churn-heavy step (3 evictions > threshold 2) halves the width
        for _ in 0..3 {
            metrics.record_expert_eviction();
        }
        ctl.observe(&sched);
        assert_eq!(ctl.effective(), 4, "pressure must halve the step width");
        assert_eq!(metrics.batch_shrinks_count(), 1);
        // healthy steps regrow one slot per GROW_EVERY, not a jump back
        for _ in 0..(GROW_EVERY * 2) {
            ctl.observe(&sched);
        }
        assert_eq!(ctl.effective(), 6, "regrowth must be additive");
    }

    #[test]
    fn staggered_arrival_joins_mid_decode_and_stays_bit_exact() {
        // a record source that slows expert decodes so the first trace
        // is still mid-decode when the second arrives: continuous
        // batching folds the latecomer into the live set, and
        // per-sequence math must not depend on who else is in the batch
        struct DelaySource;
        impl crate::faults::RecordSource for DelaySource {
            fn fetch<'a>(
                &self,
                name: &str,
                payload: &'a [u8],
            ) -> Result<std::borrow::Cow<'a, [u8]>> {
                if name.contains(".experts.") {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Ok(std::borrow::Cow::Borrowed(payload))
            }
        }
        let (cfg, dir, clean) = demo();
        let spec = cfg.moe.clone().unwrap();
        let routers = load_routers(&clean, cfg.n_layers).unwrap();
        let one = clean.expert_entry(0, 0).unwrap().decoded_f32_bytes;
        let reader = Arc::new(
            TqmReader::open(dir.join("moe.tqm"))
                .unwrap()
                .with_record_source(Arc::new(DelaySource)),
        );
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: spec.clone(),
            serve: ServeOptions {
                max_batch: 4,
                max_wait_ms: 1,
                // tight cache: decodes recur every step, keeping steps
                // slow enough that the second submit lands mid-decode
                expert_budget_bytes: spec.top_k * cfg.n_layers * one + one / 2,
                ..Default::default()
            },
            sched: Some(SchedOptions {
                prefetch: false,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let t1 = clustered_trace(cfg.d_model, 2, 3, 12, 51);
        let t2 = clustered_trace(cfg.d_model, 2, 3, 8, 52);
        let rx1 = host.submit(MoeTraceRequest::new(t1.clone())).unwrap();
        // give the first trace time to get a few steps in
        std::thread::sleep(Duration::from_millis(60));
        let rx2 = host.submit(MoeTraceRequest::new(t2.clone()).with_tenant(1)).unwrap();

        let resident: Vec<Vec<Arc<ExpertWeights>>> = (0..cfg.n_layers)
            .map(|l| {
                (0..spec.n_experts)
                    .map(|e| Arc::new(ExpertWeights::load(&clean, l, e).unwrap()))
                    .collect()
            })
            .collect();
        let reference = |trace: &[Vec<f32>]| -> Vec<Vec<f32>> {
            trace
                .iter()
                .map(|x| {
                    moe_stack_forward(&routers, &spec, x, |l, e| Ok(resident[l][e].clone()))
                        .unwrap()
                })
                .collect()
        };
        let r1 = rx1.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
        let r2 = rx2.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
        assert_eq!(r1.outputs, reference(&t1), "resident trace diverged after a join");
        assert_eq!(r2.outputs, reference(&t2), "joining trace diverged");
        let m = host.metrics.clone();
        assert_eq!(m.requests_completed_count(), 2);
        assert!(m.admission_reconciles(), "{}", m.admission_identity());
        host.shutdown();
    }

    #[test]
    fn bounded_admission_rejects_overflow_then_recovers() {
        struct DelaySource;
        impl crate::faults::RecordSource for DelaySource {
            fn fetch<'a>(
                &self,
                name: &str,
                payload: &'a [u8],
            ) -> Result<std::borrow::Cow<'a, [u8]>> {
                if name.contains(".experts.") {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Ok(std::borrow::Cow::Borrowed(payload))
            }
        }
        let (cfg, dir, _clean) = demo();
        let reader = Arc::new(
            TqmReader::open(dir.join("moe.tqm"))
                .unwrap()
                .with_record_source(Arc::new(DelaySource)),
        );
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: cfg.moe.clone().unwrap(),
            serve: ServeOptions {
                max_batch: 1,
                max_wait_ms: 1,
                admission_queue: 2,
                ..Default::default()
            },
            sched: Some(SchedOptions {
                prefetch: false,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 3, 4, 61);
        let rx1 = host.submit(MoeTraceRequest::new(trace.clone())).unwrap();
        let rx2 = host.submit(MoeTraceRequest::new(trace.clone())).unwrap();
        // slow decodes guarantee neither in-flight request has finished:
        // the queue (bound 2) is full, so the third submit is answered
        // Overloaded at the call site, before any queueing
        let err = host
            .submit(MoeTraceRequest::new(trace.clone()))
            .expect_err("overflow was admitted");
        match err.downcast_ref::<MoeError>() {
            Some(MoeError::Overloaded { retry_after_ms }) => {
                assert!(*retry_after_ms >= 1, "retry-after must be actionable");
            }
            other => panic!("expected structured Overloaded, got {other:?} ({err})"),
        }
        rx1.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        rx2.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        // capacity released on completion: the retry is admitted
        let rx4 = host.submit(MoeTraceRequest::new(trace)).unwrap();
        rx4.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        let m = host.metrics.clone();
        assert_eq!(m.requests_submitted_count(), 4);
        assert_eq!(m.requests_admitted_count(), 3);
        assert_eq!(m.requests_rejected_count(), 1);
        let identity = m.admission_identity();
        assert!(m.admission_reconciles(), "{identity}");
        assert!(identity.contains("[OK]"), "{identity}");
        host.shutdown();
    }

    #[test]
    fn predictive_shed_answers_before_any_forward_work() {
        struct DelaySource;
        impl crate::faults::RecordSource for DelaySource {
            fn fetch<'a>(
                &self,
                name: &str,
                payload: &'a [u8],
            ) -> Result<std::borrow::Cow<'a, [u8]>> {
                if name.contains(".experts.") {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Ok(std::borrow::Cow::Borrowed(payload))
            }
        }
        let (cfg, dir, _clean) = demo();
        let reader = Arc::new(
            TqmReader::open(dir.join("moe.tqm"))
                .unwrap()
                .with_record_source(Arc::new(DelaySource)),
        );
        let host = MoeHost::start(MoeHostSpec {
            reader,
            n_layers: cfg.n_layers,
            moe: cfg.moe.clone().unwrap(),
            serve: ServeOptions {
                max_batch: 2,
                max_wait_ms: 1,
                deadline_ms: 30,
                shed_predictive: true,
                ..Default::default()
            },
            sched: Some(SchedOptions {
                prefetch: false,
                ..SchedOptions::from_serve(&ServeOptions::default())
            }),
        })
        .unwrap();
        let trace = clustered_trace(cfg.d_model, 2, 3, 2, 71);
        // the first request warms the step-pace EWMA the hard way: its
        // first step alone outlasts the 30 ms deadline, so it is
        // answered Timeout — charged after work was actually spent
        let err1 = host
            .generate(MoeTraceRequest::new(trace.clone()))
            .expect_err("first request cannot make its deadline");
        assert!(
            matches!(err1.downcast_ref::<MoeError>(), Some(MoeError::Timeout)),
            "expected Timeout, got {err1}"
        );
        // the second is shed on arrival at the live set: the warm EWMA
        // predicts two slow steps, overshooting the deadline before any
        // forward work is spent on it
        let err2 = host
            .generate(MoeTraceRequest::new(trace))
            .expect_err("predicted-late request was served anyway");
        match err2.downcast_ref::<MoeError>() {
            Some(MoeError::Shed { predicted_ms }) => {
                assert!(*predicted_ms >= 1, "shed must report its prediction");
            }
            other => panic!("expected structured Shed, got {other:?} ({err2})"),
        }
        let m = host.metrics.clone();
        assert_eq!(m.requests_shed_count(), 1);
        assert_eq!(m.deadline_timeouts_count(), 1);
        assert!(m.admission_reconciles(), "{}", m.admission_identity());
        host.shutdown();
    }

    #[test]
    fn overload_chaos_every_request_answered_structurally_and_books_reconcile() {
        let (cfg, _dir, reader) = demo();
        let spec = cfg.moe.clone().unwrap();
        let one = reader.expert_entry(0, 0).unwrap().decoded_f32_bytes;
        let host = Arc::new(
            MoeHost::start(MoeHostSpec {
                reader: reader.clone(),
                n_layers: cfg.n_layers,
                moe: spec.clone(),
                serve: ServeOptions {
                    max_batch: 4,
                    max_wait_ms: 1,
                    deadline_ms: 2000,
                    admission_queue: 6,
                    tenant_quota: 3,
                    tenant_weights: vec![4, 2, 1, 1],
                    shed_predictive: true,
                    shrink_stall_frac: 0.05,
                    shrink_evictions_per_step: 1,
                    brownout_packed: true,
                    // tight cache so eviction churn actually fires
                    expert_budget_bytes: spec.top_k * cfg.n_layers * one + one / 2,
                    ..Default::default()
                },
                sched: None,
            })
            .unwrap(),
        );
        // zipf-ish tenant skew: tenant 0 dominates, tail tenants trickle
        let tenants: [u32; 12] = [0, 0, 0, 0, 0, 0, 1, 1, 1, 2, 2, 3];
        let mut handles = Vec::new();
        for (i, &tenant) in tenants.iter().enumerate() {
            let host = host.clone();
            let d_model = cfg.d_model;
            handles.push(std::thread::spawn(move || {
                let mut answered = 0usize;
                for r in 0..2 {
                    let trace =
                        clustered_trace(d_model, 2, 3, 4, (i * 2 + r) as u64 + 100);
                    match host.generate(MoeTraceRequest::new(trace).with_tenant(tenant)) {
                        Ok(resp) => {
                            assert!(!resp.outputs.is_empty());
                            answered += 1;
                        }
                        Err(e) => {
                            // overload answers must be structured, never
                            // a stringly-typed mystery — and never a
                            // hang, which generate()'s watchdog would
                            // have converted to Aborted
                            assert!(
                                e.downcast_ref::<MoeError>().is_some(),
                                "unstructured overload error: {e:#}"
                            );
                            answered += 1;
                        }
                    }
                }
                answered
            }));
        }
        let answered: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(answered, 24, "every request must be answered exactly once");
        let m = host.metrics.clone();
        assert_eq!(m.requests_submitted_count(), 24);
        let identity = m.admission_identity();
        assert!(m.admission_reconciles(), "{identity}");
        assert_eq!(m.requests_in_flight(), 0, "{identity}");
        Arc::try_unwrap(host).ok().expect("all client threads joined").shutdown();
    }
}
