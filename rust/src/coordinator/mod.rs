//! Serving coordinator (S10): the L3 shell around the pipeline engine.
//!
//! Each registered model runs on a dedicated serving thread (the PJRT
//! client, executables and weight source are not `Send`, and pinning a
//! model to a thread is the right serving topology anyway). Requests enter
//! through an mpsc queue; the dynamic batcher groups compatible requests
//! up to the compiled decode geometry; generation proceeds with batched
//! decode steps, retiring finished requests as they hit their token budget
//! or the stop token.
//!
//! The router dispatches by model name, so one process can serve e.g. the
//! fp32-resident baseline and the compressed-streamed variant side by side
//! (exactly what the benches do).

pub mod batcher;
pub mod metrics;
pub mod moe_host;

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::{Residency, ServeOptions};
use crate::gen::{Sampler, SamplerKind};
use crate::model::WeightSource;
use crate::pipeline::{Engine, PipelineMetrics, Session};
use crate::runtime::Runtime;

pub use batcher::{collect_batch, collect_batch_by, BatchPolicy};
pub use metrics::{ServeMetrics, ServeSnapshot};
pub use moe_host::{MoeHost, MoeHostSpec, MoeTraceRequest, MoeTraceResponse};
// the structured error vocabulary MoeHost answers with (Timeout /
// Quarantined / Aborted) — re-exported so serving clients need not know
// it lives in `faults`
pub use crate::faults::MoeError;

/// What a client submits.
pub struct GenRequest {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub sampler: SamplerKind,
    pub seed: u64,
    pub stop_token: Option<u32>,
}

/// What a client gets back.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub tokens: Vec<u32>,
    pub queue_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
}

struct Envelope {
    req: GenRequest,
    enqueued: Instant,
    resp: mpsc::Sender<Result<GenResponse>>,
}

/// How to build a model's engine (resolved on its serving thread).
pub struct ModelSpec {
    pub name: String,
    pub artifacts_root: std::path::PathBuf,
    pub manifest_model: String,
    pub tqm_path: std::path::PathBuf,
    pub serve: ServeOptions,
}

pub struct ModelHandle {
    tx: mpsc::Sender<Envelope>,
    pub metrics: Arc<ServeMetrics>,
    /// Engine-level pipeline metrics (layer decode + expert cache),
    /// shared out of the serving thread at registration time.
    pub pipeline: Arc<PipelineMetrics>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// The router: model name -> serving thread.
pub struct Coordinator {
    models: HashMap<String, ModelHandle>,
}

impl Coordinator {
    pub fn new() -> Self {
        Self { models: HashMap::new() }
    }

    /// Register and start a model's serving thread.
    pub fn register(&mut self, spec: ModelSpec) -> Result<()> {
        let (tx, rx) = mpsc::channel::<Envelope>();
        let metrics = Arc::new(ServeMetrics::default());
        let thread_metrics = metrics.clone();
        let name = spec.name.clone();
        // engine construction errors must surface at register time; on
        // success the thread hands back the engine's pipeline metrics so
        // callers can watch decode/expert-cache health from outside
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Arc<PipelineMetrics>>>();
        let join = std::thread::Builder::new()
            .name(format!("serve-{name}"))
            .spawn(move || serve_thread(spec, rx, thread_metrics, ready_tx))?;
        let pipeline = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("serving thread died during startup"))??;
        self.models
            .insert(name, ModelHandle { tx, metrics, pipeline, join: Some(join) });
        Ok(())
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    pub fn metrics(&self, model: &str) -> Option<Arc<ServeMetrics>> {
        self.models.get(model).map(|h| h.metrics.clone())
    }

    /// Engine-level pipeline metrics of a model: layer-decode throughput
    /// and residency, plus expert-cache hit-rate / resident bytes /
    /// per-miss decode latency for MoE models.
    pub fn pipeline_metrics(&self, model: &str) -> Option<Arc<PipelineMetrics>> {
        self.models.get(model).map(|h| h.pipeline.clone())
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(
        &self,
        model: &str,
        req: GenRequest,
    ) -> Result<mpsc::Receiver<Result<GenResponse>>> {
        let h = self
            .models
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("no model {model:?} registered"))?;
        let (resp_tx, resp_rx) = mpsc::channel();
        h.tx
            .send(Envelope { req, enqueued: Instant::now(), resp: resp_tx })
            .map_err(|_| anyhow::anyhow!("serving thread for {model:?} is gone"))?;
        Ok(resp_rx)
    }

    /// Submit and block for the response.
    pub fn generate(&self, model: &str, req: GenRequest) -> Result<GenResponse> {
        let rx = self.submit(model, req)?;
        rx.recv().map_err(|_| anyhow::anyhow!("response channel closed"))?
    }

    /// Stop all serving threads (drains queues).
    pub fn shutdown(mut self) {
        for (_, mut h) in self.models.drain() {
            drop(h.tx);
            if let Some(j) = h.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

/// One in-flight request during batched decoding.
struct Active {
    env: Envelope,
    session: Session,
    sampler: Sampler,
    generated: Vec<u32>,
    next: u32,
    prefill_s: f64,
    decode_start: Instant,
    done: bool,
}

fn serve_thread(
    spec: ModelSpec,
    rx: mpsc::Receiver<Envelope>,
    metrics: Arc<ServeMetrics>,
    ready: mpsc::Sender<Result<Arc<PipelineMetrics>>>,
) {
    let engine = match build_engine(&spec) {
        Ok(e) => {
            let _ = ready.send(Ok(e.metrics.clone()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let policy = BatchPolicy {
        max_batch: spec.serve.max_batch,
        max_wait: std::time::Duration::from_millis(spec.serve.max_wait_ms),
    };
    loop {
        let batch = collect_batch(&rx, policy);
        if batch.is_empty() {
            return; // disconnected
        }
        metrics.record_batch(batch.len());
        serve_batch(&engine, batch, &metrics, spec.serve.max_new_tokens);
    }
}

fn build_engine(spec: &ModelSpec) -> Result<Engine> {
    let rt = Arc::new(Runtime::new(&spec.artifacts_root, &spec.manifest_model)?);
    let source = match spec.serve.residency {
        Residency::AlwaysResident => {
            WeightSource::open_resident(&spec.tqm_path, &rt.manifest.config)?
        }
        _ => WeightSource::open_compressed(&spec.tqm_path)?,
    };
    Engine::new(rt, source, &spec.serve)
}

fn serve_batch(
    engine: &Engine,
    batch: Vec<Envelope>,
    metrics: &ServeMetrics,
    max_new_cap: usize,
) {
    // prefill each request individually (prefill buckets are B=1)
    let mut active: Vec<Active> = Vec::with_capacity(batch.len());
    for env in batch {
        let t0 = Instant::now();
        match engine.prefill_session(&env.req.prompt) {
            Ok((session, first_logits)) => {
                let mut sampler = match env.req.sampler {
                    SamplerKind::Greedy => Sampler::greedy(),
                    SamplerKind::TopK { k, temperature } => {
                        Sampler::top_k(k, temperature, env.req.seed)
                    }
                };
                let next = sampler.sample(&first_logits);
                active.push(Active {
                    env,
                    session,
                    sampler,
                    generated: Vec::new(),
                    next,
                    prefill_s: t0.elapsed().as_secs_f64(),
                    decode_start: Instant::now(),
                    done: false,
                });
            }
            Err(e) => {
                let _ = env.resp.send(Err(e));
            }
        }
    }

    // batched decode until everyone finishes
    loop {
        let live: Vec<usize> = (0..active.len()).filter(|&i| !active[i].done).collect();
        if live.is_empty() {
            break;
        }
        // emit the sampled token first, then check budgets
        for &i in &live {
            let a = &mut active[i];
            a.generated.push(a.next);
            let hit_stop = a.env.req.stop_token == Some(a.next);
            let budget = a.env.req.max_new.min(max_new_cap);
            if hit_stop
                || a.generated.len() >= budget
                || a.session.pos + 1 >= engine.cfg().max_seq
            {
                a.done = true;
                retire(a, metrics);
            }
        }
        let live: Vec<usize> = (0..active.len()).filter(|&i| !active[i].done).collect();
        if live.is_empty() {
            break;
        }
        // temporarily move sessions out of their slots so decode_batch can
        // take disjoint &mut without aliasing
        let tokens: Vec<u32> = live.iter().map(|&i| active[i].next).collect();
        let mut sessions_owned: Vec<Session> = live
            .iter()
            .map(|&i| std::mem::replace(&mut active[i].session, Session::empty()))
            .collect();
        let mut session_refs: Vec<&mut Session> = sessions_owned.iter_mut().collect();
        let result = engine.decode_batch(&mut session_refs, &tokens);
        for (j, &i) in live.iter().enumerate() {
            active[i].session = std::mem::replace(&mut sessions_owned[j], Session::empty());
        }
        match result {
            Ok(logit_rows) => {
                for (&i, row) in live.iter().zip(logit_rows) {
                    let a = &mut active[i];
                    a.next = a.sampler.sample(&row);
                }
            }
            Err(e) => {
                let msg = format!("decode failed: {e}");
                for &i in &live {
                    let a = &mut active[i];
                    a.done = true;
                    let _ = a.env.resp.send(Err(anyhow::anyhow!("{msg}")));
                }
            }
        }
    }
}

fn retire(a: &mut Active, metrics: &ServeMetrics) {
    let queue_s = a.env.enqueued.elapsed().as_secs_f64()
        - a.prefill_s
        - a.decode_start.elapsed().as_secs_f64();
    let queue_s = queue_s.max(0.0);
    let decode_s = a.decode_start.elapsed().as_secs_f64();
    metrics.record_request(queue_s, a.prefill_s, decode_s, a.generated.len());
    let _ = a.env.resp.send(Ok(GenResponse {
        tokens: a.generated.clone(),
        queue_s,
        prefill_s: a.prefill_s,
        decode_s,
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::{default_artifacts_root, QuantizeOptions};
    use crate::model::{quantize_checkpoint, Checkpoint};
    use crate::util::TempDir;

    fn make_spec(dir: &TempDir, residency: Residency) -> Option<ModelSpec> {
        let root = default_artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let manifest = crate::config::Manifest::load(&root, "tiny").unwrap();
        let ckpt = Checkpoint::load(root.join("tiny/weights/tiny.tqw")).unwrap();
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_checkpoint(
            &manifest.config,
            &ckpt,
            &opts,
            CodecId::FreqSeqPacked,
            None,
            "tiny.tqw",
        )
        .unwrap();
        let tqm = dir.join("tiny.tqm");
        w.write(&tqm).unwrap();
        Some(ModelSpec {
            name: "tiny".into(),
            artifacts_root: root,
            manifest_model: "tiny".into(),
            tqm_path: tqm,
            serve: ServeOptions {
                residency,
                prefetch_depth: 0,
                n_threads: 1,
                max_batch: 2,
                max_wait_ms: 5,
                max_new_tokens: 8,
                ..Default::default()
            },
        })
    }

    #[test]
    fn serve_roundtrip_single() {
        let dir = TempDir::new().unwrap();
        let Some(spec) = make_spec(&dir, Residency::StreamPerLayer) else { return };
        let mut coord = Coordinator::new();
        coord.register(spec).unwrap();
        let resp = coord
            .generate(
                "tiny",
                GenRequest {
                    prompt: vec![1, 2, 20, 3],
                    max_new: 4,
                    sampler: SamplerKind::Greedy,
                    seed: 0,
                    stop_token: None,
                },
            )
            .unwrap();
        assert_eq!(resp.tokens.len(), 4);
        assert!(resp.prefill_s > 0.0);
        let snap = coord.metrics("tiny").unwrap().snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.tokens_out, 4);
        // pipeline metrics are reachable from outside the serving thread:
        // a streamed model decompresses layers while generating
        let pm = coord.pipeline_metrics("tiny").unwrap();
        assert!(pm.decompress_count() > 0);
        assert!(coord.pipeline_metrics("nope").is_none());
        coord.shutdown();
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let dir = TempDir::new().unwrap();
        let Some(spec) = make_spec(&dir, Residency::StreamPerLayer) else { return };
        let mut coord = Coordinator::new();
        coord.register(spec).unwrap();
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                coord
                    .submit(
                        "tiny",
                        GenRequest {
                            prompt: vec![1, 2 + i as u32, 3],
                            max_new: 3,
                            sampler: SamplerKind::Greedy,
                            seed: i as u64,
                            stop_token: None,
                        },
                    )
                    .unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.tokens.len(), 3);
        }
        let snap = coord.metrics("tiny").unwrap().snapshot();
        assert_eq!(snap.requests, 4);
        coord.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let coord = Coordinator::new();
        assert!(coord
            .submit(
                "nope",
                GenRequest {
                    prompt: vec![1],
                    max_new: 1,
                    sampler: SamplerKind::Greedy,
                    seed: 0,
                    stop_token: None,
                }
            )
            .is_err());
    }

    #[test]
    fn batched_output_matches_unbatched() {
        // determinism invariant: batching must not change greedy output
        let dir = TempDir::new().unwrap();
        let Some(spec) = make_spec(&dir, Residency::StreamPerLayer) else { return };
        let mut coord = Coordinator::new();
        coord.register(spec).unwrap();
        let req = || GenRequest {
            prompt: vec![2, 17, 30, 3],
            max_new: 4,
            sampler: SamplerKind::Greedy,
            seed: 0,
            stop_token: None,
        };
        // sequential (batch of 1)
        let solo = coord.generate("tiny", req()).unwrap();
        // concurrent pair (batched decode)
        let rx1 = coord.submit("tiny", req()).unwrap();
        let rx2 = coord.submit("tiny", req()).unwrap();
        let b1 = rx1.recv().unwrap().unwrap();
        let b2 = rx2.recv().unwrap().unwrap();
        assert_eq!(solo.tokens, b1.tokens);
        assert_eq!(solo.tokens, b2.tokens);
        coord.shutdown();
    }
}
