//! Dynamic batching policy (S10): collect requests from the queue until
//! either the batch is full or the oldest request has waited `max_wait`.
//! Deadline-or-full is the same policy vLLM's continuous batcher degrades
//! to for fixed-geometry executables, which is what our compiled decode
//! buckets are.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 4, max_wait: Duration::from_millis(2) }
    }
}

/// Block for the first item, then drain until full or deadline. Returns an
/// empty vec when the channel has disconnected and is drained.
pub fn collect_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Vec<T> {
    collect_batch_by(rx, policy, |_| None)
}

/// [`collect_batch`] with per-item request deadlines: `deadline_of` maps
/// an item to its (optional) hard deadline, and the drain window shrinks
/// to the earliest one — a request that has only `t < max_wait` left
/// must not spend all of `t` queueing for batch-mates. Items are still
/// returned even when already past their deadline; expiry is answered
/// upstream (the host sends a structured Timeout), the batcher only
/// promises not to sit on them.
pub fn collect_batch_by<T>(
    rx: &Receiver<T>,
    policy: BatchPolicy,
    deadline_of: impl Fn(&T) -> Option<Instant>,
) -> Vec<T> {
    let mut batch = Vec::with_capacity(policy.max_batch);
    match rx.recv() {
        Ok(item) => batch.push(item),
        Err(_) => return batch,
    }
    let mut deadline = Instant::now() + policy.max_wait;
    if let Some(d) = deadline_of(&batch[0]) {
        deadline = deadline.min(d);
    }
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => {
                if let Some(d) = deadline_of(&item) {
                    deadline = deadline.min(d);
                }
                batch.push(item);
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) };
        let b = collect_batch(&rx, policy);
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b2 = collect_batch(&rx, policy);
        assert_eq!(b2, vec![4, 5, 6, 7]);
    }

    #[test]
    fn partial_batch_on_deadline() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        let b = collect_batch(&rx, policy);
        assert_eq!(b, vec![1]);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn empty_on_disconnect() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        let b = collect_batch(&rx, BatchPolicy::default());
        assert!(b.is_empty());
    }

    #[test]
    fn disconnect_mid_drain_returns_partial_batch() {
        // the sender dies after delivering part of a batch: the batcher
        // must return what it has promptly, not error or hang out the
        // full deadline
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(500) };
        let sender = std::thread::spawn(move || {
            tx.send(10).unwrap();
            tx.send(11).unwrap();
            std::thread::sleep(Duration::from_millis(10));
            tx.send(12).unwrap();
            // tx dropped here: disconnect mid-drain
        });
        let t0 = Instant::now();
        let b = collect_batch(&rx, policy);
        sender.join().unwrap();
        assert_eq!(b, vec![10, 11, 12], "partial batch lost on disconnect");
        assert!(
            t0.elapsed() < Duration::from_millis(400),
            "disconnect did not cut the wait short ({:?})",
            t0.elapsed()
        );
        // subsequent calls observe the drained, disconnected channel
        assert!(collect_batch(&rx, policy).is_empty());
    }

    #[test]
    fn max_wait_deadline_honored_within_tolerance() {
        // one item arrives and nothing else: the batcher must hold until
        // (about) the deadline, then dispatch the partial batch
        let (tx, rx) = mpsc::channel();
        tx.send(7u32).unwrap();
        let wait = Duration::from_millis(40);
        let policy = BatchPolicy { max_batch: 4, max_wait: wait };
        let t0 = Instant::now();
        let b = collect_batch(&rx, policy);
        let elapsed = t0.elapsed();
        assert_eq!(b, vec![7]);
        // lower bound minus scheduler slop; generous upper bound for CI
        assert!(
            elapsed >= wait - Duration::from_millis(5),
            "dispatched {elapsed:?} before the {wait:?} deadline"
        );
        assert!(
            elapsed < wait + Duration::from_millis(250),
            "deadline overshot: {elapsed:?}"
        );
        drop(tx);
    }

    #[test]
    fn max_batch_never_exceeded_under_flooding_producer() {
        let (tx, rx) = mpsc::channel();
        let total = 10_000usize;
        let producer = std::thread::spawn(move || {
            for i in 0..total {
                tx.send(i).unwrap();
            }
            // tx drops: batcher eventually sees the drained channel
        });
        let policy = BatchPolicy { max_batch: 6, max_wait: Duration::from_millis(5) };
        let mut seen = 0usize;
        let mut next_expected = 0usize;
        loop {
            let b = collect_batch(&rx, policy);
            if b.is_empty() {
                break;
            }
            assert!(b.len() <= policy.max_batch, "batch of {} > max_batch", b.len());
            // FIFO order is preserved across batches
            for v in b {
                assert_eq!(v, next_expected);
                next_expected += 1;
                seen += 1;
            }
        }
        producer.join().unwrap();
        assert_eq!(seen, total, "items lost under flood");
    }

    #[test]
    fn item_deadline_shrinks_the_drain_window() {
        // the queued item carries a deadline much closer than max_wait:
        // the batcher must dispatch at (about) the item deadline instead
        // of holding the full window
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(500) };
        let soon = Instant::now() + Duration::from_millis(20);
        tx.send((1u32, Some(soon))).unwrap();
        let t0 = Instant::now();
        let b = collect_batch_by(&rx, policy, |&(_, d)| d);
        assert_eq!(b.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(400),
            "item deadline ignored ({:?})",
            t0.elapsed()
        );
        drop(tx);
    }

    #[test]
    fn expired_items_are_returned_not_dropped() {
        // already-past deadlines cut the drain short but the item itself
        // still comes back — expiry is the host's call, not the batcher's
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(200) };
        let past = Instant::now() - Duration::from_millis(5);
        tx.send((7u32, Some(past))).unwrap();
        let t0 = Instant::now();
        let b = collect_batch_by(&rx, policy, |&(_, d)| d);
        assert_eq!(b.len(), 1, "expired item swallowed by the batcher");
        assert!(t0.elapsed() < Duration::from_millis(100));
        drop(tx);
    }

    #[test]
    fn all_queued_items_already_expired_are_drained_without_waiting() {
        // every queued request is past its deadline: the first recv's
        // deadline collapses the window to "already over", but each call
        // still returns one item — nothing is swallowed, nothing waited
        // on, and repeated calls hand every request back exactly once
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(500) };
        let past = Instant::now() - Duration::from_millis(50);
        for i in 0..5u32 {
            tx.send((i, Some(past))).unwrap();
        }
        drop(tx);
        let t0 = Instant::now();
        let mut seen = Vec::new();
        loop {
            let b = collect_batch_by(&rx, policy, |&(_, d)| d);
            if b.is_empty() {
                break;
            }
            seen.extend(b.into_iter().map(|(i, _)| i));
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4], "expired items lost or duplicated");
        assert!(
            t0.elapsed() < Duration::from_millis(400),
            "expired queue still waited out a window ({:?})",
            t0.elapsed()
        );
    }

    #[test]
    fn zero_width_drain_window_returns_the_first_item_alone() {
        // max_wait of zero: the drain window is empty, so the batcher
        // must return immediately after the blocking recv — one item per
        // call, FIFO, never a hang
        let (tx, rx) = mpsc::channel();
        for i in 0..3u32 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::ZERO };
        let t0 = Instant::now();
        assert_eq!(collect_batch(&rx, policy), vec![0]);
        assert_eq!(collect_batch(&rx, policy), vec![1]);
        assert_eq!(collect_batch(&rx, policy), vec![2]);
        assert!(
            t0.elapsed() < Duration::from_millis(200),
            "zero-width window still waited ({:?})",
            t0.elapsed()
        );
        drop(tx);
    }

    #[test]
    fn deadline_expiring_mid_drain_cuts_the_window_short() {
        // the first item is patient; a later arrival's deadline is about
        // to pass mid-drain — the window must shrink to it and dispatch
        // promptly, with both items present exactly once
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(500) };
        tx.send((1u32, None)).unwrap();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            let urgent = Instant::now() + Duration::from_millis(15);
            tx.send((2u32, Some(urgent))).unwrap();
            // keep tx alive past the expected dispatch so disconnect
            // cannot be what cuts the wait short
            std::thread::sleep(Duration::from_millis(200));
        });
        let t0 = Instant::now();
        let b = collect_batch_by(&rx, policy, |&(_, d)| d);
        let elapsed = t0.elapsed();
        sender.join().unwrap();
        let ids: Vec<u32> = b.into_iter().map(|(i, _)| i).collect();
        assert_eq!(ids, vec![1, 2], "mid-drain arrival lost");
        assert!(
            elapsed < Duration::from_millis(400),
            "mid-drain deadline ignored ({elapsed:?})"
        );
    }

    #[test]
    fn late_arrivals_within_window_join() {
        let (tx, rx) = mpsc::channel();
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(60) };
        let sender = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(10));
            tx.send(2).unwrap();
        });
        let b = collect_batch(&rx, policy);
        sender.join().unwrap();
        assert_eq!(b, vec![1, 2]);
    }
}
