//! Serving metrics (S10): request counters and latency aggregation for the
//! coordinator — what the paper's Tables 2-4 latency columns are made of,
//! plus the queueing/batching split a serving system actually needs.

use std::sync::Mutex;

use crate::util::{lock_recover, stats as ord_stats};

#[derive(Default)]
pub struct ServeMetrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    requests: u64,
    tokens_out: u64,
    batches: u64,
    batch_sizes: Vec<usize>,
    queue_s: Vec<f64>,
    prefill_s: Vec<f64>,
    decode_s: Vec<f64>,
    total_s: Vec<f64>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

fn stats(xs: &[f64]) -> LatencyStats {
    // util::stats sorts with total_cmp: a NaN sample (it would take a bug
    // upstream, but latency math divides) must not panic the metrics
    // thread mid-serve
    let mut v = xs.to_vec();
    let s = ord_stats::summarize(&mut v);
    LatencyStats { mean: s.mean, p50: s.p50, p95: s.p95, p99: s.p99 }
}

#[derive(Clone, Debug)]
pub struct ServeSnapshot {
    pub requests: u64,
    pub tokens_out: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub queue: LatencyStats,
    pub prefill: LatencyStats,
    pub decode: LatencyStats,
    pub total: LatencyStats,
    pub tokens_per_s: f64,
}

impl ServeMetrics {
    pub fn record_batch(&self, size: usize) {
        let mut i = lock_recover(&self.inner);
        i.batches += 1;
        i.batch_sizes.push(size);
    }

    pub fn record_request(
        &self,
        queue_s: f64,
        prefill_s: f64,
        decode_s: f64,
        tokens_out: usize,
    ) {
        let mut i = lock_recover(&self.inner);
        i.requests += 1;
        i.tokens_out += tokens_out as u64;
        i.queue_s.push(queue_s);
        i.prefill_s.push(prefill_s);
        i.decode_s.push(decode_s);
        i.total_s.push(queue_s + prefill_s + decode_s);
    }

    pub fn snapshot(&self) -> ServeSnapshot {
        let i = lock_recover(&self.inner);
        let decode_total: f64 = i.decode_s.iter().sum();
        ServeSnapshot {
            requests: i.requests,
            tokens_out: i.tokens_out,
            batches: i.batches,
            mean_batch_size: if i.batch_sizes.is_empty() {
                0.0
            } else {
                i.batch_sizes.iter().sum::<usize>() as f64 / i.batch_sizes.len() as f64
            },
            queue: stats(&i.queue_s),
            prefill: stats(&i.prefill_s),
            decode: stats(&i.decode_s),
            total: stats(&i.total_s),
            tokens_per_s: if decode_total > 0.0 {
                i.tokens_out as f64 / decode_total
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let m = ServeMetrics::default();
        m.record_batch(2);
        m.record_batch(4);
        m.record_request(0.001, 0.01, 0.1, 10);
        m.record_request(0.002, 0.02, 0.3, 30);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.tokens_out, 40);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size - 3.0).abs() < 1e-9);
        assert!(s.total.mean > 0.1);
        assert!(s.tokens_per_s > 0.0);
        assert!(s.queue.p95 >= s.queue.p50);
    }

    #[test]
    fn empty_snapshot_safe() {
        let m = ServeMetrics::default();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.tokens_per_s, 0.0);
    }
}
