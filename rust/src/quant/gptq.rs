//! GPTQ (S3): the data-dependent quantizer the paper layers on top of the
//! naive scheme (§3, "we applied GPTQ ... using the C4 dataset").
//!
//! Standard algorithm (Frantar et al., 2023), per weight matrix W with
//! layer-input Hessian `H = 2 Σ x xᵀ + λI`:
//!
//! 1. factor `H⁻¹ = Uᵀ U` (upper Cholesky of the inverse);
//! 2. walk the input dimension column-by-column; quantize each weight to
//!    the per-output-channel grid, and propagate the rounding error into
//!    the not-yet-quantized columns scaled by `U`'s row — so later columns
//!    compensate for earlier rounding;
//! 3. the scale/zero grid itself is the same asymmetric min/max grid as
//!    the naive quantizer (GPTQ redistributes error, it does not change
//!    the code domain), keeping the compressed-format contract identical.
//!
//! Our weight layout is `[in, out]` (columns are output channels), so the
//! walk is over *rows* and error propagates down the remaining rows.

use anyhow::{Context, Result};

use super::{uniform, Bits, Granularity, QuantizedTensor};
use crate::tensor::math::cholesky_inverse_upper;
use crate::tensor::{Tensor, U8Tensor};

/// Calibration statistics for one linear layer: Gram matrix of its inputs.
#[derive(Clone, Debug)]
pub struct Hessian {
    /// Row-major `[k, k]` accumulated `Σ x xᵀ` (f64 for stability).
    pub gram: Vec<f64>,
    pub k: usize,
    pub n_samples: usize,
}

impl Hessian {
    pub fn new(k: usize) -> Self {
        Self { gram: vec![0.0; k * k], k, n_samples: 0 }
    }

    /// Accumulate a batch of layer inputs, row-major `[n, k]`.
    pub fn accumulate(&mut self, x: &[f32]) {
        crate::tensor::math::gram_accumulate(&mut self.gram, x, self.k);
        self.n_samples += x.len() / self.k;
    }

    /// Damped Hessian `2/n Σ x xᵀ + λ mean(diag) I`.
    fn damped(&self, percdamp: f64) -> Vec<f64> {
        let k = self.k;
        let n = self.n_samples.max(1) as f64;
        let mut h: Vec<f64> = self.gram.iter().map(|g| 2.0 * g / n).collect();
        let mean_diag = (0..k).map(|i| h[i * k + i]).sum::<f64>() / k as f64;
        let damp = percdamp * mean_diag.max(1e-8);
        for i in 0..k {
            h[i * k + i] += damp;
        }
        h
    }
}

/// GPTQ-quantize `w` (`[in, out]`) given calibration `hessian` over the
/// input dimension. Falls back to increasing damping if the Hessian is
/// ill-conditioned (dead input channels are common with synthetic data).
pub fn quantize(
    w: &Tensor,
    hessian: &Hessian,
    bits: Bits,
    percdamp: f64,
) -> Result<QuantizedTensor> {
    let (k, n) = w.dims2()?;
    assert_eq!(hessian.k, k, "hessian dim mismatch");

    // grid: per-output-channel asymmetric min/max (same as naive path)
    let grid = uniform::quantize(w, bits, Granularity::PerChannel { axis: 1 })?;
    let (scale, zero) = (grid.scale.clone(), grid.zero.clone());
    let maxq = bits.maxq() as f32;

    // U: upper Cholesky factor of H^{-1}; retry with more damping if needed
    let mut u = None;
    let mut damp = percdamp;
    for _ in 0..6 {
        match cholesky_inverse_upper(hessian.damped(damp), k) {
            Ok(got) => {
                u = Some(got);
                break;
            }
            Err(_) => damp *= 10.0,
        }
    }
    let u = u.context("hessian not invertible even with damping")?;

    // working copy of W we mutate as error propagates
    let mut wf: Vec<f32> = w.data.clone();
    let mut codes = vec![0u8; k * n];
    for i in 0..k {
        let d = u[i * k + i] as f32; // U[i,i] = sqrt(Hinv[i,i] | cond)
        let row = &wf[i * n..(i + 1) * n];
        let mut err = vec![0.0f32; n];
        for c in 0..n {
            let q = ((row[c] / scale[c]).round() + zero[c]).clamp(0.0, maxq);
            codes[i * n + c] = q as u8;
            let deq = (q - zero[c]) * scale[c];
            err[c] = (row[c] - deq) / d;
        }
        // propagate: W[j,:] -= U[i,j] * err  for j > i
        for j in (i + 1)..k {
            let uij = u[i * k + j] as f32;
            if uij == 0.0 {
                continue;
            }
            let wrow = &mut wf[j * n..(j + 1) * n];
            for c in 0..n {
                wrow[c] -= uij * err[c];
            }
        }
    }

    Ok(QuantizedTensor {
        codes: U8Tensor { shape: w.shape.clone(), data: codes },
        scale,
        zero,
        bits,
        granularity: Granularity::PerChannel { axis: 1 },
    })
}

/// Task loss proxy: `tr((W - Ŵ)ᵀ H (W - Ŵ)) / n`, the objective GPTQ
/// minimizes. Used by tests and the §3 ablation bench.
pub fn hessian_weighted_error(w: &Tensor, q: &QuantizedTensor, h: &Hessian) -> f64 {
    let (k, n) = w.dims2().unwrap();
    let deq = q.dequantize();
    let nsamp = h.n_samples.max(1) as f64;
    let mut total = 0.0f64;
    // E = W - Ŵ; total = Σ_c e_cᵀ H e_c
    let mut e = vec![0.0f64; k];
    for c in 0..n {
        for i in 0..k {
            e[i] = (w.data[i * n + c] - deq.data[i * n + c]) as f64;
        }
        for i in 0..k {
            if e[i] == 0.0 {
                continue;
            }
            let hrow = &h.gram[i * k..(i + 1) * k];
            let mut s = 0.0;
            for j in 0..k {
                s += hrow[j] * e[j];
            }
            total += e[i] * s * 2.0 / nsamp;
        }
    }
    total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    
    fn setup(k: usize, n: usize, samples: usize, seed: u64) -> (Tensor, Hessian) {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let w = Tensor::new(
            vec![k, n],
            (0..k * n).map(|_| rng.uniform(-1.0 as f64, 1.0 as f64) as f32).collect(),
        )
        .unwrap();
        let mut h = Hessian::new(k);
        // correlated inputs (x = base + noise) — the regime where GPTQ wins
        let base: Vec<f32> = (0..k).map(|_| rng.uniform(-1.0 as f64, 1.0 as f64) as f32).collect();
        let mut x = vec![0.0f32; samples * k];
        for r in 0..samples {
            let a: f32 = rng.uniform(-1.0 as f64, 1.0 as f64) as f32;
            for c in 0..k {
                x[r * k + c] = a * base[c] + 0.3 * rng.uniform(-1.0f32 as f64, 1.0 as f64) as f32;
            }
        }
        h.accumulate(&x);
        (w, h)
    }

    #[test]
    fn gptq_beats_naive_on_task_loss() {
        let (w, h) = setup(32, 16, 256, 0);
        for bits in [Bits::B2, Bits::B4] {
            let naive = uniform::quantize(&w, bits, Granularity::PerChannel { axis: 1 }).unwrap();
            let gq = quantize(&w, &h, bits, 0.01).unwrap();
            let e_naive = hessian_weighted_error(&w, &naive, &h);
            let e_gptq = hessian_weighted_error(&w, &gq, &h);
            assert!(
                e_gptq < e_naive,
                "{bits:?}: gptq {e_gptq:.4} !< naive {e_naive:.4}"
            );
        }
    }

    #[test]
    fn gptq_codes_in_range() {
        let (w, h) = setup(16, 8, 64, 1);
        for bits in [Bits::Ternary, Bits::B4, Bits::B8] {
            let q = quantize(&w, &h, bits, 0.01).unwrap();
            assert!(q.codes.data.iter().all(|&c| (c as u32) <= bits.maxq()));
        }
    }

    #[test]
    fn gptq_8bit_dequant_close_to_original() {
        let (w, h) = setup(24, 12, 128, 2);
        let q = quantize(&w, &h, Bits::B8, 0.01).unwrap();
        let mse = w.mse(&q.dequantize());
        // 8-bit grid on [-1,1] range: per-element error ~ (2/255)/sqrt(12);
        // error propagation can spread it but stays the same order
        assert!(mse < 1e-3, "mse {mse}");
    }

    #[test]
    fn degenerate_hessian_handled_by_damping() {
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let k = 8;
        let w = Tensor::new(
            vec![k, 4],
            (0..k * 4).map(|_| rng.uniform(-1.0f32 as f64, 1.0 as f64) as f32).collect(),
        )
        .unwrap();
        // rank-1 Hessian (all samples identical)
        let mut h = Hessian::new(k);
        let x: Vec<f32> = (0..k).map(|i| i as f32).collect();
        for _ in 0..16 {
            h.accumulate(&x);
        }
        let q = quantize(&w, &h, Bits::B4, 0.01).unwrap();
        assert_eq!(q.codes.data.len(), k * 4);
    }

    #[test]
    fn hessian_accumulate_counts_samples() {
        let mut h = Hessian::new(4);
        h.accumulate(&[1.0; 8]); // 2 rows
        h.accumulate(&[2.0; 4]); // 1 row
        assert_eq!(h.n_samples, 3);
        // gram[0,0] = 1+1+4 = 6
        assert!((h.gram[0] - 6.0).abs() < 1e-9);
    }
}
