//! Quantization-error metrics feeding the §3 bit-width ablation bench and
//! the EXPERIMENTS.md tables: MSE, SQNR, sparsity of the dequantized grid,
//! and code-histogram entropy (which upper-bounds what any entropy coder
//! can do to the code stream — the honesty check for Table 1).

use super::QuantizedTensor;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct QuantReport {
    pub mse: f64,
    /// Signal-to-quantization-noise ratio in dB.
    pub sqnr_db: f64,
    /// Fraction of dequantized values that are exactly zero.
    pub sparsity: f64,
    /// Shannon entropy of the code histogram, bits per code.
    pub code_entropy_bits: f64,
    /// Fraction of the code alphabet actually used.
    pub alphabet_coverage: f64,
}

pub fn report(original: &Tensor, q: &QuantizedTensor) -> QuantReport {
    let deq = q.dequantize();
    let mse = original.mse(&deq);
    let signal =
        original.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            / original.data.len().max(1) as f64;
    let sqnr_db = if mse > 0.0 { 10.0 * (signal / mse).log10() } else { f64::INFINITY };
    let zeros = deq.data.iter().filter(|v| v.abs() < 1e-12).count();
    let sparsity = zeros as f64 / deq.data.len().max(1) as f64;

    let mut hist = [0usize; 256];
    for &c in &q.codes.data {
        hist[c as usize] += 1;
    }
    let n = q.codes.data.len().max(1) as f64;
    let mut entropy = 0.0;
    let mut used = 0usize;
    for &h in &hist {
        if h > 0 {
            used += 1;
            let p = h as f64 / n;
            entropy -= p * p.log2();
        }
    }
    let alphabet = (q.bits.maxq() + 1) as f64;
    QuantReport {
        mse,
        sqnr_db,
        sparsity,
        code_entropy_bits: entropy,
        alphabet_coverage: used as f64 / alphabet,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{uniform, Bits, Granularity};
    
    fn normal_tensor(n: usize, seed: u64) -> Tensor {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let data: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        Tensor::new(vec![n / 64, 64], data).unwrap()
    }

    #[test]
    fn sqnr_grows_with_bits() {
        let t = normal_tensor(64 * 64, 0);
        let mut prev = f64::NEG_INFINITY;
        for bits in [Bits::B2, Bits::B4, Bits::B6, Bits::B8] {
            let q = uniform::quantize(&t, bits, Granularity::PerTensor).unwrap();
            let r = report(&t, &q);
            assert!(r.sqnr_db > prev);
            prev = r.sqnr_db;
        }
        // rule of thumb ~6 dB/bit: 8-bit normal data lands way above 30 dB
        assert!(prev > 30.0);
    }

    #[test]
    fn entropy_bounded_by_bits() {
        let t = normal_tensor(64 * 64, 1);
        for bits in [Bits::B2, Bits::B8] {
            let q = uniform::quantize(&t, bits, Granularity::PerTensor).unwrap();
            let r = report(&t, &q);
            assert!(r.code_entropy_bits <= bits.storage_bits() as f64 + 1e-9);
            assert!(r.code_entropy_bits > 0.0);
        }
    }

    #[test]
    fn ternary_sparsity_visible_in_report() {
        let t = normal_tensor(64 * 64, 2);
        let q = uniform::quantize(&t, Bits::Ternary, Granularity::PerTensor).unwrap();
        let r = report(&t, &q);
        assert!(r.sparsity > 0.8, "sparsity {}", r.sparsity);
    }

    #[test]
    fn normal_8bit_entropy_is_high() {
        // THE honesty check behind Table 1: near-normal weights quantized
        // to 8 bits carry > 4 bits/byte of entropy — dictionary codecs
        // cannot reach the paper's 11.7x on such streams.
        let t = normal_tensor(128 * 64, 3);
        let q = uniform::quantize(&t, Bits::B8, Granularity::PerTensor).unwrap();
        let r = report(&t, &q);
        assert!(r.code_entropy_bits > 4.0, "entropy {}", r.code_entropy_bits);
    }
}
