//! Bit-packing for sub-8-bit code storage (S2).
//!
//! The unpacked `QuantizedTensor` keeps one byte per code for simplicity
//! and because the stage HLOs take u8 inputs; this module provides the
//! dense storage layout used by the TQM container for the §3 bit-width
//! ablation (ternary/2/4/6-bit checkpoints) — LSB-first within each byte,
//! codes never straddle... they DO straddle byte boundaries for 6-bit:
//! a plain little-endian bit stream.

/// Pack `codes` (values < 2^bits) into a little-endian bit stream.
pub fn pack(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; (total_bits + 7) / 8];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 8 || (c as u32) < (1 << bits), "code {c} overflows {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack a little-endian bit stream into `n` codes of `bits` width.
pub fn unpack(packed: &[u8], bits: u32, n: usize) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let mask = if bits == 8 { 0xFFu16 } else { (1u16 << bits) - 1 };
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let lo = packed[byte] as u16 >> off;
        let hi = if off + bits as usize > 8 {
            (packed[byte + 1] as u16) << (8 - off)
        } else {
            0
        };
        out.push(((lo | hi) & mask) as u8);
        bitpos += bits as usize;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    
    #[test]
    fn roundtrip_all_widths() {
        let mut rng = crate::util::Rng::seed_from_u64(0);
        for bits in 1..=8u32 {
            for n in [0usize, 1, 7, 8, 9, 255, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                assert_eq!(packed.len(), (n * bits as usize + 7) / 8);
                assert_eq!(unpack(&packed, bits, n), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn six_bit_straddles_bytes() {
        let codes = vec![0b111111u8, 0b000001, 0b101010, 0b010101];
        let packed = pack(&codes, 6);
        assert_eq!(packed.len(), 3); // 24 bits exactly
        assert_eq!(unpack(&packed, 6, 4), codes);
    }

    #[test]
    fn eight_bit_is_identity() {
        let codes: Vec<u8> = (0..=255).collect();
        assert_eq!(pack(&codes, 8), codes);
        assert_eq!(unpack(&codes, 8, 256), codes);
    }

    #[test]
    fn compression_factor() {
        let codes = vec![1u8; 800];
        assert_eq!(pack(&codes, 2).len(), 200);
        assert_eq!(pack(&codes, 4).len(), 400);
    }
}
