//! Bit-packing for sub-8-bit code storage (S2), plus the fused
//! unpack+dequantize kernel the serving fast path uses.
//!
//! The unpacked `QuantizedTensor` keeps one byte per code for simplicity
//! and because the stage HLOs take u8 inputs; this module provides the
//! dense storage layout used by the TQM container for the §3 bit-width
//! ablation (ternary/2/4/6-bit checkpoints). The layout is a plain
//! little-endian bit stream — LSB-first within each byte, and codes MAY
//! straddle byte boundaries (6-bit codes necessarily do; 1/2/4/8-bit
//! widths happen to divide 8 so theirs never straddle).
//!
//! Two read paths exist on purpose:
//!
//! * [`unpack`]/[`unpack_into`] — codes back to one-byte-per-code, the
//!   form the stage HLOs consume;
//! * [`unpack_dequant_into`] (and its per-channel variants) — a single
//!   fused pass from the packed bit-stream straight to f32, replacing the
//!   old unpack-then-dequantize double pass for host-side consumers. The
//!   arithmetic is bit-identical to `QuantizedTensor::dequantize`
//!   (`(code - zero) * scale` in f32), which a property test enforces for
//!   every width.

/// Pack `codes` (values < 2^bits) into a little-endian bit stream.
pub fn pack(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; (total_bits + 7) / 8];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 8 || (c as u32) < (1 << bits), "code {c} overflows {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Read the code at bit position `bitpos` from a little-endian bit stream.
#[inline(always)]
fn code_at(packed: &[u8], bitpos: usize, bits: u32, mask: u16) -> u8 {
    let byte = bitpos / 8;
    let off = bitpos % 8;
    let lo = packed[byte] as u16 >> off;
    let hi = if off + bits as usize > 8 {
        (packed[byte + 1] as u16) << (8 - off)
    } else {
        0
    };
    ((lo | hi) & mask) as u8
}

#[inline(always)]
fn width_mask(bits: u32) -> u16 {
    if bits == 8 {
        0xFF
    } else {
        (1u16 << bits) - 1
    }
}

/// Unpack a little-endian bit stream into `out.len()` codes of `bits`
/// width, allocation-free (the scratch-reuse form of [`unpack`]).
pub fn unpack_into(packed: &[u8], bits: u32, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    if bits == 8 {
        out.copy_from_slice(&packed[..out.len()]);
        return;
    }
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        *o = code_at(packed, bitpos, bits, mask);
        bitpos += bits as usize;
    }
}

/// Unpack a little-endian bit stream into `n` codes of `bits` width.
pub fn unpack(packed: &[u8], bits: u32, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_into(packed, bits, &mut out);
    out
}

/// Fused unpack + dequantize, per-tensor parameters: emit
/// `(code - zero) * scale` f32s straight from the packed bit-stream,
/// one pass, no intermediate code buffer.
pub fn unpack_dequant_into(packed: &[u8], bits: u32, scale: f32, zero: f32, out: &mut [f32]) {
    assert!((1..=8).contains(&bits));
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        let c = code_at(packed, bitpos, bits, mask);
        *o = (c as f32 - zero) * scale;
        bitpos += bits as usize;
    }
}

/// Fused unpack + dequantize with per-out-channel (axis 1) parameters:
/// element (r, c) of a row-major `[rows, cols]` tensor uses
/// `scale[c]`/`zero[c]` — the matmul-weight layout.
pub fn unpack_dequant_cols_into(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    out: &mut [f32],
) {
    assert!((1..=8).contains(&bits));
    assert_eq!(scale.len(), cols);
    assert_eq!(zero.len(), cols);
    assert!(cols > 0 && out.len() % cols == 0);
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for (i, o) in out.iter_mut().enumerate() {
        let c = i % cols;
        let code = code_at(packed, bitpos, bits, mask);
        *o = (code as f32 - zero[c]) * scale[c];
        bitpos += bits as usize;
    }
}

/// Fused unpack + dequantize with per-row (axis 0) parameters: element
/// (r, c) uses `scale[r]`/`zero[r]` — the embedding-table layout.
pub fn unpack_dequant_rows_into(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    out: &mut [f32],
) {
    assert!((1..=8).contains(&bits));
    assert!(cols > 0 && out.len() % cols == 0);
    let rows = out.len() / cols;
    assert_eq!(scale.len(), rows);
    assert_eq!(zero.len(), rows);
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for (r, row) in out.chunks_mut(cols).enumerate() {
        let (s, z) = (scale[r], zero[r]);
        for o in row.iter_mut() {
            let code = code_at(packed, bitpos, bits, mask);
            *o = (code as f32 - z) * s;
            bitpos += bits as usize;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = crate::util::Rng::seed_from_u64(0);
        for bits in 1..=8u32 {
            for n in [0usize, 1, 7, 8, 9, 255, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                assert_eq!(packed.len(), (n * bits as usize + 7) / 8);
                assert_eq!(unpack(&packed, bits, n), codes, "bits={bits} n={n}");
                let mut into = vec![0u8; n];
                unpack_into(&packed, bits, &mut into);
                assert_eq!(into, codes, "unpack_into bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn six_bit_straddles_bytes() {
        let codes = vec![0b111111u8, 0b000001, 0b101010, 0b010101];
        let packed = pack(&codes, 6);
        assert_eq!(packed.len(), 3); // 24 bits exactly
        assert_eq!(unpack(&packed, 6, 4), codes);
    }

    #[test]
    fn eight_bit_is_identity() {
        let codes: Vec<u8> = (0..=255).collect();
        assert_eq!(pack(&codes, 8), codes);
        assert_eq!(unpack(&codes, 8, 256), codes);
    }

    #[test]
    fn compression_factor() {
        let codes = vec![1u8; 800];
        assert_eq!(pack(&codes, 2).len(), 200);
        assert_eq!(pack(&codes, 4).len(), 400);
    }

    /// Reference two-step path the fused kernels must match bit-exactly.
    fn two_step(packed: &[u8], bits: u32, n: usize, sz: impl Fn(usize) -> (f32, f32)) -> Vec<f32> {
        unpack(packed, bits, n)
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let (s, z) = sz(i);
                (c as f32 - z) * s
            })
            .collect()
    }

    #[test]
    fn fused_matches_two_step_all_widths() {
        // property test: for widths 1..=8 and awkward lengths, the fused
        // kernel equals unpack-then-dequantize bit for bit (f32 equality,
        // not approximate)
        let mut rng = crate::util::Rng::seed_from_u64(3);
        for bits in 1..=8u32 {
            for n in [1usize, 7, 64, 255, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                let (scale, zero) = (0.0173f32, 5.0f32);
                let mut fused = vec![0.0f32; n];
                unpack_dequant_into(&packed, bits, scale, zero, &mut fused);
                let reference = two_step(&packed, bits, n, |_| (scale, zero));
                assert_eq!(fused, reference, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn fused_per_channel_matches_two_step() {
        let mut rng = crate::util::Rng::seed_from_u64(4);
        for bits in [2u32, 4, 6, 8] {
            let (rows, cols) = (24usize, 20usize);
            let n = rows * cols;
            let codes: Vec<u8> =
                (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
            let packed = pack(&codes, bits);
            let cs: Vec<f32> = (0..cols).map(|c| 0.001 + c as f32 * 0.01).collect();
            let cz: Vec<f32> = (0..cols).map(|c| (c % 5) as f32).collect();
            let mut fused = vec![0.0f32; n];
            unpack_dequant_cols_into(&packed, bits, cols, &cs, &cz, &mut fused);
            let reference = two_step(&packed, bits, n, |i| (cs[i % cols], cz[i % cols]));
            assert_eq!(fused, reference, "cols bits={bits}");

            let rs: Vec<f32> = (0..rows).map(|r| 0.002 + r as f32 * 0.02).collect();
            let rz: Vec<f32> = (0..rows).map(|r| (r % 3) as f32).collect();
            let mut fused_r = vec![0.0f32; n];
            unpack_dequant_rows_into(&packed, bits, cols, &rs, &rz, &mut fused_r);
            let reference_r = two_step(&packed, bits, n, |i| (rs[i / cols], rz[i / cols]));
            assert_eq!(fused_r, reference_r, "rows bits={bits}");
        }
    }

    #[test]
    fn fused_matches_quantized_tensor_dequantize() {
        // end-to-end against the canonical QuantizedTensor::dequantize
        use crate::quant::{uniform, Bits, Granularity};
        use crate::tensor::Tensor;
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let t = Tensor::new(vec![16, 12], (0..192).map(|_| rng.normal_f32()).collect()).unwrap();
        for bits in [Bits::Ternary, Bits::B2, Bits::B4, Bits::B6, Bits::B8] {
            let q = uniform::quantize(&t, bits, Granularity::PerTensor).unwrap();
            let packed = pack(&q.codes.data, bits.storage_bits());
            let mut fused = vec![0.0f32; q.codes.data.len()];
            unpack_dequant_into(&packed, bits.storage_bits(), q.scale[0], q.zero[0], &mut fused);
            assert_eq!(fused, q.dequantize().data, "{bits:?}");
        }
    }
}
