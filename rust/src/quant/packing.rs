//! Bit-packing for sub-8-bit code storage (S2), plus the fused
//! unpack+dequantize kernel the serving fast path uses.
//!
//! The unpacked `QuantizedTensor` keeps one byte per code for simplicity
//! and because the stage HLOs take u8 inputs; this module provides the
//! dense storage layout used by the TQM container for the §3 bit-width
//! ablation (ternary/2/4/6-bit checkpoints). The layout is a plain
//! little-endian bit stream — LSB-first within each byte, and codes MAY
//! straddle byte boundaries (6-bit codes necessarily do; 1/2/4/8-bit
//! widths happen to divide 8 so theirs never straddle).
//!
//! Three read paths exist on purpose:
//!
//! * [`unpack`]/[`unpack_into`] — codes back to one-byte-per-code, the
//!   form the stage HLOs consume;
//! * [`unpack_dequant_into`] (and its per-channel variants) — a single
//!   fused pass from the packed bit-stream straight to f32, replacing the
//!   old unpack-then-dequantize double pass for host-side consumers. The
//!   arithmetic is bit-identical to `QuantizedTensor::dequantize`
//!   (`(code - zero) * scale` in f32), which a property test enforces for
//!   every width;
//! * [`qgemv`] (and its per-channel variants) — quantized-domain GEMV:
//!   `out = x · W` computed **directly against the packed bit-stream**,
//!   never materializing the f32 weight arena at all. Per scale-group the
//!   kernel builds a `2^bits` dequant LUT (`lut[c] = (c - zero) * scale`,
//!   the exact expression the fused dequant uses), so the inner loop is a
//!   table-lookup FMA. Value *and accumulation order* are bit-identical
//!   to `unpack_dequant_into` followed by the decoded-path matmul
//!   (row-major `[rows, cols]`, rows accumulated in ascending order,
//!   zero entries of `x` skipped) — the property tests assert exact f32
//!   equality, which is what lets the expert cache serve packed-resident
//!   experts interchangeably with decoded ones.

/// Pack `codes` (values < 2^bits) into a little-endian bit stream.
pub fn pack(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; (total_bits + 7) / 8];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 8 || (c as u32) < (1 << bits), "code {c} overflows {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Read the code at bit position `bitpos` from a little-endian bit stream.
#[inline(always)]
fn code_at(packed: &[u8], bitpos: usize, bits: u32, mask: u16) -> u8 {
    let byte = bitpos / 8;
    let off = bitpos % 8;
    let lo = packed[byte] as u16 >> off;
    let hi = if off + bits as usize > 8 {
        (packed[byte + 1] as u16) << (8 - off)
    } else {
        0
    };
    ((lo | hi) & mask) as u8
}

#[inline(always)]
fn width_mask(bits: u32) -> u16 {
    if bits == 8 {
        0xFF
    } else {
        (1u16 << bits) - 1
    }
}

/// Unpack a little-endian bit stream into `out.len()` codes of `bits`
/// width, allocation-free (the scratch-reuse form of [`unpack`]).
pub fn unpack_into(packed: &[u8], bits: u32, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    if bits == 8 {
        out.copy_from_slice(&packed[..out.len()]);
        return;
    }
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        *o = code_at(packed, bitpos, bits, mask);
        bitpos += bits as usize;
    }
}

/// Unpack a little-endian bit stream into `n` codes of `bits` width.
pub fn unpack(packed: &[u8], bits: u32, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_into(packed, bits, &mut out);
    out
}

/// Fused unpack + dequantize, per-tensor parameters: emit
/// `(code - zero) * scale` f32s straight from the packed bit-stream,
/// one pass, no intermediate code buffer.
pub fn unpack_dequant_into(packed: &[u8], bits: u32, scale: f32, zero: f32, out: &mut [f32]) {
    assert!((1..=8).contains(&bits));
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        let c = code_at(packed, bitpos, bits, mask);
        *o = (c as f32 - zero) * scale;
        bitpos += bits as usize;
    }
}

/// Fused unpack + dequantize with per-out-channel (axis 1) parameters:
/// element (r, c) of a row-major `[rows, cols]` tensor uses
/// `scale[c]`/`zero[c]` — the matmul-weight layout.
pub fn unpack_dequant_cols_into(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    out: &mut [f32],
) {
    assert!((1..=8).contains(&bits));
    assert_eq!(scale.len(), cols);
    assert_eq!(zero.len(), cols);
    assert!(cols > 0 && out.len() % cols == 0);
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for (i, o) in out.iter_mut().enumerate() {
        let c = i % cols;
        let code = code_at(packed, bitpos, bits, mask);
        *o = (code as f32 - zero[c]) * scale[c];
        bitpos += bits as usize;
    }
}

/// Fused unpack + dequantize with per-row (axis 0) parameters: element
/// (r, c) uses `scale[r]`/`zero[r]` — the embedding-table layout.
pub fn unpack_dequant_rows_into(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    out: &mut [f32],
) {
    assert!((1..=8).contains(&bits));
    assert!(cols > 0 && out.len() % cols == 0);
    let rows = out.len() / cols;
    assert_eq!(scale.len(), rows);
    assert_eq!(zero.len(), rows);
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for (r, row) in out.chunks_mut(cols).enumerate() {
        let (s, z) = (scale[r], zero[r]);
        for o in row.iter_mut() {
            let code = code_at(packed, bitpos, bits, mask);
            *o = (code as f32 - z) * s;
            bitpos += bits as usize;
        }
    }
}

// ---------------------------------------------------------------------------
// Quantized-domain GEMV (qGEMV)
// ---------------------------------------------------------------------------
//
// All three kernels compute `out = x · W` for a row-major `[rows, cols]`
// weight matrix whose elements live in the little-endian bit-packed code
// stream, with `rows == x.len()` and `out.len() == cols`. They reproduce
// the decoded matmul exactly: `out` is zeroed, rows are walked in
// ascending order, a row whose `x[i] == 0.0` is skipped entirely (the
// decoded path's `continue`), and each contribution is
// `x[i] * ((code - zero) * scale)` — the dequantized weight computed
// first, then scaled by the activation, so every intermediate f32 equals
// the decoded path's bit for bit.

/// Shared assertion set for the qGEMV kernels.
#[inline(always)]
fn qgemv_checks(packed: &[u8], bits: u32, cols: usize, x: &[f32], out: &[f32]) {
    assert!((1..=8).contains(&bits));
    assert_eq!(out.len(), cols, "qgemv output dim mismatch");
    assert!(
        packed.len() * 8 >= x.len() * cols * bits as usize,
        "packed stream too short for [{}, {cols}] at {bits} bits",
        x.len()
    );
}

/// Quantized-domain GEMV, per-tensor parameters: one `2^bits` dequant
/// LUT serves the whole matrix.
pub fn qgemv(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: f32,
    zero: f32,
    x: &[f32],
    out: &mut [f32],
) {
    qgemv_checks(packed, bits, cols, x, out);
    let mask = width_mask(bits);
    let levels = 1usize << bits;
    let mut lut = [0.0f32; 256];
    for (c, v) in lut.iter_mut().take(levels).enumerate() {
        *v = (c as f32 - zero) * scale;
    }
    out.fill(0.0);
    let row_bits = cols * bits as usize;
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let mut bitpos = i * row_bits;
        for o in out.iter_mut() {
            let c = code_at(packed, bitpos, bits, mask);
            *o += xi * lut[c as usize];
            bitpos += bits as usize;
        }
    }
}

/// Quantized-domain GEMV with per-row (axis 0) parameters: element
/// (r, c) uses `scale[r]`/`zero[r]`; the row's LUT is rebuilt per row
/// (`2^bits` entries, amortized over `cols` lookups).
pub fn qgemv_rows(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    x: &[f32],
    out: &mut [f32],
) {
    qgemv_checks(packed, bits, cols, x, out);
    assert_eq!(scale.len(), x.len());
    assert_eq!(zero.len(), x.len());
    let mask = width_mask(bits);
    let levels = 1usize << bits;
    let mut lut = [0.0f32; 256];
    out.fill(0.0);
    let row_bits = cols * bits as usize;
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let (s, z) = (scale[i], zero[i]);
        for (c, v) in lut.iter_mut().take(levels).enumerate() {
            *v = (c as f32 - z) * s;
        }
        let mut bitpos = i * row_bits;
        for o in out.iter_mut() {
            let c = code_at(packed, bitpos, bits, mask);
            *o += xi * lut[c as usize];
            bitpos += bits as usize;
        }
    }
}

/// Quantized-domain GEMV with per-out-channel (axis 1) parameters:
/// element (r, c) uses `scale[c]`/`zero[c]` — the matmul-weight layout.
/// The dequant is computed inline (`scale`/`zero` are indexed by the
/// inner loop, so there is no single LUT to share); see
/// [`qgemv_cols_lut`] for the precomputed-LUT form.
pub fn qgemv_cols(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    x: &[f32],
    out: &mut [f32],
) {
    qgemv_checks(packed, bits, cols, x, out);
    assert_eq!(scale.len(), cols);
    assert_eq!(zero.len(), cols);
    let mask = width_mask(bits);
    out.fill(0.0);
    let row_bits = cols * bits as usize;
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let mut bitpos = i * row_bits;
        for ((o, &s), &z) in out.iter_mut().zip(scale).zip(zero) {
            let c = code_at(packed, bitpos, bits, mask);
            *o += xi * ((c as f32 - z) * s);
            bitpos += bits as usize;
        }
    }
}

/// [`qgemv_cols`] against a precomputed per-column LUT
/// (`lut[c * 2^bits + code]`, from [`build_col_lut`]) — the form the
/// packed-resident expert cache uses, where the LUT is built once when
/// the expert lands and reused every token.
pub fn qgemv_cols_lut(
    packed: &[u8],
    bits: u32,
    cols: usize,
    lut: &[f32],
    x: &[f32],
    out: &mut [f32],
) {
    qgemv_checks(packed, bits, cols, x, out);
    let levels = 1usize << bits;
    assert_eq!(lut.len(), cols * levels, "column LUT size mismatch");
    let mask = width_mask(bits);
    out.fill(0.0);
    let row_bits = cols * bits as usize;
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let mut bitpos = i * row_bits;
        for (o, l) in out.iter_mut().zip(lut.chunks_exact(levels)) {
            let c = code_at(packed, bitpos, bits, mask);
            *o += xi * l[c as usize];
            bitpos += bits as usize;
        }
    }
}

/// Per-column dequant LUT for axis-1 granularity: entry
/// `[c * 2^bits + code] = (code - zero[c]) * scale[c]` — the exact
/// expression every other dequant path uses, so LUT and inline kernels
/// are interchangeable bit for bit.
pub fn build_col_lut(bits: u32, scale: &[f32], zero: &[f32]) -> Vec<f32> {
    assert!((1..=8).contains(&bits));
    assert_eq!(scale.len(), zero.len());
    let levels = 1usize << bits;
    let mut lut = vec![0.0f32; scale.len() * levels];
    for (j, chunk) in lut.chunks_mut(levels).enumerate() {
        let (s, z) = (scale[j], zero[j]);
        for (c, v) in chunk.iter_mut().enumerate() {
            *v = (c as f32 - z) * s;
        }
    }
    lut
}

/// Bytes a packed-resident matrix spends on its per-column LUT: the full
/// `cols * 2^bits` table when that is no larger than the packed code
/// stream itself (always true for real-sized matrices), zero otherwise
/// (tiny matrices fall back to the inline [`qgemv_cols`] kernel rather
/// than let the LUT dominate the footprint). Deterministic from index
/// metadata alone, so the expert cache can size a packed expert before
/// decoding it.
pub fn col_lut_bytes(bits: u32, cols: usize, packed_len: usize) -> usize {
    let lut = 4 * cols * (1usize << bits);
    if lut <= packed_len {
        lut
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = crate::util::Rng::seed_from_u64(0);
        for bits in 1..=8u32 {
            for n in [0usize, 1, 7, 8, 9, 255, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                assert_eq!(packed.len(), (n * bits as usize + 7) / 8);
                assert_eq!(unpack(&packed, bits, n), codes, "bits={bits} n={n}");
                let mut into = vec![0u8; n];
                unpack_into(&packed, bits, &mut into);
                assert_eq!(into, codes, "unpack_into bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn six_bit_straddles_bytes() {
        let codes = vec![0b111111u8, 0b000001, 0b101010, 0b010101];
        let packed = pack(&codes, 6);
        assert_eq!(packed.len(), 3); // 24 bits exactly
        assert_eq!(unpack(&packed, 6, 4), codes);
    }

    #[test]
    fn eight_bit_is_identity() {
        let codes: Vec<u8> = (0..=255).collect();
        assert_eq!(pack(&codes, 8), codes);
        assert_eq!(unpack(&codes, 8, 256), codes);
    }

    #[test]
    fn compression_factor() {
        let codes = vec![1u8; 800];
        assert_eq!(pack(&codes, 2).len(), 200);
        assert_eq!(pack(&codes, 4).len(), 400);
    }

    /// Reference two-step path the fused kernels must match bit-exactly.
    fn two_step(packed: &[u8], bits: u32, n: usize, sz: impl Fn(usize) -> (f32, f32)) -> Vec<f32> {
        unpack(packed, bits, n)
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let (s, z) = sz(i);
                (c as f32 - z) * s
            })
            .collect()
    }

    #[test]
    fn fused_matches_two_step_all_widths() {
        // property test: for widths 1..=8 and awkward lengths, the fused
        // kernel equals unpack-then-dequantize bit for bit (f32 equality,
        // not approximate)
        let mut rng = crate::util::Rng::seed_from_u64(3);
        for bits in 1..=8u32 {
            for n in [1usize, 7, 64, 255, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                let (scale, zero) = (0.0173f32, 5.0f32);
                let mut fused = vec![0.0f32; n];
                unpack_dequant_into(&packed, bits, scale, zero, &mut fused);
                let reference = two_step(&packed, bits, n, |_| (scale, zero));
                assert_eq!(fused, reference, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn fused_per_channel_matches_two_step() {
        let mut rng = crate::util::Rng::seed_from_u64(4);
        for bits in [2u32, 4, 6, 8] {
            let (rows, cols) = (24usize, 20usize);
            let n = rows * cols;
            let codes: Vec<u8> =
                (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
            let packed = pack(&codes, bits);
            let cs: Vec<f32> = (0..cols).map(|c| 0.001 + c as f32 * 0.01).collect();
            let cz: Vec<f32> = (0..cols).map(|c| (c % 5) as f32).collect();
            let mut fused = vec![0.0f32; n];
            unpack_dequant_cols_into(&packed, bits, cols, &cs, &cz, &mut fused);
            let reference = two_step(&packed, bits, n, |i| (cs[i % cols], cz[i % cols]));
            assert_eq!(fused, reference, "cols bits={bits}");

            let rs: Vec<f32> = (0..rows).map(|r| 0.002 + r as f32 * 0.02).collect();
            let rz: Vec<f32> = (0..rows).map(|r| (r % 3) as f32).collect();
            let mut fused_r = vec![0.0f32; n];
            unpack_dequant_rows_into(&packed, bits, cols, &rs, &rz, &mut fused_r);
            let reference_r = two_step(&packed, bits, n, |i| (rs[i / cols], rz[i / cols]));
            assert_eq!(fused_r, reference_r, "rows bits={bits}");
        }
    }

    /// Decoded-path reference the qGEMV kernels must match bit-exactly:
    /// unpack + dequantize to an f32 arena, then the expert FFN's matmul
    /// shape (rows ascending, zero activations skipped, `xi * w`).
    fn ref_gemv(
        packed: &[u8],
        bits: u32,
        rows: usize,
        cols: usize,
        sz: impl Fn(usize) -> (f32, f32),
        x: &[f32],
    ) -> Vec<f32> {
        let w = two_step(packed, bits, rows * cols, sz);
        let mut out = vec![0.0f32; cols];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &w[i * cols..(i + 1) * cols];
            for (o, &wij) in out.iter_mut().zip(row) {
                *o += xi * wij;
            }
        }
        out
    }

    /// An activation vector with sign changes and forced exact zeros (the
    /// decoded path's skip branch must be replicated, not approximated).
    fn test_x(rng: &mut crate::util::Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| if i % 5 == 3 { 0.0 } else { rng.normal_f32() })
            .collect()
    }

    #[test]
    fn qgemv_matches_unpack_then_matmul_all_widths() {
        // property test: widths 1..=8 (6-bit codes straddle bytes) and
        // ragged shapes, per-tensor granularity — exact f32 equality
        let mut rng = crate::util::Rng::seed_from_u64(7);
        for bits in 1..=8u32 {
            for (rows, cols) in [(1usize, 1usize), (3, 5), (7, 13), (16, 24), (33, 7)] {
                let n = rows * cols;
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                let x = test_x(&mut rng, rows);
                let (scale, zero) = (0.031f32, 3.0f32);
                let mut got = vec![1.0f32; cols]; // kernels must zero `out`
                qgemv(&packed, bits, cols, scale, zero, &x, &mut got);
                let want = ref_gemv(&packed, bits, rows, cols, |_| (scale, zero), &x);
                assert_eq!(got, want, "bits={bits} rows={rows} cols={cols}");
            }
        }
    }

    #[test]
    fn qgemv_per_channel_matches_unpack_then_matmul() {
        let mut rng = crate::util::Rng::seed_from_u64(8);
        for bits in 1..=8u32 {
            for (rows, cols) in [(5usize, 3usize), (24, 20), (13, 31)] {
                let n = rows * cols;
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                let x = test_x(&mut rng, rows);

                // per-row (axis 0) parameters
                let rs: Vec<f32> = (0..rows).map(|r| 0.002 + r as f32 * 0.013).collect();
                let rz: Vec<f32> = (0..rows).map(|r| (r % 4) as f32).collect();
                let mut got = vec![0.0f32; cols];
                qgemv_rows(&packed, bits, cols, &rs, &rz, &x, &mut got);
                let want = ref_gemv(&packed, bits, rows, cols, |i| (rs[i / cols], rz[i / cols]), &x);
                assert_eq!(got, want, "rows bits={bits} {rows}x{cols}");

                // per-col (axis 1) parameters: inline and LUT kernels
                let cs: Vec<f32> = (0..cols).map(|c| 0.004 + c as f32 * 0.009).collect();
                let cz: Vec<f32> = (0..cols).map(|c| (c % 6) as f32).collect();
                let mut inline = vec![0.0f32; cols];
                qgemv_cols(&packed, bits, cols, &cs, &cz, &x, &mut inline);
                let want_c =
                    ref_gemv(&packed, bits, rows, cols, |i| (cs[i % cols], cz[i % cols]), &x);
                assert_eq!(inline, want_c, "cols bits={bits} {rows}x{cols}");
                let lut = build_col_lut(bits, &cs, &cz);
                let mut via_lut = vec![0.0f32; cols];
                qgemv_cols_lut(&packed, bits, cols, &lut, &x, &mut via_lut);
                assert_eq!(via_lut, want_c, "cols-lut bits={bits} {rows}x{cols}");
            }
        }
    }

    #[test]
    fn qgemv_all_zero_activations_yield_zero() {
        let codes = vec![1u8; 6 * 4];
        let packed = pack(&codes, 6);
        let x = vec![0.0f32; 6];
        let mut out = vec![9.0f32; 4];
        qgemv(&packed, 6, 4, 0.5, 1.0, &x, &mut out);
        assert_eq!(out, vec![0.0f32; 4], "output must be zeroed even when every row skips");
    }

    #[test]
    fn col_lut_bytes_rule() {
        // stored only when the LUT is no larger than the packed codes:
        // 4096x64 @ 4-bit -> codes 131072 B, lut 64*16*4 = 4096 B: stored
        assert_eq!(col_lut_bytes(4, 64, 131072), 4096);
        // tiny matrix: lut 64*16*4 = 4096 B > 96 B of codes: skipped
        assert_eq!(col_lut_bytes(4, 64, 96), 0);
        // boundary: equal sizes are stored
        assert_eq!(col_lut_bytes(2, 8, 128), 128);
        assert_eq!(col_lut_bytes(2, 8, 127), 0);
    }

    #[test]
    fn fused_matches_quantized_tensor_dequantize() {
        // end-to-end against the canonical QuantizedTensor::dequantize
        use crate::quant::{uniform, Bits, Granularity};
        use crate::tensor::Tensor;
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let t = Tensor::new(vec![16, 12], (0..192).map(|_| rng.normal_f32()).collect()).unwrap();
        for bits in [Bits::Ternary, Bits::B2, Bits::B4, Bits::B6, Bits::B8] {
            let q = uniform::quantize(&t, bits, Granularity::PerTensor).unwrap();
            let packed = pack(&q.codes.data, bits.storage_bits());
            let mut fused = vec![0.0f32; q.codes.data.len()];
            unpack_dequant_into(&packed, bits.storage_bits(), q.scale[0], q.zero[0], &mut fused);
            assert_eq!(fused, q.dequantize().data, "{bits:?}");
        }
    }
}
