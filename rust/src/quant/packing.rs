//! Bit-packing for sub-8-bit code storage (S2), plus the fused
//! unpack+dequantize kernel the serving fast path uses.
//!
//! The unpacked `QuantizedTensor` keeps one byte per code for simplicity
//! and because the stage HLOs take u8 inputs; this module provides the
//! dense storage layout used by the TQM container for the §3 bit-width
//! ablation (ternary/2/4/6-bit checkpoints). The layout is a plain
//! little-endian bit stream — LSB-first within each byte, and codes MAY
//! straddle byte boundaries (6-bit codes necessarily do; 1/2/4/8-bit
//! widths happen to divide 8 so theirs never straddle).
//!
//! Three read paths exist on purpose:
//!
//! * [`unpack`]/[`unpack_into`] — codes back to one-byte-per-code, the
//!   form the stage HLOs consume;
//! * [`unpack_dequant_into`] (and its per-channel variants) — a single
//!   fused pass from the packed bit-stream straight to f32, replacing the
//!   old unpack-then-dequantize double pass for host-side consumers. The
//!   arithmetic is bit-identical to `QuantizedTensor::dequantize`
//!   (`(code - zero) * scale` in f32), which a property test enforces for
//!   every width;
//! * [`qgemv`] (and its per-channel variants) — quantized-domain GEMV:
//!   `out = x · W` computed **directly against the packed bit-stream**,
//!   never materializing the f32 weight arena at all. Per scale-group the
//!   kernel builds a `2^bits` dequant LUT (`lut[c] = (c - zero) * scale`,
//!   the exact expression the fused dequant uses), so the inner loop is a
//!   table-lookup FMA. Value *and accumulation order* are bit-identical
//!   to `unpack_dequant_into` followed by the decoded-path matmul
//!   (row-major `[rows, cols]`, rows accumulated in ascending order,
//!   zero entries of `x` skipped) — the property tests assert exact f32
//!   equality, which is what lets the expert cache serve packed-resident
//!   experts interchangeably with decoded ones.

/// Pack `codes` (values < 2^bits) into a little-endian bit stream.
pub fn pack(codes: &[u8], bits: u32) -> Vec<u8> {
    assert!((1..=8).contains(&bits));
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; (total_bits + 7) / 8];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(bits == 8 || (c as u32) < (1 << bits), "code {c} overflows {bits} bits");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + bits as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += bits as usize;
    }
    out
}

/// Read the code at bit position `bitpos` from a little-endian bit stream.
#[inline(always)]
fn code_at(packed: &[u8], bitpos: usize, bits: u32, mask: u16) -> u8 {
    let byte = bitpos / 8;
    let off = bitpos % 8;
    let lo = packed[byte] as u16 >> off;
    let hi = if off + bits as usize > 8 {
        (packed[byte + 1] as u16) << (8 - off)
    } else {
        0
    };
    ((lo | hi) & mask) as u8
}

#[inline(always)]
fn width_mask(bits: u32) -> u16 {
    if bits == 8 {
        0xFF
    } else {
        (1u16 << bits) - 1
    }
}

/// Unpack a little-endian bit stream into `out.len()` codes of `bits`
/// width, allocation-free (the scratch-reuse form of [`unpack`]).
pub fn unpack_into(packed: &[u8], bits: u32, out: &mut [u8]) {
    assert!((1..=8).contains(&bits));
    if bits == 8 {
        out.copy_from_slice(&packed[..out.len()]);
        return;
    }
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        *o = code_at(packed, bitpos, bits, mask);
        bitpos += bits as usize;
    }
}

/// Unpack a little-endian bit stream into `n` codes of `bits` width.
pub fn unpack(packed: &[u8], bits: u32, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_into(packed, bits, &mut out);
    out
}

/// Fused unpack + dequantize, per-tensor parameters: emit
/// `(code - zero) * scale` f32s straight from the packed bit-stream,
/// one pass, no intermediate code buffer.
pub fn unpack_dequant_into(packed: &[u8], bits: u32, scale: f32, zero: f32, out: &mut [f32]) {
    assert!((1..=8).contains(&bits));
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        let c = code_at(packed, bitpos, bits, mask);
        *o = (c as f32 - zero) * scale;
        bitpos += bits as usize;
    }
}

/// Fused unpack + dequantize with per-out-channel (axis 1) parameters:
/// element (r, c) of a row-major `[rows, cols]` tensor uses
/// `scale[c]`/`zero[c]` — the matmul-weight layout.
pub fn unpack_dequant_cols_into(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    out: &mut [f32],
) {
    assert!((1..=8).contains(&bits));
    assert_eq!(scale.len(), cols);
    assert_eq!(zero.len(), cols);
    assert!(cols > 0 && out.len() % cols == 0);
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for (i, o) in out.iter_mut().enumerate() {
        let c = i % cols;
        let code = code_at(packed, bitpos, bits, mask);
        *o = (code as f32 - zero[c]) * scale[c];
        bitpos += bits as usize;
    }
}

/// Fused unpack + dequantize with per-row (axis 0) parameters: element
/// (r, c) uses `scale[r]`/`zero[r]` — the embedding-table layout.
pub fn unpack_dequant_rows_into(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    out: &mut [f32],
) {
    assert!((1..=8).contains(&bits));
    assert!(cols > 0 && out.len() % cols == 0);
    let rows = out.len() / cols;
    assert_eq!(scale.len(), rows);
    assert_eq!(zero.len(), rows);
    let mask = width_mask(bits);
    let mut bitpos = 0usize;
    for (r, row) in out.chunks_mut(cols).enumerate() {
        let (s, z) = (scale[r], zero[r]);
        for o in row.iter_mut() {
            let code = code_at(packed, bitpos, bits, mask);
            *o = (code as f32 - z) * s;
            bitpos += bits as usize;
        }
    }
}

// ---------------------------------------------------------------------------
// Quantized-domain GEMV (qGEMV)
// ---------------------------------------------------------------------------
//
// All three kernels compute `out = x · W` for a row-major `[rows, cols]`
// weight matrix whose elements live in the little-endian bit-packed code
// stream, with `rows == x.len()` and `out.len() == cols`. They reproduce
// the decoded matmul exactly: `out` is zeroed, rows are walked in
// ascending order, a row whose `x[i] == 0.0` is skipped entirely (the
// decoded path's `continue`), and each contribution is
// `x[i] * ((code - zero) * scale)` — the dequantized weight computed
// first, then scaled by the activation, so every intermediate f32 equals
// the decoded path's bit for bit.

/// Shared assertion set for the qGEMV kernels.
#[inline(always)]
fn qgemv_checks(packed: &[u8], bits: u32, cols: usize, x: &[f32], out: &[f32]) {
    assert!((1..=8).contains(&bits));
    assert_eq!(out.len(), cols, "qgemv output dim mismatch");
    assert!(
        packed.len() * 8 >= x.len() * cols * bits as usize,
        "packed stream too short for [{}, {cols}] at {bits} bits",
        x.len()
    );
}

/// Quantized-domain GEMV, per-tensor parameters: one `2^bits` dequant
/// LUT serves the whole matrix.
pub fn qgemv(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: f32,
    zero: f32,
    x: &[f32],
    out: &mut [f32],
) {
    qgemv_checks(packed, bits, cols, x, out);
    let mask = width_mask(bits);
    let levels = 1usize << bits;
    let mut lut = [0.0f32; 256];
    for (c, v) in lut.iter_mut().take(levels).enumerate() {
        *v = (c as f32 - zero) * scale;
    }
    out.fill(0.0);
    let row_bits = cols * bits as usize;
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let mut bitpos = i * row_bits;
        for o in out.iter_mut() {
            let c = code_at(packed, bitpos, bits, mask);
            *o += xi * lut[c as usize];
            bitpos += bits as usize;
        }
    }
}

/// Quantized-domain GEMV with per-row (axis 0) parameters: element
/// (r, c) uses `scale[r]`/`zero[r]`; the row's LUT is rebuilt per row
/// (`2^bits` entries, amortized over `cols` lookups).
pub fn qgemv_rows(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    x: &[f32],
    out: &mut [f32],
) {
    qgemv_checks(packed, bits, cols, x, out);
    assert_eq!(scale.len(), x.len());
    assert_eq!(zero.len(), x.len());
    let mask = width_mask(bits);
    let levels = 1usize << bits;
    let mut lut = [0.0f32; 256];
    out.fill(0.0);
    let row_bits = cols * bits as usize;
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let (s, z) = (scale[i], zero[i]);
        for (c, v) in lut.iter_mut().take(levels).enumerate() {
            *v = (c as f32 - z) * s;
        }
        let mut bitpos = i * row_bits;
        for o in out.iter_mut() {
            let c = code_at(packed, bitpos, bits, mask);
            *o += xi * lut[c as usize];
            bitpos += bits as usize;
        }
    }
}

/// Quantized-domain GEMV with per-out-channel (axis 1) parameters:
/// element (r, c) uses `scale[c]`/`zero[c]` — the matmul-weight layout.
/// The dequant is computed inline (`scale`/`zero` are indexed by the
/// inner loop, so there is no single LUT to share); see
/// [`qgemv_cols_lut`] for the precomputed-LUT form.
pub fn qgemv_cols(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    x: &[f32],
    out: &mut [f32],
) {
    qgemv_checks(packed, bits, cols, x, out);
    assert_eq!(scale.len(), cols);
    assert_eq!(zero.len(), cols);
    let mask = width_mask(bits);
    out.fill(0.0);
    let row_bits = cols * bits as usize;
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let mut bitpos = i * row_bits;
        for ((o, &s), &z) in out.iter_mut().zip(scale).zip(zero) {
            let c = code_at(packed, bitpos, bits, mask);
            *o += xi * ((c as f32 - z) * s);
            bitpos += bits as usize;
        }
    }
}

/// [`qgemv_cols`] against a precomputed per-column LUT
/// (`lut[c * 2^bits + code]`, from [`build_col_lut`]) — the form the
/// packed-resident expert cache uses, where the LUT is built once when
/// the expert lands and reused every token.
pub fn qgemv_cols_lut(
    packed: &[u8],
    bits: u32,
    cols: usize,
    lut: &[f32],
    x: &[f32],
    out: &mut [f32],
) {
    qgemv_checks(packed, bits, cols, x, out);
    let levels = 1usize << bits;
    assert_eq!(lut.len(), cols * levels, "column LUT size mismatch");
    let mask = width_mask(bits);
    out.fill(0.0);
    let row_bits = cols * bits as usize;
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let mut bitpos = i * row_bits;
        for (o, l) in out.iter_mut().zip(lut.chunks_exact(levels)) {
            let c = code_at(packed, bitpos, bits, mask);
            *o += xi * l[c as usize];
            bitpos += bits as usize;
        }
    }
}

/// Per-column dequant LUT for axis-1 granularity: entry
/// `[c * 2^bits + code] = (code - zero[c]) * scale[c]` — the exact
/// expression every other dequant path uses, so LUT and inline kernels
/// are interchangeable bit for bit.
pub fn build_col_lut(bits: u32, scale: &[f32], zero: &[f32]) -> Vec<f32> {
    assert!((1..=8).contains(&bits));
    assert_eq!(scale.len(), zero.len());
    let levels = 1usize << bits;
    let mut lut = vec![0.0f32; scale.len() * levels];
    for (j, chunk) in lut.chunks_mut(levels).enumerate() {
        let (s, z) = (scale[j], zero[j]);
        for (c, v) in chunk.iter_mut().enumerate() {
            *v = (c as f32 - z) * s;
        }
    }
    lut
}

/// Bytes a packed-resident matrix spends on its per-column LUT: the full
/// `cols * 2^bits` table when that is no larger than the packed code
/// stream itself (always true for real-sized matrices), zero otherwise
/// (tiny matrices fall back to the inline [`qgemv_cols`] kernel rather
/// than let the LUT dominate the footprint). Deterministic from index
/// metadata alone, so the expert cache can size a packed expert before
/// decoding it.
pub fn col_lut_bytes(bits: u32, cols: usize, packed_len: usize) -> usize {
    let lut = 4 * cols * (1usize << bits);
    if lut <= packed_len {
        lut
    } else {
        0
    }
}

/// The one LUT-profitability rule: bytes a packed matrix of the given
/// granularity spends on a precomputed dequant LUT. Only per-out-channel
/// (axis 1) matrices ever store one — per-tensor and per-row kernels
/// build their `2^bits` table on the stack — and then only when
/// [`col_lut_bytes`] says it pays for itself. Every consumer of the rule
/// (`PackedMatrix::new`, the `TqmReader` index's `packed_resident_bytes`,
/// and the cache's size-before-decode admission) MUST call this so the
/// bytes the index promises are the bytes the decode allocates.
pub fn col_lut_stored_bytes(
    bits: u32,
    granularity: crate::quant::Granularity,
    cols: usize,
    packed_len: usize,
) -> usize {
    match granularity {
        crate::quant::Granularity::PerChannel { axis: 1 } => col_lut_bytes(bits, cols, packed_len),
        _ => 0,
    }
}

/// Resident footprint of a packed matrix: packed codes + f32 affine
/// parameters + the (possibly absent) per-column LUT per
/// [`col_lut_stored_bytes`]. Computable from index metadata alone, and
/// asserted (drift test) to equal what a constructed `PackedMatrix`
/// actually holds.
pub fn packed_resident_bytes(
    bits: u32,
    granularity: crate::quant::Granularity,
    cols: usize,
    packed_len: usize,
    n_scale: usize,
    n_zero: usize,
) -> usize {
    packed_len + 4 * (n_scale + n_zero) + col_lut_stored_bytes(bits, granularity, cols, packed_len)
}

// ---------------------------------------------------------------------------
// Blocked / batched quantized-domain kernels (qGEMM)
// ---------------------------------------------------------------------------
//
// The scalar qGEMV kernels above walk the packed stream once per token;
// a batch of B tokens routed to the same expert re-decodes the same
// codes B times. The kernels below decode each run of codes ONCE into a
// small stack buffer and apply it to every token of the batch, so one
// traversal of the packed stream serves the whole routed token group.
// With B == 1 they are the "blocked" qGEMV variants: same single
// traversal, but the decode and the FMA run in separate tight loops over
// a cache-line-sized buffer instead of interleaving per code.
//
// Accumulation contract: in [`Accumulation::Exact`] mode every output
// element sees exactly the contributions, values, and order the scalar
// kernels produce (rows ascending, zero activations skipped, dequantized
// weight first) — bit-exact, property-tested with f32 equality. In
// [`Accumulation::Relaxed`] mode rows are consumed in pairs and each
// pair's two contributions are summed before touching the accumulator
// (`out += x0*w0 + x1*w1`), which changes the association order; the
// results are tolerance-tested against the exact kernel, not bit-exact,
// in exchange for an extra independent FMA lane.

/// Codes decoded per run: 64 f32 = 256 B of decoded weights — a few
/// cache lines, comfortably inside L1 alongside the output rows.
pub const QGEMM_BLOCK: usize = 64;

/// Accumulation mode of the blocked/batched kernels. `Exact` (the
/// default) reproduces the scalar kernels bit for bit; `Relaxed` trades
/// bit-exactness for paired accumulator lanes and is only
/// tolerance-tested.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Accumulation {
    #[default]
    Exact,
    Relaxed,
}

/// Shared assertion set for the batched kernels. `x` is row-major
/// `[b, rows]` activations, `out` row-major `[b, cols]`.
#[inline(always)]
fn qgemm_checks(packed: &[u8], bits: u32, cols: usize, x: &[f32], b: usize, out: &[f32]) {
    assert!((1..=8).contains(&bits));
    assert!(b > 0, "qgemm batch must be non-empty");
    assert!(x.len() % b == 0, "qgemm activation batch not divisible: {} % {b}", x.len());
    assert_eq!(out.len(), b * cols, "qgemm output dim mismatch");
    let rows = x.len() / b;
    assert!(
        packed.len() * 8 >= rows * cols * bits as usize,
        "packed stream too short for [{rows}, {cols}] at {bits} bits"
    );
}

/// The one blocked/batched traversal, shared by every granularity:
/// `decode(i, j0, buf)` fills `buf` with the dequantized weights of row
/// `i`, columns `j0 .. j0 + buf.len()`. Rows whose activation is zero
/// for EVERY token are skipped without decoding (the batched analogue of
/// the scalar kernels' skip branch).
fn qgemm_core<F>(
    rows: usize,
    cols: usize,
    x: &[f32],
    b: usize,
    out: &mut [f32],
    mode: Accumulation,
    mut decode: F,
) where
    F: FnMut(usize, usize, &mut [f32]),
{
    out.fill(0.0);
    let mut buf0 = [0.0f32; QGEMM_BLOCK];
    let mut buf1 = [0.0f32; QGEMM_BLOCK];
    // exact-mode body, also the relaxed path's odd-tail row
    macro_rules! single_row {
        ($i:expr) => {{
            let i = $i;
            if (0..b).any(|t| x[t * rows + i] != 0.0) {
                let mut j = 0usize;
                while j < cols {
                    let blk = QGEMM_BLOCK.min(cols - j);
                    decode(i, j, &mut buf0[..blk]);
                    for t in 0..b {
                        let xi = x[t * rows + i];
                        if xi == 0.0 {
                            continue;
                        }
                        let o = &mut out[t * cols + j..t * cols + j + blk];
                        for (ov, &v) in o.iter_mut().zip(&buf0[..blk]) {
                            *ov += xi * v;
                        }
                    }
                    j += blk;
                }
            }
        }};
    }
    match mode {
        Accumulation::Exact => {
            for i in 0..rows {
                single_row!(i);
            }
        }
        Accumulation::Relaxed => {
            let mut i = 0usize;
            while i + 1 < rows {
                if (0..b).any(|t| x[t * rows + i] != 0.0 || x[t * rows + i + 1] != 0.0) {
                    let mut j = 0usize;
                    while j < cols {
                        let blk = QGEMM_BLOCK.min(cols - j);
                        decode(i, j, &mut buf0[..blk]);
                        decode(i + 1, j, &mut buf1[..blk]);
                        for t in 0..b {
                            let (x0, x1) = (x[t * rows + i], x[t * rows + i + 1]);
                            if x0 == 0.0 && x1 == 0.0 {
                                continue;
                            }
                            let o = &mut out[t * cols + j..t * cols + j + blk];
                            for (k, ov) in o.iter_mut().enumerate() {
                                // paired lanes: one rounding point fewer
                                // than two sequential adds — this is the
                                // relaxation
                                *ov += x0 * buf0[k] + x1 * buf1[k];
                            }
                        }
                        j += blk;
                    }
                }
                i += 2;
            }
            if i < rows {
                single_row!(i);
            }
        }
    }
}

/// Batched quantized-domain GEMM, per-tensor parameters: `Y = X · W` for
/// row-major `x: [b, rows]` activations against the packed `[rows, cols]`
/// codes, one traversal of the packed stream for the whole batch.
#[allow(clippy::too_many_arguments)]
pub fn qgemm(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: f32,
    zero: f32,
    x: &[f32],
    b: usize,
    out: &mut [f32],
    mode: Accumulation,
) {
    qgemm_checks(packed, bits, cols, x, b, out);
    let mask = width_mask(bits);
    let levels = 1usize << bits;
    let mut lut = [0.0f32; 256];
    for (c, v) in lut.iter_mut().take(levels).enumerate() {
        *v = (c as f32 - zero) * scale;
    }
    let rows = x.len() / b;
    let row_bits = cols * bits as usize;
    qgemm_core(rows, cols, x, b, out, mode, |i, j0, buf| {
        let mut bitpos = i * row_bits + j0 * bits as usize;
        for v in buf.iter_mut() {
            *v = lut[code_at(packed, bitpos, bits, mask) as usize];
            bitpos += bits as usize;
        }
    });
}

/// Batched GEMM with per-row (axis 0) parameters; the row's LUT is
/// rebuilt once per row and amortized over `b * cols` FMAs.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_rows(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    x: &[f32],
    b: usize,
    out: &mut [f32],
    mode: Accumulation,
) {
    qgemm_checks(packed, bits, cols, x, b, out);
    let rows = x.len() / b;
    assert_eq!(scale.len(), rows);
    assert_eq!(zero.len(), rows);
    let mask = width_mask(bits);
    let levels = 1usize << bits;
    let mut lut = [0.0f32; 256];
    let mut lut_row = usize::MAX;
    let row_bits = cols * bits as usize;
    qgemm_core(rows, cols, x, b, out, mode, |i, j0, buf| {
        if lut_row != i {
            let (s, z) = (scale[i], zero[i]);
            for (c, v) in lut.iter_mut().take(levels).enumerate() {
                *v = (c as f32 - z) * s;
            }
            lut_row = i;
        }
        let mut bitpos = i * row_bits + j0 * bits as usize;
        for v in buf.iter_mut() {
            *v = lut[code_at(packed, bitpos, bits, mask) as usize];
            bitpos += bits as usize;
        }
    });
}

/// Batched GEMM with per-out-channel (axis 1) parameters, inline dequant
/// (the no-stored-LUT form — see [`qgemm_cols_lut`]).
#[allow(clippy::too_many_arguments)]
pub fn qgemm_cols(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: &[f32],
    zero: &[f32],
    x: &[f32],
    b: usize,
    out: &mut [f32],
    mode: Accumulation,
) {
    qgemm_checks(packed, bits, cols, x, b, out);
    assert_eq!(scale.len(), cols);
    assert_eq!(zero.len(), cols);
    let mask = width_mask(bits);
    let rows = x.len() / b;
    let row_bits = cols * bits as usize;
    qgemm_core(rows, cols, x, b, out, mode, |i, j0, buf| {
        let mut bitpos = i * row_bits + j0 * bits as usize;
        for (k, v) in buf.iter_mut().enumerate() {
            let c = code_at(packed, bitpos, bits, mask);
            *v = (c as f32 - zero[j0 + k]) * scale[j0 + k];
            bitpos += bits as usize;
        }
    });
}

/// [`qgemm_cols`] against the precomputed per-column LUT from
/// [`build_col_lut`] — the packed-resident expert cache's form.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_cols_lut(
    packed: &[u8],
    bits: u32,
    cols: usize,
    lut: &[f32],
    x: &[f32],
    b: usize,
    out: &mut [f32],
    mode: Accumulation,
) {
    qgemm_checks(packed, bits, cols, x, b, out);
    let levels = 1usize << bits;
    assert_eq!(lut.len(), cols * levels, "column LUT size mismatch");
    let mask = width_mask(bits);
    let rows = x.len() / b;
    let row_bits = cols * bits as usize;
    qgemm_core(rows, cols, x, b, out, mode, |i, j0, buf| {
        let mut bitpos = i * row_bits + j0 * bits as usize;
        for (k, v) in buf.iter_mut().enumerate() {
            let c = code_at(packed, bitpos, bits, mask);
            *v = lut[(j0 + k) * levels + c as usize];
            bitpos += bits as usize;
        }
    });
}

/// Blocked single-token qGEMV, per-tensor parameters: [`qgemm`] at
/// batch 1 — decode a [`QGEMM_BLOCK`]-sized run once, then a tight FMA
/// loop over it. Bit-exact vs [`qgemv`] in `Exact` mode.
#[allow(clippy::too_many_arguments)]
pub fn qgemv_blocked(
    packed: &[u8],
    bits: u32,
    cols: usize,
    scale: f32,
    zero: f32,
    x: &[f32],
    out: &mut [f32],
    mode: Accumulation,
) {
    qgemm(packed, bits, cols, scale, zero, x, 1, out, mode);
}

/// Blocked single-token qGEMV against a precomputed per-column LUT:
/// [`qgemm_cols_lut`] at batch 1.
pub fn qgemv_cols_lut_blocked(
    packed: &[u8],
    bits: u32,
    cols: usize,
    lut: &[f32],
    x: &[f32],
    out: &mut [f32],
    mode: Accumulation,
) {
    qgemm_cols_lut(packed, bits, cols, lut, x, 1, out, mode);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = crate::util::Rng::seed_from_u64(0);
        for bits in 1..=8u32 {
            for n in [0usize, 1, 7, 8, 9, 255, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                assert_eq!(packed.len(), (n * bits as usize + 7) / 8);
                assert_eq!(unpack(&packed, bits, n), codes, "bits={bits} n={n}");
                let mut into = vec![0u8; n];
                unpack_into(&packed, bits, &mut into);
                assert_eq!(into, codes, "unpack_into bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn six_bit_straddles_bytes() {
        let codes = vec![0b111111u8, 0b000001, 0b101010, 0b010101];
        let packed = pack(&codes, 6);
        assert_eq!(packed.len(), 3); // 24 bits exactly
        assert_eq!(unpack(&packed, 6, 4), codes);
    }

    #[test]
    fn eight_bit_is_identity() {
        let codes: Vec<u8> = (0..=255).collect();
        assert_eq!(pack(&codes, 8), codes);
        assert_eq!(unpack(&codes, 8, 256), codes);
    }

    #[test]
    fn compression_factor() {
        let codes = vec![1u8; 800];
        assert_eq!(pack(&codes, 2).len(), 200);
        assert_eq!(pack(&codes, 4).len(), 400);
    }

    /// Reference two-step path the fused kernels must match bit-exactly.
    fn two_step(packed: &[u8], bits: u32, n: usize, sz: impl Fn(usize) -> (f32, f32)) -> Vec<f32> {
        unpack(packed, bits, n)
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let (s, z) = sz(i);
                (c as f32 - z) * s
            })
            .collect()
    }

    #[test]
    fn fused_matches_two_step_all_widths() {
        // property test: for widths 1..=8 and awkward lengths, the fused
        // kernel equals unpack-then-dequantize bit for bit (f32 equality,
        // not approximate)
        let mut rng = crate::util::Rng::seed_from_u64(3);
        for bits in 1..=8u32 {
            for n in [1usize, 7, 64, 255, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                let (scale, zero) = (0.0173f32, 5.0f32);
                let mut fused = vec![0.0f32; n];
                unpack_dequant_into(&packed, bits, scale, zero, &mut fused);
                let reference = two_step(&packed, bits, n, |_| (scale, zero));
                assert_eq!(fused, reference, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn fused_per_channel_matches_two_step() {
        let mut rng = crate::util::Rng::seed_from_u64(4);
        for bits in [2u32, 4, 6, 8] {
            let (rows, cols) = (24usize, 20usize);
            let n = rows * cols;
            let codes: Vec<u8> =
                (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
            let packed = pack(&codes, bits);
            let cs: Vec<f32> = (0..cols).map(|c| 0.001 + c as f32 * 0.01).collect();
            let cz: Vec<f32> = (0..cols).map(|c| (c % 5) as f32).collect();
            let mut fused = vec![0.0f32; n];
            unpack_dequant_cols_into(&packed, bits, cols, &cs, &cz, &mut fused);
            let reference = two_step(&packed, bits, n, |i| (cs[i % cols], cz[i % cols]));
            assert_eq!(fused, reference, "cols bits={bits}");

            let rs: Vec<f32> = (0..rows).map(|r| 0.002 + r as f32 * 0.02).collect();
            let rz: Vec<f32> = (0..rows).map(|r| (r % 3) as f32).collect();
            let mut fused_r = vec![0.0f32; n];
            unpack_dequant_rows_into(&packed, bits, cols, &rs, &rz, &mut fused_r);
            let reference_r = two_step(&packed, bits, n, |i| (rs[i / cols], rz[i / cols]));
            assert_eq!(fused_r, reference_r, "rows bits={bits}");
        }
    }

    /// Decoded-path reference the qGEMV kernels must match bit-exactly:
    /// unpack + dequantize to an f32 arena, then the expert FFN's matmul
    /// shape (rows ascending, zero activations skipped, `xi * w`).
    fn ref_gemv(
        packed: &[u8],
        bits: u32,
        rows: usize,
        cols: usize,
        sz: impl Fn(usize) -> (f32, f32),
        x: &[f32],
    ) -> Vec<f32> {
        let w = two_step(packed, bits, rows * cols, sz);
        let mut out = vec![0.0f32; cols];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &w[i * cols..(i + 1) * cols];
            for (o, &wij) in out.iter_mut().zip(row) {
                *o += xi * wij;
            }
        }
        out
    }

    /// An activation vector with sign changes and forced exact zeros (the
    /// decoded path's skip branch must be replicated, not approximated).
    fn test_x(rng: &mut crate::util::Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| if i % 5 == 3 { 0.0 } else { rng.normal_f32() })
            .collect()
    }

    #[test]
    fn qgemv_matches_unpack_then_matmul_all_widths() {
        // property test: widths 1..=8 (6-bit codes straddle bytes) and
        // ragged shapes, per-tensor granularity — exact f32 equality
        let mut rng = crate::util::Rng::seed_from_u64(7);
        for bits in 1..=8u32 {
            for (rows, cols) in [(1usize, 1usize), (3, 5), (7, 13), (16, 24), (33, 7)] {
                let n = rows * cols;
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                let x = test_x(&mut rng, rows);
                let (scale, zero) = (0.031f32, 3.0f32);
                let mut got = vec![1.0f32; cols]; // kernels must zero `out`
                qgemv(&packed, bits, cols, scale, zero, &x, &mut got);
                let want = ref_gemv(&packed, bits, rows, cols, |_| (scale, zero), &x);
                assert_eq!(got, want, "bits={bits} rows={rows} cols={cols}");
            }
        }
    }

    #[test]
    fn qgemv_per_channel_matches_unpack_then_matmul() {
        let mut rng = crate::util::Rng::seed_from_u64(8);
        for bits in 1..=8u32 {
            for (rows, cols) in [(5usize, 3usize), (24, 20), (13, 31)] {
                let n = rows * cols;
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                let x = test_x(&mut rng, rows);

                // per-row (axis 0) parameters
                let rs: Vec<f32> = (0..rows).map(|r| 0.002 + r as f32 * 0.013).collect();
                let rz: Vec<f32> = (0..rows).map(|r| (r % 4) as f32).collect();
                let mut got = vec![0.0f32; cols];
                qgemv_rows(&packed, bits, cols, &rs, &rz, &x, &mut got);
                let want = ref_gemv(&packed, bits, rows, cols, |i| (rs[i / cols], rz[i / cols]), &x);
                assert_eq!(got, want, "rows bits={bits} {rows}x{cols}");

                // per-col (axis 1) parameters: inline and LUT kernels
                let cs: Vec<f32> = (0..cols).map(|c| 0.004 + c as f32 * 0.009).collect();
                let cz: Vec<f32> = (0..cols).map(|c| (c % 6) as f32).collect();
                let mut inline = vec![0.0f32; cols];
                qgemv_cols(&packed, bits, cols, &cs, &cz, &x, &mut inline);
                let want_c =
                    ref_gemv(&packed, bits, rows, cols, |i| (cs[i % cols], cz[i % cols]), &x);
                assert_eq!(inline, want_c, "cols bits={bits} {rows}x{cols}");
                let lut = build_col_lut(bits, &cs, &cz);
                let mut via_lut = vec![0.0f32; cols];
                qgemv_cols_lut(&packed, bits, cols, &lut, &x, &mut via_lut);
                assert_eq!(via_lut, want_c, "cols-lut bits={bits} {rows}x{cols}");
            }
        }
    }

    #[test]
    fn qgemv_all_zero_activations_yield_zero() {
        let codes = vec![1u8; 6 * 4];
        let packed = pack(&codes, 6);
        let x = vec![0.0f32; 6];
        let mut out = vec![9.0f32; 4];
        qgemv(&packed, 6, 4, 0.5, 1.0, &x, &mut out);
        assert_eq!(out, vec![0.0f32; 4], "output must be zeroed even when every row skips");
    }

    #[test]
    fn col_lut_bytes_rule() {
        // stored only when the LUT is no larger than the packed codes:
        // 4096x64 @ 4-bit -> codes 131072 B, lut 64*16*4 = 4096 B: stored
        assert_eq!(col_lut_bytes(4, 64, 131072), 4096);
        // tiny matrix: lut 64*16*4 = 4096 B > 96 B of codes: skipped
        assert_eq!(col_lut_bytes(4, 64, 96), 0);
        // boundary: equal sizes are stored
        assert_eq!(col_lut_bytes(2, 8, 128), 128);
        assert_eq!(col_lut_bytes(2, 8, 127), 0);
    }

    #[test]
    fn qgemm_bit_exact_vs_per_token_qgemv_all_widths_granularities_batches() {
        // THE batched-kernel property test: for widths 1..=8, ragged
        // shapes (incl. cols beyond one QGEMM_BLOCK), every granularity
        // kernel, and batch sizes 1..=8, Exact-mode qgemm equals running
        // the scalar qgemv once per token — f32 equality, not
        // approximate. Batch 1 doubles as the blocked-qGEMV proof.
        let mut rng = crate::util::Rng::seed_from_u64(11);
        for bits in 1..=8u32 {
            for (rows, cols) in [(1usize, 1usize), (3, 5), (7, 13), (16, 24), (33, 7), (9, 150)] {
                let n = rows * cols;
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                let (scale, zero) = (0.027f32, 2.0f32);
                let rs: Vec<f32> = (0..rows).map(|r| 0.002 + r as f32 * 0.013).collect();
                let rz: Vec<f32> = (0..rows).map(|r| (r % 4) as f32).collect();
                let cs: Vec<f32> = (0..cols).map(|c| 0.004 + c as f32 * 0.009).collect();
                let cz: Vec<f32> = (0..cols).map(|c| (c % 6) as f32).collect();
                let lut = build_col_lut(bits, &cs, &cz);
                for b in 1..=8usize {
                    let xs: Vec<Vec<f32>> = (0..b).map(|_| test_x(&mut rng, rows)).collect();
                    let xf: Vec<f32> = xs.iter().flatten().copied().collect();
                    let mut want = vec![0.0f32; b * cols];
                    let mut got = vec![1.0f32; b * cols]; // kernels must zero

                    for (t, x) in xs.iter().enumerate() {
                        qgemv(&packed, bits, cols, scale, zero, x, &mut want[t * cols..(t + 1) * cols]);
                    }
                    qgemm(&packed, bits, cols, scale, zero, &xf, b, &mut got, Accumulation::Exact);
                    assert_eq!(got, want, "per-tensor bits={bits} {rows}x{cols} b={b}");

                    for (t, x) in xs.iter().enumerate() {
                        qgemv_rows(&packed, bits, cols, &rs, &rz, x, &mut want[t * cols..(t + 1) * cols]);
                    }
                    qgemm_rows(&packed, bits, cols, &rs, &rz, &xf, b, &mut got, Accumulation::Exact);
                    assert_eq!(got, want, "per-row bits={bits} {rows}x{cols} b={b}");

                    for (t, x) in xs.iter().enumerate() {
                        qgemv_cols(&packed, bits, cols, &cs, &cz, x, &mut want[t * cols..(t + 1) * cols]);
                    }
                    qgemm_cols(&packed, bits, cols, &cs, &cz, &xf, b, &mut got, Accumulation::Exact);
                    assert_eq!(got, want, "per-col bits={bits} {rows}x{cols} b={b}");

                    for (t, x) in xs.iter().enumerate() {
                        qgemv_cols_lut(&packed, bits, cols, &lut, x, &mut want[t * cols..(t + 1) * cols]);
                    }
                    qgemm_cols_lut(&packed, bits, cols, &lut, &xf, b, &mut got, Accumulation::Exact);
                    assert_eq!(got, want, "per-col-lut bits={bits} {rows}x{cols} b={b}");
                }
            }
        }
    }

    #[test]
    fn blocked_qgemv_wrappers_bit_exact_across_block_boundaries() {
        // cols straddling QGEMM_BLOCK: one short block, exactly one
        // block, one-past, and multi-block shapes
        let mut rng = crate::util::Rng::seed_from_u64(12);
        for bits in [1u32, 3, 6, 8] {
            for cols in [QGEMM_BLOCK - 1, QGEMM_BLOCK, QGEMM_BLOCK + 1, 3 * QGEMM_BLOCK + 7] {
                let rows = 17usize;
                let codes: Vec<u8> = (0..rows * cols)
                    .map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8)
                    .collect();
                let packed = pack(&codes, bits);
                let x = test_x(&mut rng, rows);
                let (scale, zero) = (0.021f32, 1.0f32);
                let mut want = vec![0.0f32; cols];
                qgemv(&packed, bits, cols, scale, zero, &x, &mut want);
                let mut got = vec![5.0f32; cols];
                qgemv_blocked(&packed, bits, cols, scale, zero, &x, &mut got, Accumulation::Exact);
                assert_eq!(got, want, "blocked bits={bits} cols={cols}");

                let cs: Vec<f32> = (0..cols).map(|c| 0.003 + c as f32 * 0.001).collect();
                let cz: Vec<f32> = (0..cols).map(|c| (c % 3) as f32).collect();
                let lut = build_col_lut(bits, &cs, &cz);
                qgemv_cols_lut(&packed, bits, cols, &lut, &x, &mut want);
                qgemv_cols_lut_blocked(&packed, bits, cols, &lut, &x, &mut got, Accumulation::Exact);
                assert_eq!(got, want, "blocked-lut bits={bits} cols={cols}");
            }
        }
    }

    #[test]
    fn relaxed_accumulation_is_close_but_only_tolerance_tested() {
        // Relaxed mode pairs rows into two accumulator lanes — a
        // different association order, so the contract is closeness (and
        // only closeness) to the exact kernel.
        let mut rng = crate::util::Rng::seed_from_u64(13);
        for bits in 1..=8u32 {
            for (rows, cols) in [(1usize, 9usize), (2, 70), (47, 129), (64, 64)] {
                let n = rows * cols;
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.gen_range(0, (1u16 << bits) as u64) as u8).collect();
                let packed = pack(&codes, bits);
                let (scale, zero) = (0.0137f32, (1u32 << (bits - 1)) as f32);
                for b in [1usize, 3, 8] {
                    let xf: Vec<f32> = (0..b).flat_map(|_| test_x(&mut rng, rows)).collect();
                    let mut exact = vec![0.0f32; b * cols];
                    let mut relaxed = vec![0.0f32; b * cols];
                    qgemm(&packed, bits, cols, scale, zero, &xf, b, &mut exact, Accumulation::Exact);
                    qgemm(&packed, bits, cols, scale, zero, &xf, b, &mut relaxed, Accumulation::Relaxed);
                    for (k, (&e, &r)) in exact.iter().zip(&relaxed).enumerate() {
                        let tol = 1e-3f32 * (1.0 + e.abs());
                        assert!(
                            (e - r).abs() <= tol,
                            "bits={bits} {rows}x{cols} b={b} elem {k}: exact {e} relaxed {r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lut_profitability_rule_is_shared_across_widths_and_granularities() {
        // drift test, widths 1..=8 x all granularities: only axis-1
        // stores a LUT, and exactly when col_lut_bytes says it pays;
        // resident bytes = codes + params + that LUT, byte for byte
        use crate::quant::Granularity;
        for bits in 1..=8u32 {
            for cols in [4usize, 64, 512] {
                for packed_len in [16usize, 4096, 1 << 20] {
                    let lut = col_lut_bytes(bits, cols, packed_len);
                    for g in [
                        Granularity::PerTensor,
                        Granularity::PerChannel { axis: 0 },
                        Granularity::PerChannel { axis: 1 },
                    ] {
                        let stored = col_lut_stored_bytes(bits, g, cols, packed_len);
                        match g {
                            Granularity::PerChannel { axis: 1 } => assert_eq!(stored, lut),
                            _ => assert_eq!(stored, 0, "only axis-1 ever stores a LUT"),
                        }
                        let (ns, nz) = match g {
                            Granularity::PerTensor => (1, 1),
                            _ => (cols, cols),
                        };
                        assert_eq!(
                            packed_resident_bytes(bits, g, cols, packed_len, ns, nz),
                            packed_len + 4 * (ns + nz) + stored,
                            "bits={bits} cols={cols} packed={packed_len} {g:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_matches_quantized_tensor_dequantize() {
        // end-to-end against the canonical QuantizedTensor::dequantize
        use crate::quant::{uniform, Bits, Granularity};
        use crate::tensor::Tensor;
        let mut rng = crate::util::Rng::seed_from_u64(5);
        let t = Tensor::new(vec![16, 12], (0..192).map(|_| rng.normal_f32()).collect()).unwrap();
        for bits in [Bits::Ternary, Bits::B2, Bits::B4, Bits::B6, Bits::B8] {
            let q = uniform::quantize(&t, bits, Granularity::PerTensor).unwrap();
            let packed = pack(&q.codes.data, bits.storage_bits());
            let mut fused = vec![0.0f32; q.codes.data.len()];
            unpack_dequant_into(&packed, bits.storage_bits(), q.scale[0], q.zero[0], &mut fused);
            assert_eq!(fused, q.dequantize().data, "{bits:?}");
        }
    }
}
