//! The paper's "naive" quantizer (§3, Listing 1): asymmetric uniform
//! min/max mapping, plus the ternary threshold variant it compares against.
//!
//! Listing-1 semantics, faithfully:
//!   scale = (xmax - xmin) / maxq
//!   zero  = round(-xmin / scale)
//!   q     = clamp(round(x / scale) + zero, 0, maxq)
//!   deq   = (q - zero) * scale
//! with min/max clamped through 0 so the zero point is representable.

use anyhow::{bail, Result};

use super::{Bits, Granularity, QuantizedTensor};
use crate::tensor::{Tensor, U8Tensor};

/// Scale/zero from a value range (the paper's `find_params`).
fn params_from_range(mut xmin: f32, mut xmax: f32, maxq: u32) -> (f32, f32) {
    xmin = xmin.min(0.0);
    xmax = xmax.max(0.0);
    let mut scale = (xmax - xmin) / maxq as f32;
    if scale <= 1e-12 {
        scale = 1.0;
    }
    let zero = (-xmin / scale).round();
    (scale, zero)
}

fn quantize_slice(out: &mut [u8], xs: &[f32], scale: f32, zero: f32, maxq: u32) {
    let maxq_f = maxq as f32;
    for (o, &x) in out.iter_mut().zip(xs) {
        let q = (x / scale).round() + zero;
        *o = q.clamp(0.0, maxq_f) as u8;
    }
}

/// Quantize a tensor with the paper's naive scheme.
///
/// For 2-D tensors any granularity is allowed; 1-D tensors only support
/// `PerTensor`. `Ternary` uses the same uniform machinery with maxq = 2,
/// which reproduces QMoE's {min, 0, max} three-level grid (the zero point
/// lands on a code because min/max are clamped through 0).
pub fn quantize(t: &Tensor, bits: Bits, gran: Granularity) -> Result<QuantizedTensor> {
    let maxq = bits.maxq();
    let mut codes = vec![0u8; t.data.len()];
    let (scale, zero): (Vec<f32>, Vec<f32>) = match gran {
        Granularity::PerTensor => {
            let xmin = t.data.iter().copied().fold(f32::INFINITY, f32::min);
            let xmax = t.data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let (s, z) = params_from_range(xmin, xmax, maxq);
            quantize_slice(&mut codes, &t.data, s, z, maxq);
            (vec![s], vec![z])
        }
        Granularity::PerChannel { axis } => {
            let (rows, cols) = t.dims2()?;
            match axis {
                0 => {
                    let mut ss = Vec::with_capacity(rows);
                    let mut zs = Vec::with_capacity(rows);
                    for r in 0..rows {
                        let row = &t.data[r * cols..(r + 1) * cols];
                        let xmin = row.iter().copied().fold(f32::INFINITY, f32::min);
                        let xmax = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                        let (s, z) = params_from_range(xmin, xmax, maxq);
                        quantize_slice(&mut codes[r * cols..(r + 1) * cols], row, s, z, maxq);
                        ss.push(s);
                        zs.push(z);
                    }
                    (ss, zs)
                }
                1 => {
                    let mut xmin = vec![f32::INFINITY; cols];
                    let mut xmax = vec![f32::NEG_INFINITY; cols];
                    for r in 0..rows {
                        for c in 0..cols {
                            let v = t.data[r * cols + c];
                            xmin[c] = xmin[c].min(v);
                            xmax[c] = xmax[c].max(v);
                        }
                    }
                    let mut ss = Vec::with_capacity(cols);
                    let mut zs = Vec::with_capacity(cols);
                    for c in 0..cols {
                        let (s, z) = params_from_range(xmin[c], xmax[c], maxq);
                        ss.push(s);
                        zs.push(z);
                    }
                    let maxq_f = maxq as f32;
                    for r in 0..rows {
                        for c in 0..cols {
                            let q = (t.data[r * cols + c] / ss[c]).round() + zs[c];
                            codes[r * cols + c] = q.clamp(0.0, maxq_f) as u8;
                        }
                    }
                    (ss, zs)
                }
                a => bail!("bad channel axis {a}"),
            }
        }
    };
    Ok(QuantizedTensor {
        codes: U8Tensor { shape: t.shape.clone(), data: codes },
        scale,
        zero,
        bits,
        granularity: gran,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    
    fn random_tensor(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-1.5 as f64, 1.5 as f64) as f32).collect();
        Tensor::new(vec![rows, cols], data).unwrap()
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let t = random_tensor(64, 32, 0);
        for gran in [
            Granularity::PerTensor,
            Granularity::PerChannel { axis: 0 },
            Granularity::PerChannel { axis: 1 },
        ] {
            let q = quantize(&t, Bits::B8, gran).unwrap();
            let deq = q.dequantize();
            let (rows, cols) = t.dims2().unwrap();
            for r in 0..rows {
                for c in 0..cols {
                    let s = match gran {
                        Granularity::PerTensor => q.scale[0],
                        Granularity::PerChannel { axis: 0 } => q.scale[r],
                        _ => q.scale[c],
                    };
                    let err = (t.data[r * cols + c] - deq.data[r * cols + c]).abs();
                    assert!(err <= s * 0.5 + 1e-6, "err {err} > s/2 {}", s * 0.5);
                }
            }
        }
    }

    #[test]
    fn per_channel_beats_per_tensor_mse() {
        // rows with very different magnitude ranges
        let mut t = random_tensor(32, 16, 1);
        for c in 0..16 {
            t.data[c] *= 100.0; // first row much larger
        }
        let qt = quantize(&t, Bits::B8, Granularity::PerTensor).unwrap();
        let qc = quantize(&t, Bits::B8, Granularity::PerChannel { axis: 0 }).unwrap();
        assert!(t.mse(&qc.dequantize()) < t.mse(&qt.dequantize()));
    }

    #[test]
    fn more_bits_less_error() {
        let t = random_tensor(64, 64, 2);
        let mut prev = f64::INFINITY;
        for bits in [Bits::B2, Bits::B4, Bits::B6, Bits::B8] {
            let q = quantize(&t, bits, Granularity::PerTensor).unwrap();
            let mse = t.mse(&q.dequantize());
            assert!(mse < prev, "{bits:?}: {mse} !< {prev}");
            prev = mse;
        }
    }

    #[test]
    fn ternary_three_levels() {
        let t = random_tensor(16, 16, 3);
        let q = quantize(&t, Bits::Ternary, Granularity::PerTensor).unwrap();
        assert!(q.codes.data.iter().all(|&c| c <= 2));
        let deq = q.dequantize();
        let mut uniq: Vec<i64> = deq.data.iter().map(|v| (v * 1e6) as i64).collect();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() <= 3);
    }

    #[test]
    fn ternary_high_sparsity_on_normal_weights() {
        // the QMoE §2.5 claim: ternary on ~normal weights is mostly zeros
        let t = {
            let mut rng = crate::util::Rng::seed_from_u64(7);
            let data: Vec<f32> = (0..10_000).map(|_| rng.normal_f32()).collect();
            Tensor::new(vec![100, 100], data).unwrap()
        };
        let q = quantize(&t, Bits::Ternary, Granularity::PerTensor).unwrap();
        let deq = q.dequantize();
        let zeros = deq.data.iter().filter(|v| v.abs() < 1e-6).count();
        assert!(
            zeros as f64 / deq.data.len() as f64 > 0.8,
            "ternary sparsity only {}",
            zeros as f64 / deq.data.len() as f64
        );
    }

    #[test]
    fn constant_tensor_is_exact() {
        let t = Tensor::new(vec![4, 4], vec![0.0; 16]).unwrap();
        let q = quantize(&t, Bits::B8, Granularity::PerTensor).unwrap();
        assert_eq!(q.dequantize().data, t.data);
    }

    #[test]
    fn zero_always_representable() {
        // a strictly positive tensor still encodes 0 exactly (clamped range)
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let q = quantize(&t, Bits::B8, Granularity::PerTensor).unwrap();
        let z = q.zero[0];
        let s = q.scale[0];
        assert!(((0.0f32 / s).round() + z) >= 0.0);
        assert_eq!(z, 0.0); // xmin clamped to 0 => zero code 0
    }

    #[test]
    fn matches_python_mirror_semantics() {
        // fixed vector with known quantization, cross-checked against
        // python/compile/model.py::quantize_tensor by hand
        let t = Tensor::new(vec![1, 4], vec![-1.0, 0.0, 0.5, 1.0]).unwrap();
        let q = quantize(&t, Bits::B8, Granularity::PerTensor).unwrap();
        // range [-1, 1], scale = f32(2/255); -xmin/scale = 127.499985 -> 127.
        // Verified against python/compile/model.py::quantize_tensor, which
        // yields scale 0.00784314, zero 127, codes [0, 127, 191, 254].
        assert!((q.scale[0] - 2.0 / 255.0).abs() < 1e-7);
        assert_eq!(q.zero[0], 127.0);
        assert_eq!(q.codes.data, vec![0, 127, 191, 254]);
    }
}
