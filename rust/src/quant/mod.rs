//! Quantization substrate (S2/S3): the paper's §3.
//!
//! * [`uniform`] — the paper's Listing-1 "naive" asymmetric min/max
//!   quantizer, at ternary/2/4/6/8 bits, per-tensor or per-channel.
//! * [`gptq`] — the data-dependent upgrade the paper applies on top
//!   (Hessian-damped, Cholesky-based error propagation).
//! * [`packing`] — bit-packing for sub-8-bit codes (storage ablation).
//! * [`stats`] — quantization-error metrics feeding the §3 ablation bench.
//!
//! Semantics contract: `dequant(x) = (codes - zero) * scale`, `zero` a
//! rounded code offset — identical to `python/compile/model.py::
//! quantize_tensor`, which the cross-language test fixture checks.

pub mod gptq;
pub mod packing;
pub mod stats;
pub mod uniform;

use crate::tensor::{Tensor, U8Tensor};

/// Quantization bit-width. `Ternary` mirrors the paper's QMoE baseline
/// (three levels: min, 0, max).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Bits {
    Ternary,
    B2,
    B4,
    B6,
    B8,
}

impl Bits {
    /// Maximum code value (`2^bits - 1`); ternary uses codes {0, 1, 2}.
    pub fn maxq(self) -> u32 {
        match self {
            Bits::Ternary => 2,
            Bits::B2 => 3,
            Bits::B4 => 15,
            Bits::B6 => 63,
            Bits::B8 => 255,
        }
    }

    /// Storage bits per weight after packing.
    pub fn storage_bits(self) -> u32 {
        match self {
            Bits::Ternary => 2,
            Bits::B2 => 2,
            Bits::B4 => 4,
            Bits::B6 => 6,
            Bits::B8 => 8,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Bits::Ternary => "ternary",
            Bits::B2 => "2-bit",
            Bits::B4 => "4-bit",
            Bits::B6 => "6-bit",
            Bits::B8 => "8-bit",
        }
    }

    pub const ALL: [Bits; 5] = [Bits::Ternary, Bits::B2, Bits::B4, Bits::B6, Bits::B8];
}

/// Channel granularity for scale/zero parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One (scale, zero) for the whole tensor — the paper's Listing 1.
    PerTensor,
    /// One (scale, zero) per channel along `axis` (0 = rows, 1 = cols).
    PerChannel { axis: usize },
}

/// A quantized tensor: u8 codes (one byte per weight, regardless of bit
/// width — packing is a storage-layer concern) plus affine parameters.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub codes: U8Tensor,
    /// Per-channel scale; length 1 for per-tensor granularity.
    pub scale: Vec<f32>,
    /// Per-channel zero point (rounded, in code units).
    pub zero: Vec<f32>,
    pub bits: Bits,
    pub granularity: Granularity,
}

impl QuantizedTensor {
    /// Expand channel parameters to per-element factors and dequantize.
    pub fn dequantize(&self) -> Tensor {
        let shape = self.codes.shape.clone();
        let n = self.codes.data.len();
        let mut data = vec![0.0f32; n];
        match self.granularity {
            Granularity::PerTensor => {
                let (s, z) = (self.scale[0], self.zero[0]);
                for (o, &c) in data.iter_mut().zip(&self.codes.data) {
                    *o = (c as f32 - z) * s;
                }
            }
            Granularity::PerChannel { axis } => {
                let (rows, cols) = (shape[0], shape[1]);
                match axis {
                    0 => {
                        for r in 0..rows {
                            let (s, z) = (self.scale[r], self.zero[r]);
                            for c in 0..cols {
                                data[r * cols + c] = (self.codes.data[r * cols + c] as f32 - z) * s;
                            }
                        }
                    }
                    1 => {
                        for r in 0..rows {
                            for c in 0..cols {
                                data[r * cols + c] =
                                    (self.codes.data[r * cols + c] as f32 - self.zero[c])
                                        * self.scale[c];
                            }
                        }
                    }
                    a => panic!("bad channel axis {a}"),
                }
            }
        }
        Tensor { shape, data }
    }

    /// Per-output-channel scale/zero vectors of length `channels`, expanded
    /// from per-tensor granularity when needed — the form the stage HLOs
    /// take as arguments.
    pub fn channel_params(&self, channels: usize) -> (Vec<f32>, Vec<f32>) {
        match self.granularity {
            Granularity::PerTensor => (
                vec![self.scale[0]; channels],
                vec![self.zero[0]; channels],
            ),
            Granularity::PerChannel { .. } => {
                assert_eq!(self.scale.len(), channels);
                (self.scale.clone(), self.zero.clone())
            }
        }
    }

    /// Bytes when stored naively (1 byte/code + f32 params).
    pub fn unpacked_bytes(&self) -> usize {
        self.codes.data.len() + 4 * (self.scale.len() + self.zero.len())
    }

    /// Bytes when bit-packed at the native width.
    pub fn packed_bytes(&self) -> usize {
        let bits = self.bits.storage_bits() as usize;
        (self.codes.data.len() * bits + 7) / 8 + 4 * (self.scale.len() + self.zero.len())
    }
}
