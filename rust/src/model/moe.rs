//! Mixture-of-Experts FFN sublayer (the "QMoE" in Tiny-QMoE): a learned
//! top-k router in front of `n_experts` SwiGLU experts, with every expert
//! quantized and compressed as its **own** set of TQM records so the
//! serving side can decode exactly the experts a token routes to.
//!
//! Selection is config-driven: a [`crate::config::ModelConfig`] whose
//! `moe` field is `Some(spec)` uses this sublayer in place of the dense
//! FFN. The host-side forward here is the reference implementation the
//! expert-cache integration tests and the MoE eval scenario run against.
//! An expert's weights live behind [`ExpertBody`]: `Decoded` holds plain
//! f32 arenas, `Packed` holds the container's bit-packed codes and runs
//! the SwiGLU through the quantized-domain qGEMV kernels
//! ([`crate::quant::packing::qgemv`]) — bit-exact against the decoded
//! math, identical regardless of whether the weights came from a cache
//! hit, a streamed miss, or a fully resident decode, which is what makes
//! the bit-exactness invariant testable.
//!
//! Container contract (canonical names live in [`crate::format`]):
//!   layers.{l}.router           f32 [d_model, n_experts]
//!   layers.{l}.experts.{e}.w1   quant [d_model, d_expert]
//!   layers.{l}.experts.{e}.w3   quant [d_model, d_expert]
//!   layers.{l}.experts.{e}.w2   quant [d_expert, d_model]

use anyhow::{Context, Result};

use crate::compress::CodecId;
use crate::config::{ExpertResidency, ModelConfig, MoeSpec, QuantizeOptions};
use crate::format::{
    expert_record_name, router_record_name, TensorRecord, TqmMeta, TqmReader, TqmWriter,
};
use crate::model::Checkpoint;
use crate::quant::{packing, uniform, Granularity};
use crate::tensor::Tensor;

/// Expert matrix names, container walk order (mirrors the dense FFN's
/// w1/w3/w2 slice of `MATRIX_NAMES`).
pub const EXPERT_MATRIX_NAMES: [&str; 3] = ["w1", "w3", "w2"];

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

/// A layer's routing matrix plus the top-k gating math.
#[derive(Clone, Debug)]
pub struct Router {
    pub layer: usize,
    /// `[d_model, n_experts]` f32.
    pub w: Tensor,
}

impl Router {
    pub fn load(reader: &TqmReader, layer: usize) -> Result<Self> {
        let w = reader
            .load_f32(&router_record_name(layer))
            .with_context(|| format!("loading router of layer {layer}"))?;
        anyhow::ensure!(w.shape.len() == 2, "router of layer {layer} must be 2-D");
        Ok(Self { layer, w })
    }

    pub fn n_experts(&self) -> usize {
        self.w.shape[1]
    }

    /// Raw routing logits `x @ W` for one token vector.
    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        let (d, e) = (self.w.shape[0], self.w.shape[1]);
        assert_eq!(x.len(), d, "router input dim mismatch");
        let mut out = vec![0.0f32; e];
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.w.data[i * e..(i + 1) * e];
            for (o, &wij) in out.iter_mut().zip(row) {
                *o += xi * wij;
            }
        }
        out
    }

    /// Full softmax gating distribution over the experts — the prefetch
    /// scorer wants probability mass per expert, not just the top-k set.
    pub fn gating_probs(&self, x: &[f32]) -> Vec<f32> {
        let logits = self.logits(x);
        let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut p: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
        let total: f32 = p.iter().sum();
        if total > 0.0 {
            for v in &mut p {
                *v /= total;
            }
        }
        p
    }

    /// Top-k expert picks with renormalized softmax gates, deterministic
    /// under ties (lower expert index wins).
    pub fn top_k(&self, x: &[f32], k: usize) -> Vec<(usize, f32)> {
        let logits = self.logits(x);
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_unstable_by(|&a, &b| {
            logits[b]
                .partial_cmp(&logits[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(k.clamp(1, logits.len()));
        let m = logits[idx[0]];
        let weights: Vec<f32> = idx.iter().map(|&i| (logits[i] - m).exp()).collect();
        let total: f32 = weights.iter().sum();
        idx.into_iter().zip(weights).map(|(i, w)| (i, w / total)).collect()
    }
}

// ---------------------------------------------------------------------------
// Expert weights + SwiGLU forward
// ---------------------------------------------------------------------------

/// One expert matrix kept in its container (bit-packed) form: the raw
/// little-endian code stream plus quantization parameters, consumed
/// directly by the qGEMV kernels — never expanded to f32. This is what a
/// packed-resident cache slot holds; a 4-bit matrix costs ~1/8 of its
/// decoded footprint, which is the whole point.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Storage bit width of the packed codes (1..=8).
    pub bits: u32,
    pub granularity: Granularity,
    /// Little-endian bit-packed codes, `rows * cols` of them.
    pub codes: Vec<u8>,
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
    /// Per-column dequant LUT (`cols * 2^bits` entries) for axis-1
    /// granularity, built once here and reused every token — stored only
    /// when no larger than the code stream
    /// ([`packing::col_lut_bytes`]); empty otherwise.
    pub col_lut: Vec<f32>,
}

impl PackedMatrix {
    pub fn new(
        rows: usize,
        cols: usize,
        bits: u32,
        granularity: Granularity,
        codes: Vec<u8>,
        scale: Vec<f32>,
        zero: Vec<f32>,
    ) -> Self {
        // one shared profitability rule with the TqmReader index and the
        // cache's size-before-decode admission — see
        // `packing::col_lut_stored_bytes`'s drift test
        let col_lut = if packing::col_lut_stored_bytes(bits, granularity, cols, codes.len()) > 0 {
            packing::build_col_lut(bits, &scale, &zero)
        } else {
            Vec::new()
        };
        Self { rows, cols, bits, granularity, codes, scale, zero, col_lut }
    }

    /// Build from a container record plus its decompressed (still
    /// bit-packed) code stream — the single place record metadata
    /// becomes packed-matrix form.
    pub fn from_record(r: &TensorRecord, codes: Vec<u8>) -> Self {
        Self::new(
            r.shape[0],
            r.shape[1],
            r.bits.storage_bits(),
            r.granularity,
            codes,
            r.scale.clone(),
            r.zero.clone(),
        )
    }

    /// Resident footprint: packed codes + quant params + stored LUT.
    /// Matches [`crate::format::ExpertEntry::packed_resident_bytes`]'s
    /// per-record formula, which the cache accounting relies on.
    pub fn resident_bytes(&self) -> usize {
        self.codes.len() + 4 * (self.scale.len() + self.zero.len() + self.col_lut.len())
    }

    /// `out = x · W` straight from the packed codes, bit-exact in value
    /// and accumulation order against dequantizing to f32 and running
    /// the decoded matmul.
    pub fn gemv_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.rows, "packed gemv input dim mismatch");
        match self.granularity {
            Granularity::PerTensor => packing::qgemv(
                &self.codes,
                self.bits,
                self.cols,
                self.scale[0],
                self.zero[0],
                x,
                out,
            ),
            Granularity::PerChannel { axis: 0 } => packing::qgemv_rows(
                &self.codes,
                self.bits,
                self.cols,
                &self.scale,
                &self.zero,
                x,
                out,
            ),
            Granularity::PerChannel { axis: 1 } if self.col_lut.is_empty() => packing::qgemv_cols(
                &self.codes,
                self.bits,
                self.cols,
                &self.scale,
                &self.zero,
                x,
                out,
            ),
            Granularity::PerChannel { axis: 1 } => packing::qgemv_cols_lut(
                &self.codes,
                self.bits,
                self.cols,
                &self.col_lut,
                x,
                out,
            ),
            Granularity::PerChannel { axis } => panic!("bad channel axis {axis}"),
        }
    }

    /// Batched `Y = X · W` straight from the packed codes: `x` is
    /// row-major `[b, rows]`, `out` row-major `[b, cols]`, and the
    /// packed stream is traversed ONCE for the whole batch. In
    /// [`packing::Accumulation::Exact`] mode each token's output is
    /// bit-exact against [`PackedMatrix::gemv_into`] on that token.
    pub fn gemm_into(&self, x: &[f32], b: usize, out: &mut [f32], mode: packing::Accumulation) {
        assert_eq!(x.len(), b * self.rows, "packed gemm input dim mismatch");
        match self.granularity {
            Granularity::PerTensor => packing::qgemm(
                &self.codes,
                self.bits,
                self.cols,
                self.scale[0],
                self.zero[0],
                x,
                b,
                out,
                mode,
            ),
            Granularity::PerChannel { axis: 0 } => packing::qgemm_rows(
                &self.codes,
                self.bits,
                self.cols,
                &self.scale,
                &self.zero,
                x,
                b,
                out,
                mode,
            ),
            Granularity::PerChannel { axis: 1 } if self.col_lut.is_empty() => packing::qgemm_cols(
                &self.codes,
                self.bits,
                self.cols,
                &self.scale,
                &self.zero,
                x,
                b,
                out,
                mode,
            ),
            Granularity::PerChannel { axis: 1 } => packing::qgemm_cols_lut(
                &self.codes,
                self.bits,
                self.cols,
                &self.col_lut,
                x,
                b,
                out,
                mode,
            ),
            Granularity::PerChannel { axis } => panic!("bad channel axis {axis}"),
        }
    }
}

/// The three packed matrices of one expert (boxed behind
/// [`ExpertBody::Packed`] so the enum's variants stay similar in size).
#[derive(Clone, Debug)]
pub struct PackedExpert {
    /// `[d_model, d_expert]`.
    pub w1: PackedMatrix,
    /// `[d_model, d_expert]`.
    pub w3: PackedMatrix,
    /// `[d_expert, d_model]`.
    pub w2: PackedMatrix,
}

/// How an expert's three matrices are held in memory — the residency
/// seam behind [`ExpertWeights::ffn`]. Both bodies run the identical
/// SwiGLU math (the qGEMV kernels are bit-exact against the decoded
/// matmul), so callers never observe which one they got.
#[derive(Clone, Debug)]
pub enum ExpertBody {
    /// Dequantized f32 arenas — the classic form.
    Decoded {
        /// `[d_model, d_expert]` row-major.
        w1: Vec<f32>,
        /// `[d_model, d_expert]` row-major.
        w3: Vec<f32>,
        /// `[d_expert, d_model]` row-major.
        w2: Vec<f32>,
    },
    /// Container-form bit-packed codes, computed against directly.
    Packed(Box<PackedExpert>),
}

/// One expert's weights — the unit the expert cache holds, sizes, and
/// evicts — in either decoded (f32) or packed (quantized-domain) form.
#[derive(Clone, Debug)]
pub struct ExpertWeights {
    pub layer: usize,
    pub expert: usize,
    pub d_model: usize,
    pub d_expert: usize,
    pub body: ExpertBody,
}

impl ExpertWeights {
    /// Assemble a decoded expert from f32 arenas.
    pub fn decoded(
        layer: usize,
        expert: usize,
        d_model: usize,
        d_expert: usize,
        w1: Vec<f32>,
        w3: Vec<f32>,
        w2: Vec<f32>,
    ) -> Self {
        Self { layer, expert, d_model, d_expert, body: ExpertBody::Decoded { w1, w3, w2 } }
    }

    /// Assemble a packed expert from container-form matrices.
    pub fn packed(
        layer: usize,
        expert: usize,
        d_model: usize,
        d_expert: usize,
        w1: PackedMatrix,
        w3: PackedMatrix,
        w2: PackedMatrix,
    ) -> Self {
        Self {
            layer,
            expert,
            d_model,
            d_expert,
            body: ExpertBody::Packed(Box::new(PackedExpert { w1, w3, w2 })),
        }
    }

    /// Decode one expert from the container into fresh buffers via the
    /// fused decompress→dequantize kernel (the same kernel the expert
    /// cache uses, so cached and uncached decodes are bit-identical).
    pub fn load(reader: &TqmReader, layer: usize, expert: usize) -> Result<Self> {
        let mut scratch = Vec::new();
        let mut bufs = [Vec::new(), Vec::new(), Vec::new()];
        for (mat, out) in EXPERT_MATRIX_NAMES.iter().zip(bufs.iter_mut()) {
            reader
                .load_dequantized_into(&expert_record_name(layer, expert, mat), &mut scratch, out)
                .with_context(|| format!("decoding expert ({layer}, {expert}) {mat}"))?;
        }
        let [w1, w3, w2] = bufs;
        let r1 = reader.record(&expert_record_name(layer, expert, "w1"))?;
        let (d_model, d_expert) = (r1.shape[0], r1.shape[1]);
        let out = Self::decoded(layer, expert, d_model, d_expert, w1, w3, w2);
        out.validate()?;
        Ok(out)
    }

    /// Load one expert in container (bit-packed) form: the payloads are
    /// decompressed but the codes stay packed; quantization parameters
    /// ride along and the per-column dequant LUTs are built here, once.
    /// No f32 weight arena is ever allocated.
    pub fn load_packed(reader: &TqmReader, layer: usize, expert: usize) -> Result<Self> {
        let mut bufs: [Vec<u8>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for (mat, out) in EXPERT_MATRIX_NAMES.iter().zip(bufs.iter_mut()) {
            reader
                .load_packed_into(&expert_record_name(layer, expert, mat), out)
                .with_context(|| format!("packed-decoding expert ({layer}, {expert}) {mat}"))?;
        }
        Self::assemble_packed(reader, layer, expert, bufs)
    }

    /// Assemble a packed expert from the three matrices' decompressed
    /// (still bit-packed) code streams, container walk order (w1, w3,
    /// w2). Shared by [`ExpertWeights::load_packed`] and the expert
    /// cache's pooled-arena miss path, so record metadata turns into
    /// [`PackedMatrix`] form in exactly one place.
    pub fn assemble_packed(
        reader: &TqmReader,
        layer: usize,
        expert: usize,
        codes: [Vec<u8>; 3],
    ) -> Result<Self> {
        let mut mats = Vec::with_capacity(EXPERT_MATRIX_NAMES.len());
        for (mat, c) in EXPERT_MATRIX_NAMES.iter().zip(codes) {
            let r = reader.record(&expert_record_name(layer, expert, mat))?;
            mats.push(PackedMatrix::from_record(r, c));
        }
        let m2 = mats.pop().expect("three expert matrices");
        let m3 = mats.pop().expect("three expert matrices");
        let m1 = mats.pop().expect("three expert matrices");
        let (d_model, d_expert) = (m1.rows, m1.cols);
        let out = Self::packed(layer, expert, d_model, d_expert, m1, m3, m2);
        out.validate()?;
        Ok(out)
    }

    /// Load one expert in the given residency mode — the single seam the
    /// cache, the scheduler's demand path, and the prefetch workers all
    /// decode through.
    pub fn load_with(
        reader: &TqmReader,
        layer: usize,
        expert: usize,
        residency: ExpertResidency,
    ) -> Result<Self> {
        match residency {
            ExpertResidency::Decoded => Self::load(reader, layer, expert),
            ExpertResidency::Packed => Self::load_packed(reader, layer, expert),
        }
    }

    pub fn is_packed(&self) -> bool {
        matches!(self.body, ExpertBody::Packed(_))
    }

    /// Decoded `w1` arena — panics for packed experts (test/debug view).
    pub fn w1(&self) -> &[f32] {
        match &self.body {
            ExpertBody::Decoded { w1, .. } => w1,
            ExpertBody::Packed(_) => panic!("packed expert has no f32 w1"),
        }
    }

    /// Decoded `w3` arena — panics for packed experts (test/debug view).
    pub fn w3(&self) -> &[f32] {
        match &self.body {
            ExpertBody::Decoded { w3, .. } => w3,
            ExpertBody::Packed(_) => panic!("packed expert has no f32 w3"),
        }
    }

    /// Decoded `w2` arena — panics for packed experts (test/debug view).
    pub fn w2(&self) -> &[f32] {
        match &self.body {
            ExpertBody::Decoded { w2, .. } => w2,
            ExpertBody::Packed(_) => panic!("packed expert has no f32 w2"),
        }
    }

    /// Shape sanity: w1/w3 `[d, de]`, w2 `[de, d]`.
    pub fn validate(&self) -> Result<()> {
        let (d, de) = (self.d_model, self.d_expert);
        match &self.body {
            ExpertBody::Decoded { w1, w3, w2 } => anyhow::ensure!(
                w1.len() == d * de && w3.len() == d * de && w2.len() == de * d,
                "expert ({}, {}) weight sizes inconsistent with [{d}, {de}]",
                self.layer,
                self.expert
            ),
            ExpertBody::Packed(p) => {
                anyhow::ensure!(
                    p.w1.rows == d
                        && p.w1.cols == de
                        && p.w3.rows == d
                        && p.w3.cols == de
                        && p.w2.rows == de
                        && p.w2.cols == d,
                    "expert ({}, {}) packed shapes inconsistent with [{d}, {de}]",
                    self.layer,
                    self.expert
                );
                for m in [&p.w1, &p.w3, &p.w2] {
                    let want = (m.rows * m.cols * m.bits as usize + 7) / 8;
                    anyhow::ensure!(
                        m.codes.len() == want,
                        "expert ({}, {}) packed stream is {} bytes, expected {want}",
                        self.layer,
                        self.expert,
                        m.codes.len()
                    );
                }
            }
        }
        Ok(())
    }

    /// Resident size in bytes (what this expert costs the cache budget):
    /// f32 arenas when decoded, code streams + params + LUTs when packed.
    pub fn bytes(&self) -> usize {
        match &self.body {
            ExpertBody::Decoded { w1, w3, w2 } => (w1.len() + w3.len() + w2.len()) * 4,
            ExpertBody::Packed(p) => {
                p.w1.resident_bytes() + p.w3.resident_bytes() + p.w2.resident_bytes()
            }
        }
    }

    /// SwiGLU expert FFN for one token vector:
    /// `(silu(x W1) ⊙ (x W3)) W2`. Decoded and packed bodies run the
    /// identical float operations in the identical order (the qGEMV
    /// kernels replicate the decoded matmul exactly), so the two forms
    /// are bit-exact — `integration_moe` asserts it end to end.
    pub fn ffn(&self, x: &[f32]) -> Vec<f32> {
        let (d, de) = (self.d_model, self.d_expert);
        assert_eq!(x.len(), d, "expert input dim mismatch");
        match &self.body {
            ExpertBody::Decoded { w1, w3, w2 } => {
                let mut h1 = vec![0.0f32; de];
                let mut h3 = vec![0.0f32; de];
                for (i, &xi) in x.iter().enumerate() {
                    if xi == 0.0 {
                        continue;
                    }
                    let r1 = &w1[i * de..(i + 1) * de];
                    let r3 = &w3[i * de..(i + 1) * de];
                    for j in 0..de {
                        h1[j] += xi * r1[j];
                        h3[j] += xi * r3[j];
                    }
                }
                let mut out = vec![0.0f32; d];
                for j in 0..de {
                    let a = h1[j];
                    let g = a / (1.0 + (-a).exp()) * h3[j]; // silu(a) * h3
                    if g == 0.0 {
                        continue;
                    }
                    let r2 = &w2[j * d..(j + 1) * d];
                    for (o, &w) in out.iter_mut().zip(r2) {
                        *o += g * w;
                    }
                }
                out
            }
            ExpertBody::Packed(p) => {
                // same math, quantized domain: the gate vector is built
                // with the identical expression, and w2's qGEMV skips
                // g[j] == 0.0 rows exactly like the decoded `continue`
                let _k = crate::trace::span(crate::trace::Category::Kernel, "qgemv")
                    .layer(self.layer)
                    .expert(self.expert);
                let mut h1 = vec![0.0f32; de];
                let mut h3 = vec![0.0f32; de];
                p.w1.gemv_into(x, &mut h1);
                p.w3.gemv_into(x, &mut h3);
                let mut g = vec![0.0f32; de];
                for ((gj, &a), &h) in g.iter_mut().zip(&h1).zip(&h3) {
                    *gj = a / (1.0 + (-a).exp()) * h;
                }
                let mut out = vec![0.0f32; d];
                p.w2.gemv_into(&g, &mut out);
                out
            }
        }
    }

    /// SwiGLU expert FFN for a whole routed token group. For a packed
    /// body each of w1/w3/w2 is traversed ONCE for all `xs.len()` tokens
    /// (the batched qGEMM), instead of once per token — this is the
    /// scheduler's single-traversal win. Exact accumulation mode: every
    /// token's output is bit-exact against [`ExpertWeights::ffn`] on
    /// that token. A decoded body has no packed stream to amortize and
    /// simply runs the per-token FFN.
    pub fn ffn_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let (d, de) = (self.d_model, self.d_expert);
        let b = xs.len();
        if b == 0 {
            return Vec::new();
        }
        match &self.body {
            ExpertBody::Decoded { .. } => xs.iter().map(|x| self.ffn(x)).collect(),
            ExpertBody::Packed(p) => {
                let _k = crate::trace::span(crate::trace::Category::Kernel, "qgemm")
                    .layer(self.layer)
                    .expert(self.expert);
                let mut xf = Vec::with_capacity(b * d);
                for x in xs {
                    assert_eq!(x.len(), d, "expert input dim mismatch");
                    xf.extend_from_slice(x);
                }
                let mut h1 = vec![0.0f32; b * de];
                let mut h3 = vec![0.0f32; b * de];
                p.w1.gemm_into(&xf, b, &mut h1, packing::Accumulation::Exact);
                p.w3.gemm_into(&xf, b, &mut h3, packing::Accumulation::Exact);
                // identical gate expression to `ffn`, elementwise across
                // the flat [b, de] buffers
                let mut g = vec![0.0f32; b * de];
                for ((gj, &a), &h) in g.iter_mut().zip(&h1).zip(&h3) {
                    *gj = a / (1.0 + (-a).exp()) * h;
                }
                let mut yf = vec![0.0f32; b * d];
                p.w2.gemm_into(&g, b, &mut yf, packing::Accumulation::Exact);
                yf.chunks(d).map(|c| c.to_vec()).collect()
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Forward
// ---------------------------------------------------------------------------

/// The gated expert sum for one token vector given *precomputed* picks,
/// accumulated in pick order. Every MoE forward in the crate — the
/// per-sequence path, the scheduler's batched path — bottoms out here,
/// which is what makes "scheduling changes residency, never values"
/// structurally true rather than merely tested.
pub fn moe_token_from_picks<F>(
    x: &[f32],
    picks: &[(usize, f32)],
    mut expert: F,
) -> Result<Vec<f32>>
where
    F: FnMut(usize) -> Result<std::sync::Arc<ExpertWeights>>,
{
    let mut out = vec![0.0f32; x.len()];
    for &(e, gate) in picks {
        let w = expert(e)?;
        let y = w.ffn(x);
        for (o, v) in out.iter_mut().zip(y) {
            *o += gate * v;
        }
    }
    Ok(out)
}

/// One MoE sublayer forward for a single token vector: route, run the
/// top-k experts fetched through `expert`, and sum gate-weighted outputs.
/// `expert` is the residency seam — the cache, a resident table, and a
/// pure streamer all plug in here, running identical math.
pub fn moe_forward_token<F>(
    x: &[f32],
    router: &Router,
    top_k: usize,
    expert: F,
) -> Result<Vec<f32>>
where
    F: FnMut(usize) -> Result<std::sync::Arc<ExpertWeights>>,
{
    moe_token_from_picks(x, &router.top_k(x, top_k), expert)
}

/// Batched MoE sublayer forward consuming a decode plan's picks: each
/// sequence's picks are applied in router order (bit-exact vs the
/// per-sequence path), while `expert` is consulted per pick — the
/// scheduler passes a closure over the experts it fetched **once** for
/// the whole batch, which is where the decode dedup lands.
pub fn moe_layer_forward_batched<F>(
    xs: &[Vec<f32>],
    picks: &[Vec<(usize, f32)>],
    mut expert: F,
) -> Result<Vec<Vec<f32>>>
where
    F: FnMut(usize) -> Result<std::sync::Arc<ExpertWeights>>,
{
    anyhow::ensure!(xs.len() == picks.len(), "batch/picks length mismatch");
    xs.iter()
        .zip(picks)
        .map(|(x, p)| moe_token_from_picks(x, p, &mut expert))
        .collect()
}

/// Execution shape of one grouped layer forward — what the scheduler's
/// batched-vs-scalar metrics are fed from.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GroupedExec {
    /// Batched (expert, token-group) calls made — one traversal of each
    /// of the expert's three packed streams per call.
    pub groups: u64,
    /// Routed tokens served across those calls (Σ group sizes).
    pub tokens: u64,
}

/// Batched MoE sublayer forward that hands each expert its WHOLE routed
/// token group in one [`ExpertWeights::ffn_batch`] call — one packed-
/// stream traversal per (layer, expert) per step — then assembles every
/// sequence's output by accumulating `gate * y` in its original router
/// pick order. Because `ffn_batch` is bit-exact per token and the
/// assembly replays exactly the accumulation [`moe_token_from_picks`]
/// performs, the result is bit-exact against
/// [`moe_layer_forward_batched`]; experts are consulted in sorted order.
pub fn moe_layer_forward_grouped<F>(
    xs: &[Vec<f32>],
    picks: &[Vec<(usize, f32)>],
    mut expert: F,
) -> Result<(Vec<Vec<f32>>, GroupedExec)>
where
    F: FnMut(usize) -> Result<std::sync::Arc<ExpertWeights>>,
{
    anyhow::ensure!(xs.len() == picks.len(), "batch/picks length mismatch");
    // token groups per expert, sorted expert order (deterministic and
    // batch-order independent, like LayerPlan::unique)
    let mut groups: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
    for (t, p) in picks.iter().enumerate() {
        for &(e, _) in p {
            let toks = groups.entry(e).or_default();
            if toks.last() != Some(&t) {
                toks.push(t);
            }
        }
    }
    let mut stats = GroupedExec::default();
    let mut results: std::collections::BTreeMap<usize, Vec<Vec<f32>>> = Default::default();
    for (&e, toks) in &groups {
        let w = expert(e)?;
        let gathered: Vec<Vec<f32>> = toks.iter().map(|&t| xs[t].clone()).collect();
        let ys = w.ffn_batch(&gathered);
        stats.groups += 1;
        stats.tokens += toks.len() as u64;
        results.insert(e, ys);
    }
    let mut out = Vec::with_capacity(xs.len());
    for (t, (x, p)) in xs.iter().zip(picks).enumerate() {
        let mut acc = vec![0.0f32; x.len()];
        for &(e, gate) in p {
            let toks = &groups[&e];
            let idx = toks.iter().position(|&tt| tt == t).expect("token in its expert's group");
            for (o, &v) in acc.iter_mut().zip(&results[&e][idx]) {
                *o += gate * v;
            }
        }
        out.push(acc);
    }
    Ok((out, stats))
}

/// Forward one token vector through a stack of MoE sublayers with
/// residual connections: `x <- x + moe_l(x)` for each layer. `expert`
/// receives `(layer, expert)`.
pub fn moe_stack_forward<F>(
    routers: &[Router],
    spec: &MoeSpec,
    x0: &[f32],
    mut expert: F,
) -> Result<Vec<f32>>
where
    F: FnMut(usize, usize) -> Result<std::sync::Arc<ExpertWeights>>,
{
    let mut x = x0.to_vec();
    for (l, router) in routers.iter().enumerate() {
        let y = moe_forward_token(&x, router, spec.top_k, |e| expert(l, e))?;
        for (xi, yi) in x.iter_mut().zip(y) {
            *xi += yi;
        }
    }
    Ok(x)
}

/// Load every router of an MoE container, layer order.
pub fn load_routers(reader: &TqmReader, n_layers: usize) -> Result<Vec<Router>> {
    (0..n_layers).map(|l| Router::load(reader, l)).collect()
}

// ---------------------------------------------------------------------------
// Quantize / synthesize
// ---------------------------------------------------------------------------

/// Quantize an MoE checkpoint (routers + per-expert SwiGLU matrices) and
/// stage it for writing. Every expert matrix is quantized independently —
/// per-expert scale/zero parameters — and staged as its own record, so
/// the container's expert index lets one expert decode alone.
pub fn quantize_moe_checkpoint(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    opts: &QuantizeOptions,
    codec: CodecId,
    source: &str,
) -> Result<TqmWriter> {
    let spec = cfg
        .moe
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("config {:?} has no moe spec", cfg.name))?;
    let meta = TqmMeta {
        model_name: cfg.name.clone(),
        codec,
        bits: opts.bits,
        per_channel: opts.per_channel,
        quantizer: "naive".into(),
        source_checkpoint: source.to_string(),
    };
    let mut w = TqmWriter::new(meta);
    let gran = if opts.per_channel {
        Granularity::PerChannel { axis: 1 }
    } else {
        Granularity::PerTensor
    };
    for l in 0..cfg.n_layers {
        w.add_router(l, ckpt.f32(&router_record_name(l))?);
        for e in 0..spec.n_experts {
            for mat in EXPERT_MATRIX_NAMES {
                let name = expert_record_name(l, e, mat);
                let t = ckpt.f32(&name)?;
                w.add_expert_quantized(l, e, mat, &uniform::quantize(t, opts.bits, gran)?);
            }
        }
    }
    Ok(w)
}

/// A small MoE geometry for the eval scenario, examples and tests (no
/// lowered artifacts required — the MoE forward runs host-side).
pub fn moe_demo_config() -> ModelConfig {
    ModelConfig {
        name: "moe-demo".into(),
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 8 * 48, // dense-equivalent FFN width
        vocab: 64,
        max_seq: 16,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
        head_dim: 8,
        kv_dim: 16,
        n_params: 0,
        prefill_t: vec![8],
        prefill_b: vec![1],
        decode_b: vec![1],
        moe: Some(MoeSpec { n_experts: 8, top_k: 2, d_expert: 48 }),
    }
}

/// Synthesize an MoE checkpoint matching `cfg` (routers + experts),
/// deterministic in `seed`.
pub fn synth_moe_checkpoint(cfg: &ModelConfig, seed: u64) -> Result<Checkpoint> {
    let spec = cfg
        .moe
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("config {:?} has no moe spec", cfg.name))?;
    let mut rng = crate::util::Rng::seed_from_u64(seed);
    let (d, de, ne) = (cfg.d_model, spec.d_expert, spec.n_experts);
    let mut tensors = std::collections::BTreeMap::new();
    let std_in = 1.0 / (d as f32).sqrt();
    let std_out = 1.0 / (de as f32).sqrt();
    for l in 0..cfg.n_layers {
        tensors.insert(
            router_record_name(l),
            crate::tensor::io::TqwTensor::F32(Tensor::new(
                vec![d, ne],
                rng.normal_vec(d * ne, std_in),
            )?),
        );
        for e in 0..ne {
            for (mat, shape, std) in [
                ("w1", vec![d, de], std_in),
                ("w3", vec![d, de], std_in),
                ("w2", vec![de, d], std_out),
            ] {
                let n = crate::tensor::numel(&shape);
                tensors.insert(
                    expert_record_name(l, e, mat),
                    crate::tensor::io::TqwTensor::F32(Tensor::new(
                        shape,
                        rng.normal_vec(n, std),
                    )?),
                );
            }
        }
    }
    Ok(Checkpoint { tensors })
}

/// A reuse-heavy token-vector trace for expert-cache experiments: `n`
/// vectors drawn from `clusters` centers in runs of `run_len` (temporal
/// locality — consecutive tokens route to the same experts, like real
/// decode traffic with topic-coherent prompts).
pub fn clustered_trace(
    d_model: usize,
    clusters: usize,
    run_len: usize,
    n: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut rng = crate::util::Rng::seed_from_u64(seed);
    let centers: Vec<Vec<f32>> =
        (0..clusters.max(1)).map(|_| rng.normal_vec(d_model, 1.0)).collect();
    (0..n)
        .map(|t| centers[(t / run_len.max(1)) % centers.len()].clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;
    use std::sync::Arc;

    fn demo_container() -> (ModelConfig, TempDir, TqmReader) {
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, 7).unwrap();
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, "unit")
            .unwrap()
            .with_chunk_len(512);
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        let reader = TqmReader::open(&p).unwrap();
        (cfg, dir, reader)
    }

    #[test]
    fn container_carries_all_experts() {
        let (cfg, _dir, reader) = demo_container();
        let spec = cfg.moe.as_ref().unwrap();
        assert_eq!(reader.expert_entries().len(), cfg.n_layers * spec.n_experts);
        for l in 0..cfg.n_layers {
            assert_eq!(reader.n_experts(l), spec.n_experts);
        }
        // records per expert: w1, w3, w2
        let e = reader.expert_entry(0, 0).unwrap();
        assert_eq!(e.records.len(), 3);
        assert_eq!(
            e.decoded_f32_bytes,
            (2 * cfg.d_model * spec.d_expert + spec.d_expert * cfg.d_model) * 4
        );
    }

    #[test]
    fn expert_load_matches_two_step_dequant() {
        let (_cfg, _dir, reader) = demo_container();
        let w = ExpertWeights::load(&reader, 1, 3).unwrap();
        for (mat, data) in EXPERT_MATRIX_NAMES.iter().zip([w.w1(), w.w3(), w.w2()]) {
            let q = reader.load_quantized(&expert_record_name(1, 3, mat)).unwrap();
            assert_eq!(data, q.dequantize().data, "{mat}");
        }
    }

    #[test]
    fn packed_and_decoded_ffn_bit_exact_all_widths() {
        // THE packed-execution invariant: for every bit width and both
        // granularities, the quantized-domain SwiGLU equals the decoded
        // one bit for bit — on random vectors and on vectors with exact
        // zeros (the skip branch)
        use crate::quant::Bits;
        for bits in [Bits::Ternary, Bits::B2, Bits::B4, Bits::B6, Bits::B8] {
            for per_channel in [false, true] {
                let cfg = moe_demo_config();
                let ckpt = synth_moe_checkpoint(&cfg, 57).unwrap();
                let opts = QuantizeOptions { bits, per_channel, ..Default::default() };
                let w =
                    quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, "unit")
                        .unwrap()
                        .with_chunk_len(300);
                let dir = TempDir::new().unwrap();
                let p = dir.join("moe.tqm");
                w.write(&p).unwrap();
                let reader = TqmReader::open(&p).unwrap();
                let dec = ExpertWeights::load(&reader, 1, 2).unwrap();
                let pkd = ExpertWeights::load_packed(&reader, 1, 2).unwrap();
                assert!(pkd.is_packed() && !dec.is_packed());
                assert!(
                    pkd.bytes() < dec.bytes(),
                    "{bits:?}: packed {} B not below decoded {} B",
                    pkd.bytes(),
                    dec.bytes()
                );
                let mut rng = crate::util::Rng::seed_from_u64(13);
                for t in 0..8 {
                    let mut x = rng.normal_vec(cfg.d_model, 1.0);
                    if t % 2 == 1 {
                        for v in x.iter_mut().step_by(3) {
                            *v = 0.0;
                        }
                    }
                    assert_eq!(
                        dec.ffn(&x),
                        pkd.ffn(&x),
                        "{bits:?} per_channel={per_channel}: packed ffn diverged"
                    );
                }
                // load_with is the same two paths behind the knob
                let via_knob =
                    ExpertWeights::load_with(&reader, 1, 2, ExpertResidency::Packed).unwrap();
                assert_eq!(via_knob.bytes(), pkd.bytes());
                // and the index predicted the packed footprint exactly
                assert_eq!(
                    reader.expert_entry(1, 2).unwrap().packed_resident_bytes,
                    pkd.bytes(),
                    "{bits:?} per_channel={per_channel}: index size disagrees with decode"
                );
            }
        }
    }

    #[test]
    fn router_top_k_properties() {
        let (cfg, _dir, reader) = demo_container();
        let spec = cfg.moe.as_ref().unwrap();
        let router = Router::load(&reader, 0).unwrap();
        assert_eq!(router.n_experts(), spec.n_experts);
        let mut rng = crate::util::Rng::seed_from_u64(3);
        for _ in 0..50 {
            let x = rng.normal_vec(cfg.d_model, 1.0);
            let picks = router.top_k(&x, spec.top_k);
            assert_eq!(picks.len(), spec.top_k);
            // distinct experts, gates positive and normalized
            let mut ids: Vec<usize> = picks.iter().map(|p| p.0).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), spec.top_k);
            let total: f32 = picks.iter().map(|p| p.1).sum();
            assert!((total - 1.0).abs() < 1e-5, "gates sum to {total}");
            assert!(picks.iter().all(|p| p.1 > 0.0));
            // picked experts really are the argmax set of the logits
            let logits = router.logits(&x);
            let min_picked =
                picks.iter().map(|p| logits[p.0]).fold(f32::INFINITY, f32::min);
            let unpicked_max = logits
                .iter()
                .enumerate()
                .filter(|(i, _)| !picks.iter().any(|p| p.0 == *i))
                .map(|(_, &v)| v)
                .fold(f32::NEG_INFINITY, f32::max);
            assert!(min_picked >= unpicked_max);
        }
    }

    #[test]
    fn moe_forward_is_gated_expert_sum() {
        let (cfg, _dir, reader) = demo_container();
        let spec = cfg.moe.as_ref().unwrap();
        let router = Router::load(&reader, 0).unwrap();
        let all: Vec<Arc<ExpertWeights>> = (0..spec.n_experts)
            .map(|e| Arc::new(ExpertWeights::load(&reader, 0, e).unwrap()))
            .collect();
        let mut rng = crate::util::Rng::seed_from_u64(9);
        let x = rng.normal_vec(cfg.d_model, 1.0);
        let y =
            moe_forward_token(&x, &router, spec.top_k, |e| Ok(all[e].clone())).unwrap();
        // manual recompute
        let mut want = vec![0.0f32; cfg.d_model];
        for (e, g) in router.top_k(&x, spec.top_k) {
            for (w, v) in want.iter_mut().zip(all[e].ffn(&x)) {
                *w += g * v;
            }
        }
        assert_eq!(y, want);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn per_tensor_quantization_roundtrips_too() {
        let cfg = moe_demo_config();
        let ckpt = synth_moe_checkpoint(&cfg, 21).unwrap();
        let opts = QuantizeOptions::default(); // per-tensor
        let w = quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::Lzw, "unit").unwrap();
        let dir = TempDir::new().unwrap();
        let p = dir.join("moe.tqm");
        w.write(&p).unwrap();
        let reader = TqmReader::open(&p).unwrap();
        let e = ExpertWeights::load(&reader, 0, 1).unwrap();
        e.validate().unwrap();
        // quantization error stays small at 8 bits
        let orig = ckpt.f32(&expert_record_name(0, 1, "w1")).unwrap();
        let mse: f64 = orig
            .data
            .iter()
            .zip(e.w1())
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / orig.data.len() as f64;
        assert!(mse < 1e-4, "mse {mse}");
    }

    #[test]
    fn clustered_trace_repeats_within_runs() {
        let trace = clustered_trace(8, 3, 4, 24, 1);
        assert_eq!(trace.len(), 24);
        assert_eq!(trace[0], trace[3]); // same run
        assert_ne!(trace[0], trace[4]); // next cluster
        assert_eq!(trace[0], trace[12]); // cluster cycle repeats
    }

    #[test]
    fn ffn_batch_bit_exact_vs_per_token_ffn() {
        // one traversal for the whole group must not change a single bit
        // vs running ffn per token, for packed AND decoded bodies, with
        // exact zeros in some tokens (the skip branch)
        use crate::quant::Bits;
        for bits in [Bits::Ternary, Bits::B4, Bits::B6, Bits::B8] {
            for per_channel in [false, true] {
                let cfg = moe_demo_config();
                let ckpt = synth_moe_checkpoint(&cfg, 77).unwrap();
                let opts = QuantizeOptions { bits, per_channel, ..Default::default() };
                let w =
                    quantize_moe_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, "unit")
                        .unwrap();
                let dir = TempDir::new().unwrap();
                let p = dir.join("moe.tqm");
                w.write(&p).unwrap();
                let reader = TqmReader::open(&p).unwrap();
                for residency in [ExpertResidency::Decoded, ExpertResidency::Packed] {
                    let e = ExpertWeights::load_with(&reader, 0, 4, residency).unwrap();
                    let mut rng = crate::util::Rng::seed_from_u64(31);
                    for b in [1usize, 2, 5, 8] {
                        let xs: Vec<Vec<f32>> = (0..b)
                            .map(|t| {
                                let mut x = rng.normal_vec(cfg.d_model, 1.0);
                                if t % 2 == 1 {
                                    for v in x.iter_mut().step_by(3) {
                                        *v = 0.0;
                                    }
                                }
                                x
                            })
                            .collect();
                        let ys = e.ffn_batch(&xs);
                        assert_eq!(ys.len(), b);
                        for (x, y) in xs.iter().zip(&ys) {
                            assert_eq!(
                                y,
                                &e.ffn(x),
                                "{bits:?} per_channel={per_channel} {residency:?} b={b}"
                            );
                        }
                    }
                    assert!(e.ffn_batch(&[]).is_empty());
                }
            }
        }
    }

    #[test]
    fn grouped_layer_forward_bit_exact_and_one_call_per_expert() {
        let (cfg, _dir, reader) = demo_container();
        let spec = cfg.moe.as_ref().unwrap();
        let router = Router::load(&reader, 0).unwrap();
        for residency in [ExpertResidency::Decoded, ExpertResidency::Packed] {
            let all: Vec<Arc<ExpertWeights>> = (0..spec.n_experts)
                .map(|e| Arc::new(ExpertWeights::load_with(&reader, 0, e, residency).unwrap()))
                .collect();
            let mut rng = crate::util::Rng::seed_from_u64(41);
            // shared tokens so expert groups have size > 1
            let base = rng.normal_vec(cfg.d_model, 1.0);
            let mut xs: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(cfg.d_model, 1.0)).collect();
            xs.push(base.clone());
            xs.push(base);
            let picks: Vec<Vec<(usize, f32)>> =
                xs.iter().map(|x| router.top_k(x, spec.top_k)).collect();
            let want =
                moe_layer_forward_batched(&xs, &picks, |e| Ok(all[e].clone())).unwrap();
            let mut calls = 0u64;
            let (got, stats) = moe_layer_forward_grouped(&xs, &picks, |e| {
                calls += 1;
                Ok(all[e].clone())
            })
            .unwrap();
            assert_eq!(got, want, "{residency:?}: grouped forward diverged");
            let mut unique: Vec<usize> = picks.iter().flatten().map(|p| p.0).collect();
            unique.sort_unstable();
            unique.dedup();
            assert_eq!(stats.groups, unique.len() as u64, "one ffn_batch call per expert");
            assert_eq!(calls, unique.len() as u64, "one fetch per expert");
            assert_eq!(stats.tokens, picks.iter().map(|p| p.len() as u64).sum::<u64>());
        }
        // empty batch
        let (got, stats) = moe_layer_forward_grouped(&[], &[], |_| unreachable!()).unwrap();
        assert!(got.is_empty());
        assert_eq!(stats, GroupedExec::default());
    }

    #[test]
    fn packed_matrix_resident_bytes_matches_shared_rule() {
        // drift test (widths 1..=8 x all granularities): what a built
        // PackedMatrix actually holds equals the shared index-side
        // formula the cache sizes experts with
        for bits in 1..=8u32 {
            for (rows, cols) in [(4usize, 6usize), (64, 96)] {
                let n = rows * cols;
                let codes = packing::pack(&vec![0u8; n], bits);
                for g in [
                    Granularity::PerTensor,
                    Granularity::PerChannel { axis: 0 },
                    Granularity::PerChannel { axis: 1 },
                ] {
                    let (ns, nz) = match g {
                        Granularity::PerTensor => (1usize, 1usize),
                        Granularity::PerChannel { axis: 0 } => (rows, rows),
                        _ => (cols, cols),
                    };
                    let m = PackedMatrix::new(
                        rows,
                        cols,
                        bits,
                        g,
                        codes.clone(),
                        vec![0.01; ns],
                        vec![0.0; nz],
                    );
                    assert_eq!(
                        m.resident_bytes(),
                        packing::packed_resident_bytes(bits, g, cols, codes.len(), ns, nz),
                        "bits={bits} {rows}x{cols} {g:?}"
                    );
                    assert_eq!(
                        !m.col_lut.is_empty(),
                        packing::col_lut_stored_bytes(bits, g, cols, codes.len()) > 0,
                        "LUT presence must follow the shared rule"
                    );
                }
            }
        }
    }
}
