//! Per-layer weight set and its flattening into stage-HLO arguments.
//!
//! The argument order is a binary contract with
//! `python/compile/model.py::LAYER_WEIGHT_ORDER`:
//!   ln1, (wq, wq_s, wq_z), (wk, ..), (wv, ..), (wo, ..),
//!   ln2, (w1, ..), (w3, ..), (w2, ..)
//! where each matrix contributes u8 codes plus per-out-channel f32
//! scale/zero vectors (per-tensor params are broadcast at this boundary).

use anyhow::Result;

use crate::config::ModelConfig;
use crate::format::TqmReader;
use crate::quant::QuantizedTensor;
use crate::runtime::literal;
use crate::tensor::Tensor;
use crate::xla;

#[derive(Clone)]
pub struct LayerWeights {
    pub index: usize,
    pub ln1: Tensor,
    pub wq: QuantizedTensor,
    pub wk: QuantizedTensor,
    pub wv: QuantizedTensor,
    pub wo: QuantizedTensor,
    pub ln2: Tensor,
    pub w1: QuantizedTensor,
    pub w3: QuantizedTensor,
    pub w2: QuantizedTensor,
}

impl LayerWeights {
    /// Decompress layer `i` from a TQM container (scratch-buffer variant
    /// available through `load_into` for the pipeline's reuse path).
    pub fn load(reader: &TqmReader, i: usize) -> Result<Self> {
        let mut scratch = Vec::new();
        Self::load_into(reader, i, &mut scratch)
    }

    pub fn load_into(reader: &TqmReader, i: usize, scratch: &mut Vec<u8>) -> Result<Self> {
        let q = |name: &str, scratch: &mut Vec<u8>| -> Result<QuantizedTensor> {
            reader.load_quantized_into(&format!("layers.{i}.{name}"), scratch)
        };
        Ok(Self {
            index: i,
            ln1: reader.load_f32(&format!("layers.{i}.ln1"))?,
            wq: q("wq", scratch)?,
            wk: q("wk", scratch)?,
            wv: q("wv", scratch)?,
            wo: q("wo", scratch)?,
            ln2: reader.load_f32(&format!("layers.{i}.ln2"))?,
            w1: q("w1", scratch)?,
            w3: q("w3", scratch)?,
            w2: q("w2", scratch)?,
        })
    }

    fn matrices(&self) -> [(&QuantizedTensor, usize); 7] {
        let kv = self.wk.codes.shape[1];
        let d = self.wq.codes.shape[1];
        let f = self.w1.codes.shape[1];
        [
            (&self.wq, d),
            (&self.wk, kv),
            (&self.wv, kv),
            (&self.wo, d),
            (&self.w1, f),
            (&self.w3, f),
            (&self.w2, d),
        ]
    }

    /// Flatten into the stage-argument literal list (contract order).
    pub fn to_literals(&self, _cfg: &ModelConfig) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(2 + 7 * 3);
        let push_q = |out: &mut Vec<xla::Literal>, q: &QuantizedTensor, ch: usize| -> Result<()> {
            out.push(literal::u8_literal(&q.codes.shape, &q.codes.data)?);
            let (s, z) = q.channel_params(ch);
            out.push(literal::f32_literal(&[ch], &s)?);
            out.push(literal::f32_literal(&[ch], &z)?);
            Ok(())
        };
        out.push(literal::tensor_literal(&self.ln1)?);
        let mats = self.matrices();
        for (q, ch) in &mats[..4] {
            push_q(&mut out, q, *ch)?;
        }
        out.push(literal::tensor_literal(&self.ln2)?);
        for (q, ch) in &mats[4..] {
            push_q(&mut out, q, *ch)?;
        }
        Ok(out)
    }

    /// Bytes this layer occupies once expanded (codes + params + norms) —
    /// the number the residency bench (E8) tracks.
    pub fn expanded_bytes(&self) -> usize {
        let mats = self.matrices();
        let m: usize = mats.iter().map(|(q, _)| q.unpacked_bytes()).sum();
        m + (self.ln1.data.len() + self.ln2.data.len()) * 4
    }
}

/// f32 layer weights — the unquantized baseline path (stages `*_f32`).
#[derive(Clone)]
pub struct LayerWeightsF32 {
    pub index: usize,
    pub tensors: Vec<Tensor>, // LAYER_WEIGHT_ORDER: ln1,wq,wk,wv,wo,ln2,w1,w3,w2
}

pub const LAYER_WEIGHT_ORDER: [&str; 9] =
    ["ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w3", "w2"];

impl LayerWeightsF32 {
    pub fn load(ckpt: &crate::model::Checkpoint, i: usize) -> Result<Self> {
        let tensors = LAYER_WEIGHT_ORDER
            .iter()
            .map(|n| ckpt.f32(&format!("layers.{i}.{n}")).cloned())
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { index: i, tensors })
    }

    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        self.tensors.iter().map(literal::tensor_literal).collect()
    }

    pub fn bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::config::QuantizeOptions;
    use crate::model::tests::{fake_checkpoint, tiny_cfg};
    use crate::model::quantize_checkpoint;
    use crate::util::TempDir;

    fn sample_reader() -> (crate::config::ModelConfig, TqmReader) {
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 3);
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_checkpoint(&cfg, &ckpt, &opts, CodecId::Huffman, None, "t").unwrap();
        let dir = TempDir::new().unwrap();
        let p = dir.join("m.tqm");
        w.write(&p).unwrap();
        // read fully into memory before TempDir drops
        let reader = TqmReader::open(&p).unwrap();
        (cfg, reader)
    }

    #[test]
    fn literal_contract_order_and_count() {
        let (cfg, reader) = sample_reader();
        let lw = LayerWeights::load(&reader, 1).unwrap();
        let lits = lw.to_literals(&cfg).unwrap();
        // ln1 + 4 matrices * 3 + ln2 + 3 matrices * 3 = 23
        assert_eq!(lits.len(), 23);
        // spot-check arg dtypes: [0] f32 norm, [1] u8 codes, [2]/[3] f32
        assert_eq!(lits[0].ty().unwrap(), xla::ElementType::F32);
        assert_eq!(lits[1].ty().unwrap(), xla::ElementType::U8);
        assert_eq!(lits[2].ty().unwrap(), xla::ElementType::F32);
        // wk codes at position 4 with kv_dim out channels
        assert_eq!(
            crate::runtime::literal::literal_shape(&lits[4]).unwrap(),
            vec![cfg.d_model, cfg.kv_dim]
        );
    }

    #[test]
    fn per_tensor_params_broadcast_to_channels() {
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 4);
        let opts = QuantizeOptions { per_channel: false, ..Default::default() };
        let w = quantize_checkpoint(&cfg, &ckpt, &opts, CodecId::Raw, None, "t").unwrap();
        let dir = TempDir::new().unwrap();
        let p = dir.join("m.tqm");
        w.write(&p).unwrap();
        let reader = TqmReader::open(&p).unwrap();
        let lw = LayerWeights::load(&reader, 0).unwrap();
        let lits = lw.to_literals(&cfg).unwrap();
        // wq scale vector must be expanded to d_model
        assert_eq!(
            crate::runtime::literal::literal_shape(&lits[2]).unwrap(),
            vec![cfg.d_model]
        );
    }

    #[test]
    fn expanded_bytes_sane() {
        let (cfg, reader) = sample_reader();
        let lw = LayerWeights::load(&reader, 0).unwrap();
        let d = cfg.d_model;
        let min_codes = d * d * 2 + d * cfg.kv_dim * 2 + d * cfg.d_ff * 3;
        assert!(lw.expanded_bytes() > min_codes);
    }

    #[test]
    fn scratch_reuse_consistent() {
        let (_, reader) = sample_reader();
        let mut scratch = Vec::new();
        let a = LayerWeights::load_into(&reader, 0, &mut scratch).unwrap();
        let b = LayerWeights::load(&reader, 0).unwrap();
        assert_eq!(a.wq.codes, b.wq.codes);
        assert_eq!(a.w2.codes, b.w2.codes);
    }
}
