//! Scalar f32 reference forward pass — two jobs:
//!
//! 1. **GPTQ calibration** (S3): run calibration tokens through the fp32
//!    model and accumulate the per-linear input Gram matrices GPTQ needs.
//!    The paper calibrates on C4; we calibrate on the SynthLang stream.
//! 2. **fp32 baseline rows** of Tables 2-4: the "llama3.2-xB" (unquantized)
//!    rows are produced by this path, so the accuracy deltas against the
//!    quantized/compressed pipeline are measured, not assumed.
//!
//! Mirrors `python/compile/model.py::full_forward_f32` operation-for-
//! operation (RMSNorm -> GQA attention with half-rotation RoPE -> SwiGLU).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::ModelConfig;
use crate::model::Checkpoint;
use crate::quant::gptq::Hessian;

/// Row-major matmul y[M,N] = x[M,K] @ w[K,N] (blocked over K for cache).
pub fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    let mut y = vec![0.0f32; m * n];
    for i in 0..m {
        let xr = &x[i * k..(i + 1) * k];
        let yr = &mut y[i * n..(i + 1) * n];
        for (kk, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w[kk * n..(kk + 1) * n];
            for (yv, &wv) in yr.iter_mut().zip(wr) {
                *yv += xv * wv;
            }
        }
    }
    y
}

fn rmsnorm(x: &[f32], w: &[f32], eps: f32) -> Vec<f32> {
    let d = w.len();
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for ((o, &v), &wv) in orow.iter_mut().zip(row).zip(w) {
            *o = v * inv * wv;
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Apply half-rotation RoPE in place. `x` is [T, H, Dh] flattened; the
/// position of row t is `t` (prefill from 0).
fn apply_rope(x: &mut [f32], t_len: usize, n_heads: usize, hd: usize, theta: f32) {
    let half = hd / 2;
    for t in 0..t_len {
        for h in 0..n_heads {
            let base = (t * n_heads + h) * hd;
            for i in 0..half {
                let ang = t as f32 / theta.powf(2.0 * i as f32 / hd as f32);
                let (sin, cos) = ang.sin_cos();
                let x1 = x[base + i];
                let x2 = x[base + half + i];
                x[base + i] = x1 * cos - x2 * sin;
                x[base + half + i] = x2 * cos + x1 * sin;
            }
        }
    }
}

/// Accumulates per-linear input activations into GPTQ Hessians.
pub struct Capture {
    pub hessians: BTreeMap<String, Hessian>,
}

impl Capture {
    pub fn new() -> Self {
        Self { hessians: BTreeMap::new() }
    }

    fn record(&mut self, name: &str, x: &[f32], k: usize) {
        self.hessians
            .entry(name.to_string())
            .or_insert_with(|| Hessian::new(k))
            .accumulate(x);
    }
}

/// Forward a single sequence (B = 1), returning logits [T, V].
/// With `capture`, every linear's input is accumulated for GPTQ.
pub fn forward(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    tokens: &[u32],
    mut capture: Option<&mut Capture>,
) -> Result<Vec<f32>> {
    let (d, hd, nh, kv) = (cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads);
    let t_len = tokens.len();
    let group = nh / kv;
    let theta = cfg.rope_theta as f32;
    let eps = cfg.norm_eps as f32;

    let embed = ckpt.f32("embed.weight")?;
    let mut h = vec![0.0f32; t_len * d];
    for (t, &tok) in tokens.iter().enumerate() {
        h[t * d..(t + 1) * d].copy_from_slice(embed.row(tok as usize));
    }

    for li in 0..cfg.n_layers {
        let name = |m: &str| format!("layers.{li}.{m}");
        let ln1 = ckpt.f32(&name("ln1"))?;
        let a = rmsnorm(&h, &ln1.data, eps);
        if let Some(cap) = capture.as_deref_mut() {
            for m in ["wq", "wk", "wv"] {
                cap.record(&name(m), &a, d);
            }
        }
        let wq = ckpt.f32(&name("wq"))?;
        let wk = ckpt.f32(&name("wk"))?;
        let wv = ckpt.f32(&name("wv"))?;
        let mut q = matmul(&a, &wq.data, t_len, d, d);
        let mut k = matmul(&a, &wk.data, t_len, d, cfg.kv_dim);
        let v = matmul(&a, &wv.data, t_len, d, cfg.kv_dim);
        apply_rope(&mut q, t_len, nh, hd, theta);
        apply_rope(&mut k, t_len, kv, hd, theta);

        // causal attention per head
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn_out = vec![0.0f32; t_len * d]; // [T, H*Dh]
        let mut scores = vec![0.0f32; t_len];
        for hix in 0..nh {
            let kvh = hix / group;
            for ti in 0..t_len {
                let qrow = &q[(ti * nh + hix) * hd..(ti * nh + hix + 1) * hd];
                let mut maxs = f32::NEG_INFINITY;
                for tj in 0..=ti {
                    let krow = &k[(tj * kv + kvh) * hd..(tj * kv + kvh + 1) * hd];
                    let s: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                    scores[tj] = s;
                    maxs = maxs.max(s);
                }
                let mut denom = 0.0f32;
                for s in scores[..=ti].iter_mut() {
                    *s = (*s - maxs).exp();
                    denom += *s;
                }
                let orow = &mut attn_out[(ti * nh + hix) * hd..(ti * nh + hix + 1) * hd];
                for tj in 0..=ti {
                    let w = scores[tj] / denom;
                    let vrow = &v[(tj * kv + kvh) * hd..(tj * kv + kvh + 1) * hd];
                    for (o, &vv) in orow.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            }
        }
        if let Some(cap) = capture.as_deref_mut() {
            cap.record(&name("wo"), &attn_out, d);
        }
        let wo = ckpt.f32(&name("wo"))?;
        let proj = matmul(&attn_out, &wo.data, t_len, d, d);
        for (hv, pv) in h.iter_mut().zip(&proj) {
            *hv += pv;
        }

        let ln2 = ckpt.f32(&name("ln2"))?;
        let a2 = rmsnorm(&h, &ln2.data, eps);
        if let Some(cap) = capture.as_deref_mut() {
            for m in ["w1", "w3"] {
                cap.record(&name(m), &a2, d);
            }
        }
        let w1 = ckpt.f32(&name("w1"))?;
        let w3 = ckpt.f32(&name("w3"))?;
        let gate = matmul(&a2, &w1.data, t_len, d, cfg.d_ff);
        let up = matmul(&a2, &w3.data, t_len, d, cfg.d_ff);
        let mut act = vec![0.0f32; t_len * cfg.d_ff];
        for ((o, &g), &u) in act.iter_mut().zip(&gate).zip(&up) {
            *o = silu(g) * u;
        }
        if let Some(cap) = capture.as_deref_mut() {
            cap.record(&name("w2"), &act, cfg.d_ff);
        }
        let w2 = ckpt.f32(&name("w2"))?;
        let down = matmul(&act, &w2.data, t_len, cfg.d_ff, d);
        for (hv, dv) in h.iter_mut().zip(&down) {
            *hv += dv;
        }
    }

    let fin = ckpt.f32("final_norm")?;
    let a = rmsnorm(&h, &fin.data, eps);
    if let Some(cap) = capture.as_deref_mut() {
        cap.record("head.weight", &a, d);
    }
    let head = ckpt.f32("head.weight")?;
    Ok(matmul(&a, &head.data, t_len, d, cfg.vocab))
}

/// Run calibration tokens through the model in windows, returning the
/// Hessians GPTQ consumes. `budget` bounds total tokens.
pub fn calibrate(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    tokens: &[u32],
    budget: usize,
    window: usize,
) -> Result<Capture> {
    let mut cap = Capture::new();
    let mut used = 0;
    for chunk in tokens.chunks(window) {
        if used >= budget || chunk.len() < 2 {
            break;
        }
        forward(cfg, ckpt, chunk, Some(&mut cap))?;
        used += chunk.len();
    }
    Ok(cap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests::{fake_checkpoint, tiny_cfg};

    #[test]
    fn forward_shapes_and_finite() {
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 0);
        let tokens: Vec<u32> = (0..8).map(|i| i % cfg.vocab as u32).collect();
        let logits = forward(&cfg, &ckpt, &tokens, None).unwrap();
        assert_eq!(logits.len(), 8 * cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_in_scalar_forward() {
        // changing a later token must not affect earlier logits
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 1);
        let t1: Vec<u32> = vec![1, 2, 3, 4, 5, 6];
        let mut t2 = t1.clone();
        t2[5] = 9;
        let l1 = forward(&cfg, &ckpt, &t1, None).unwrap();
        let l2 = forward(&cfg, &ckpt, &t2, None).unwrap();
        let v = cfg.vocab;
        for t in 0..5 {
            for c in 0..v {
                assert!((l1[t * v + c] - l2[t * v + c]).abs() < 1e-5);
            }
        }
        assert!((0..v).any(|c| (l1[5 * v + c] - l2[5 * v + c]).abs() > 1e-6));
    }

    #[test]
    fn capture_collects_all_linears() {
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 2);
        let cap = calibrate(&cfg, &ckpt, &(0..64u32).collect::<Vec<_>>(), 64, 16).unwrap();
        // 7 matrices per layer * 2 layers + head
        assert_eq!(cap.hessians.len(), 7 * cfg.n_layers + 1);
        let h = &cap.hessians["layers.0.wq"];
        assert_eq!(h.k, cfg.d_model);
        assert!(h.n_samples >= 64);
        // gram diagonal strictly positive (inputs are not all zero)
        assert!((0..h.k).all(|i| h.gram[i * h.k + i] > 0.0));
    }

    #[test]
    fn matmul_matches_naive() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // [2,2]
        let w = vec![5.0, 6.0, 7.0, 8.0]; // [2,2]
        let y = matmul(&x, &w, 2, 2, 2);
        assert_eq!(y, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn gptq_end_to_end_with_real_calibration() {
        // full S3 path: calibrate -> gptq quantize -> better task loss
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 3);
        let cap = calibrate(&cfg, &ckpt, &(0..128u32).map(|i| i % 64).collect::<Vec<_>>(), 128, 16)
            .unwrap();
        let w = ckpt.f32("layers.0.w2").unwrap();
        let h = &cap.hessians["layers.0.w2"];
        let gq = crate::quant::gptq::quantize(w, h, crate::quant::Bits::B4, 0.01).unwrap();
        let naive = crate::quant::uniform::quantize(
            w,
            crate::quant::Bits::B4,
            crate::quant::Granularity::PerChannel { axis: 1 },
        )
        .unwrap();
        let e_g = crate::quant::gptq::hessian_weighted_error(w, &gq, h);
        let e_n = crate::quant::gptq::hessian_weighted_error(w, &naive, h);
        assert!(e_g <= e_n, "gptq {e_g} !<= naive {e_n}");
    }
}
