//! Model layer (S1/S7 glue): checkpoint access, quantization of a full
//! checkpoint into a TQM container, and the weight-source abstraction the
//! pipeline streams layers from.

pub mod forward_f32;
pub mod layer;
pub mod moe;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{ModelConfig, QuantizeOptions};
use crate::format::{TqmMeta, TqmReader, TqmWriter};
use crate::quant::{gptq, uniform, Granularity, QuantizedTensor};
use crate::tensor::io::{read_tqw, TqwTensor};
use crate::tensor::Tensor;

pub use layer::{LayerWeights, LayerWeightsF32};

/// Fully-resident f32 weights (the unquantized baseline of Tables 2-4).
pub struct F32Weights {
    pub layers: Vec<LayerWeightsF32>,
    pub embed: Tensor,
    pub final_norm: Tensor,
    pub head: Tensor,
}

impl F32Weights {
    pub fn load(cfg: &ModelConfig, ckpt: &Checkpoint) -> Result<Self> {
        Ok(Self {
            layers: (0..cfg.n_layers)
                .map(|i| LayerWeightsF32::load(ckpt, i))
                .collect::<Result<Vec<_>>>()?,
            embed: ckpt.f32("embed.weight")?.clone(),
            final_norm: ckpt.f32("final_norm")?.clone(),
            head: ckpt.f32("head.weight")?.clone(),
        })
    }

    pub fn total_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.bytes()).sum::<usize>()
            + (self.embed.data.len() + self.final_norm.data.len() + self.head.data.len()) * 4
    }
}

/// Matrix tensors per layer, in the stage-argument contract order
/// (mirrors python/compile/model.py::LAYER_WEIGHT_ORDER minus the norms).
pub const MATRIX_NAMES: [&str; 7] = ["wq", "wk", "wv", "wo", "w1", "w3", "w2"];

/// An f32 checkpoint loaded from the TQW the python build exported.
pub struct Checkpoint {
    pub tensors: BTreeMap<String, TqwTensor>,
}

impl Checkpoint {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Ok(Self { tensors: read_tqw(path)? })
    }

    pub fn f32(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing {name:?}"))?
            .as_f32()
    }

    pub fn total_f32_bytes(&self) -> usize {
        self.tensors
            .values()
            .map(|t| crate::tensor::numel(t.shape()) * 4)
            .sum()
    }
}

/// Out-channel count for each matrix (the scale/zero vector length the
/// stage HLOs expect).
pub fn out_channels(cfg: &ModelConfig, name: &str) -> usize {
    match name {
        "wq" | "wo" | "w2" => cfg.d_model,
        "wk" | "wv" => cfg.kv_dim,
        "w1" | "w3" => cfg.d_ff,
        "embed.weight" => cfg.vocab, // per-ROW (axis 0) for the table
        "head.weight" => cfg.vocab,
        _ => panic!("not a matrix: {name}"),
    }
}

/// Quantize one named matrix with the configured scheme.
fn quantize_matrix(
    name: &str,
    w: &Tensor,
    opts: &QuantizeOptions,
    hessian: Option<&gptq::Hessian>,
) -> Result<QuantizedTensor> {
    // the embedding table is always per-row (a gather, not a matmul)
    let gran = if name == "embed.weight" {
        Granularity::PerChannel { axis: 0 }
    } else if opts.per_channel {
        Granularity::PerChannel { axis: 1 }
    } else {
        Granularity::PerTensor
    };
    if let Some(h) = hessian {
        // GPTQ only applies to matmul weights (always per out-channel)
        return gptq::quantize(w, h, opts.bits, opts.percdamp);
    }
    uniform::quantize(w, opts.bits, gran)
}

/// Quantize a full checkpoint and stage it for writing as `.tqm`.
///
/// `hessians` (from [`forward_f32::calibrate`]) switches matmul weights to
/// GPTQ; the embedding table always uses the naive per-row scheme (it is a
/// lookup, GPTQ's input-covariance model does not apply).
pub fn quantize_checkpoint(
    cfg: &ModelConfig,
    ckpt: &Checkpoint,
    opts: &QuantizeOptions,
    codec: crate::compress::CodecId,
    hessians: Option<&BTreeMap<String, gptq::Hessian>>,
    source: &str,
) -> Result<TqmWriter> {
    if opts.gptq && hessians.is_none() {
        bail!("gptq requested but no calibration hessians supplied");
    }
    let meta = TqmMeta {
        model_name: cfg.name.clone(),
        codec,
        bits: opts.bits,
        per_channel: opts.per_channel,
        quantizer: if opts.gptq { "gptq".into() } else { "naive".into() },
        source_checkpoint: source.to_string(),
    };
    let mut w = TqmWriter::new(meta);

    let get_h = |name: &str| hessians.and_then(|m| m.get(name));

    let embed = ckpt.f32("embed.weight").context("embed.weight")?;
    w.add_quantized("embed.weight", &quantize_matrix("embed.weight", embed, opts, None)?);

    for i in 0..cfg.n_layers {
        for ln in ["ln1", "ln2"] {
            let name = format!("layers.{i}.{ln}");
            w.add_f32(&name, ckpt.f32(&name)?);
        }
        for m in MATRIX_NAMES {
            let name = format!("layers.{i}.{m}");
            let t = ckpt.f32(&name)?;
            w.add_quantized(&name, &quantize_matrix(m, t, opts, get_h(&name))?);
        }
    }

    w.add_f32("final_norm", ckpt.f32("final_norm")?);
    let head = ckpt.f32("head.weight")?;
    w.add_quantized(
        "head.weight",
        &quantize_matrix("head.weight", head, opts, get_h("head.weight"))?,
    );
    Ok(w)
}

/// Where layer weights come from at serving time.
pub enum WeightSource {
    /// Lazy: decompress from the TQM container per request (streaming).
    Compressed(TqmReader),
    /// Eager: everything quantized in memory, expanded once (the paper's
    /// "Quantized" baseline) — built either from a TQM file or checkpoint.
    Resident(ResidentWeights),
}

pub struct ResidentWeights {
    pub layers: Vec<LayerWeights>,
    pub embed: QuantizedTensor,
    pub final_norm: Tensor,
    pub head: QuantizedTensor,
}

impl WeightSource {
    pub fn open_compressed(path: impl AsRef<Path>) -> Result<Self> {
        Ok(WeightSource::Compressed(TqmReader::open(path)?))
    }

    /// Fully expand a TQM container into memory (baseline mode).
    pub fn open_resident(path: impl AsRef<Path>, cfg: &ModelConfig) -> Result<Self> {
        let reader = TqmReader::open(path)?;
        let layers = (0..cfg.n_layers)
            .map(|i| LayerWeights::load(&reader, i))
            .collect::<Result<Vec<_>>>()?;
        Ok(WeightSource::Resident(ResidentWeights {
            embed: reader.load_quantized("embed.weight")?,
            final_norm: reader.load_f32("final_norm")?,
            head: reader.load_quantized("head.weight")?,
            layers,
        }))
    }

    pub fn meta_summary(&self) -> String {
        match self {
            WeightSource::Compressed(r) => format!(
                "compressed ({} tensors, {} on disk, {} expanded)",
                r.records().len(),
                r.file_bytes(),
                r.unpacked_bytes()
            ),
            WeightSource::Resident(rw) => {
                format!("resident ({} layers expanded)", rw.layers.len())
            }
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::compress::CodecId;
    use crate::quant::Bits;
    use crate::util::{Rng, TempDir};

    /// Synthesize a small checkpoint matching `cfg` dims.
    pub(crate) fn fake_checkpoint(cfg: &ModelConfig, seed: u64) -> Checkpoint {
        let mut rng = Rng::seed_from_u64(seed);
        let mut tensors = BTreeMap::new();
        fn add(
            tensors: &mut BTreeMap<String, TqwTensor>,
            name: String,
            shape: Vec<usize>,
            rng: &mut Rng,
        ) {
            let n = crate::tensor::numel(&shape);
            let std = 1.0 / (shape[0] as f32).sqrt();
            tensors.insert(
                name,
                TqwTensor::F32(Tensor::new(shape, rng.normal_vec(n, std)).unwrap()),
            );
        }
        let (d, f, v, kvd) = (cfg.d_model, cfg.d_ff, cfg.vocab, cfg.kv_dim);
        add(&mut tensors, "embed.weight".into(), vec![v, d], &mut rng);
        add(&mut tensors, "head.weight".into(), vec![d, v], &mut rng);
        for i in 0..cfg.n_layers {
            for (m, shape) in [
                ("wq", vec![d, d]),
                ("wk", vec![d, kvd]),
                ("wv", vec![d, kvd]),
                ("wo", vec![d, d]),
                ("w1", vec![d, f]),
                ("w3", vec![d, f]),
                ("w2", vec![f, d]),
            ] {
                add(&mut tensors, format!("layers.{i}.{m}"), shape, &mut rng);
            }
            for ln in ["ln1", "ln2"] {
                tensors.insert(
                    format!("layers.{i}.{ln}"),
                    TqwTensor::F32(Tensor::new(vec![d], vec![1.0; d]).unwrap()),
                );
            }
        }
        tensors.insert(
            "final_norm".into(),
            TqwTensor::F32(Tensor::new(vec![d], vec![1.0; d]).unwrap()),
        );
        Checkpoint { tensors }
    }

    pub(crate) fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "unit".into(),
            d_model: 16,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 32,
            vocab: 64,
            max_seq: 16,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
            head_dim: 4,
            kv_dim: 8,
            n_params: 0,
            prefill_t: vec![8],
            prefill_b: vec![1],
            decode_b: vec![1],
            moe: None,
        }
    }

    #[test]
    fn quantize_roundtrip_through_container() {
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 0);
        let opts = QuantizeOptions { per_channel: true, ..Default::default() };
        let w = quantize_checkpoint(&cfg, &ckpt, &opts, CodecId::Lzw, None, "unit").unwrap();
        let dir = TempDir::new().unwrap();
        let p = dir.join("m.tqm");
        w.write(&p).unwrap();

        let src = WeightSource::open_compressed(&p).unwrap();
        let WeightSource::Compressed(reader) = &src else { panic!() };
        assert_eq!(reader.meta.model_name, "unit");
        // all tensors present: embed + head + final_norm + layers*(2+7)
        assert_eq!(reader.records().len(), 3 + cfg.n_layers * 9);
        // layer loads and dequantizes close to the original
        let lw = LayerWeights::load(reader, 0).unwrap();
        let orig = ckpt.f32("layers.0.wq").unwrap();
        let deq = lw.wq.dequantize();
        assert!(orig.mse(&deq) < 1e-4);
    }

    #[test]
    fn resident_mode_expands_all_layers() {
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 1);
        let opts = QuantizeOptions::default();
        let w = quantize_checkpoint(&cfg, &ckpt, &opts, CodecId::FreqSeqPacked, None, "unit")
            .unwrap();
        let dir = TempDir::new().unwrap();
        let p = dir.join("m.tqm");
        w.write(&p).unwrap();
        let src = WeightSource::open_resident(&p, &cfg).unwrap();
        let WeightSource::Resident(rw) = &src else { panic!() };
        assert_eq!(rw.layers.len(), 2);
        assert_eq!(rw.embed.codes.shape, vec![cfg.vocab, cfg.d_model]);
    }

    #[test]
    fn gptq_without_hessians_rejected() {
        let cfg = tiny_cfg();
        let ckpt = fake_checkpoint(&cfg, 2);
        let opts = QuantizeOptions { gptq: true, ..Default::default() };
        assert!(quantize_checkpoint(&cfg, &ckpt, &opts, CodecId::Raw, None, "unit").is_err());
    }

    #[test]
    fn real_e2e_checkpoint_loads_if_built() {
        let root = crate::config::default_artifacts_root();
        let p = root.join("e2e/weights/e2e.tqw");
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ckpt = Checkpoint::load(&p).unwrap();
        assert!(ckpt.f32("embed.weight").is_ok());
        assert!(ckpt.f32("layers.0.wq").is_ok());
        assert!(ckpt.total_f32_bytes() > 1_000_000);
    }
}
