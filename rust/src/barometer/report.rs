//! Baseline diffing for `tqm bench-report`: pair up two recorded
//! `BENCH_*.json` sets by (area, bench name) and classify every cell as
//! regression / improvement / neutral against a noise threshold, plus
//! new / missing for cells only one side has. Classification is on
//! `mean_s` (lower is better); the rendered table carries p50/p99 so a
//! tail-only shift is still visible even when the mean calls it neutral.

use crate::util::bench::{fmt_secs, Table};

use super::schema::{BenchRecord, BenchSet};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiffClass {
    /// Slower than baseline beyond the noise threshold.
    Regression,
    /// Faster than baseline beyond the noise threshold.
    Improvement,
    /// Within the noise threshold either way.
    Neutral,
    /// Present now, absent from the baseline (first run / new bench).
    New,
    /// Present in the baseline, absent now (renamed or deleted bench).
    Missing,
}

impl DiffClass {
    pub fn label(&self) -> &'static str {
        match self {
            DiffClass::Regression => "REGRESSION",
            DiffClass::Improvement => "improvement",
            DiffClass::Neutral => "neutral",
            DiffClass::New => "new",
            DiffClass::Missing => "missing",
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct DiffOptions {
    /// Fractional mean_s change below which a cell is neutral (0.10 =
    /// ±10%). Single-box wall-clock numbers are noisy; anything tighter
    /// than ~5% flags phantom regressions on shared CI runners.
    pub noise_frac: f64,
    /// Absolute floor: ignore changes smaller than this many seconds
    /// regardless of ratio (a 2 µs bench doubling is still noise).
    pub min_delta_s: f64,
}

impl Default for DiffOptions {
    fn default() -> Self {
        Self { noise_frac: 0.10, min_delta_s: 1e-6 }
    }
}

#[derive(Clone, Debug)]
pub struct DiffRow {
    pub area: String,
    pub name: String,
    pub baseline: Option<BenchRecord>,
    pub current: Option<BenchRecord>,
    /// Fractional mean_s change, current vs baseline (+0.25 = 25% slower).
    pub delta_frac: Option<f64>,
    pub class: DiffClass,
}

fn classify(base: &BenchRecord, cur: &BenchRecord, opts: &DiffOptions) -> (Option<f64>, DiffClass) {
    let b = base.mean_s;
    let c = cur.mean_s;
    if b <= 0.0 || !b.is_finite() || !c.is_finite() {
        return (None, DiffClass::Neutral);
    }
    let frac = (c - b) / b;
    let class = if (c - b).abs() < opts.min_delta_s || frac.abs() <= opts.noise_frac {
        DiffClass::Neutral
    } else if frac > 0.0 {
        DiffClass::Regression
    } else {
        DiffClass::Improvement
    };
    (Some(frac), class)
}

/// Diff two recorded sets. Every (area, name) appearing on either side
/// produces exactly one row; an empty baseline yields all-`New` (the
/// first-run case). Rows are sorted worst-first: regressions, then
/// missing, then new, improvements, neutral.
pub fn diff_sets(baseline: &[BenchSet], current: &[BenchSet], opts: &DiffOptions) -> Vec<DiffRow> {
    use std::collections::BTreeMap;
    let mut keys: BTreeMap<(String, String), (Option<BenchRecord>, Option<BenchRecord>)> =
        BTreeMap::new();
    for set in baseline {
        for r in &set.records {
            keys.entry((set.area.clone(), r.name.clone())).or_default().0 = Some(r.clone());
        }
    }
    for set in current {
        for r in &set.records {
            keys.entry((set.area.clone(), r.name.clone())).or_default().1 = Some(r.clone());
        }
    }
    let mut rows: Vec<DiffRow> = keys
        .into_iter()
        .map(|((area, name), (base, cur))| {
            let (delta_frac, class) = match (&base, &cur) {
                (Some(b), Some(c)) => classify(b, c, opts),
                (None, Some(_)) => (None, DiffClass::New),
                (Some(_), None) => (None, DiffClass::Missing),
                (None, None) => unreachable!("key without either side"),
            };
            DiffRow { area, name, baseline: base, current: cur, delta_frac, class }
        })
        .collect();
    let rank = |c: DiffClass| match c {
        DiffClass::Regression => 0,
        DiffClass::Missing => 1,
        DiffClass::New => 2,
        DiffClass::Improvement => 3,
        DiffClass::Neutral => 4,
    };
    rows.sort_by(|a, b| {
        rank(a.class)
            .cmp(&rank(b.class))
            .then_with(|| {
                // within regressions/improvements, biggest change first
                let da = a.delta_frac.map(|d| d.abs()).unwrap_or(0.0);
                let db = b.delta_frac.map(|d| d.abs()).unwrap_or(0.0);
                db.total_cmp(&da)
            })
            .then_with(|| {
                (a.area.as_str(), a.name.as_str()).cmp(&(b.area.as_str(), b.name.as_str()))
            })
    });
    rows
}

/// Render a diff as the repo's standard aligned table.
pub fn render_diff(rows: &[DiffRow], opts: &DiffOptions) -> Table {
    let title = format!(
        "bench-report (noise ±{:.0}%, {} benchmarks)",
        opts.noise_frac * 100.0,
        rows.len()
    );
    let headers = ["area", "bench", "base mean", "cur mean", "delta", "p99 cur", "class"];
    let mut t = Table::new(&title, &headers);
    let fmt_opt = |r: &Option<BenchRecord>, f: fn(&BenchRecord) -> f64| -> String {
        match r {
            Some(rec) => fmt_secs(f(rec)),
            None => "-".to_string(),
        }
    };
    for row in rows {
        let delta = match row.delta_frac {
            Some(d) => format!("{:+.1}%", d * 100.0),
            None => "-".to_string(),
        };
        t.row(vec![
            row.area.clone(),
            row.name.clone(),
            fmt_opt(&row.baseline, |r| r.mean_s),
            fmt_opt(&row.current, |r| r.mean_s),
            delta,
            fmt_opt(&row.current, |r| r.p99_s),
            row.class.label().to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &str, mean_s: f64) -> BenchRecord {
        BenchRecord::single(name, 10, mean_s * 10.0)
    }

    fn set(area: &str, recs: Vec<BenchRecord>) -> BenchSet {
        let mut s = BenchSet::new(area);
        for r in recs {
            s.push(r);
        }
        s
    }

    #[test]
    fn classifies_regression_improvement_neutral() {
        let base = [set("a", vec![rec("slow", 1.0), rec("fast", 1.0), rec("same", 1.0)])];
        let cur = [set("a", vec![rec("slow", 1.5), rec("fast", 0.5), rec("same", 1.02)])];
        let rows = diff_sets(&base, &cur, &DiffOptions::default());
        let by_name = |n: &str| rows.iter().find(|r| r.name == n).unwrap().class;
        assert_eq!(by_name("slow"), DiffClass::Regression);
        assert_eq!(by_name("fast"), DiffClass::Improvement);
        assert_eq!(by_name("same"), DiffClass::Neutral);
        // worst first
        assert_eq!(rows[0].class, DiffClass::Regression);
    }

    #[test]
    fn noise_threshold_is_configurable() {
        let base = [set("a", vec![rec("x", 1.0)])];
        let cur = [set("a", vec![rec("x", 1.15)])];
        let loose = DiffOptions { noise_frac: 0.20, ..Default::default() };
        let tight = DiffOptions { noise_frac: 0.05, ..Default::default() };
        assert_eq!(diff_sets(&base, &cur, &loose)[0].class, DiffClass::Neutral);
        assert_eq!(diff_sets(&base, &cur, &tight)[0].class, DiffClass::Regression);
    }

    #[test]
    fn absolute_floor_mutes_microsecond_flapping() {
        let base = [set("a", vec![rec("tiny", 2e-7)])];
        let cur = [set("a", vec![rec("tiny", 6e-7)])]; // 3x, but < 1 µs
        assert_eq!(diff_sets(&base, &cur, &DiffOptions::default())[0].class, DiffClass::Neutral);
    }

    #[test]
    fn empty_baseline_yields_all_new() {
        let cur = [set("a", vec![rec("x", 1.0), rec("y", 2.0)])];
        let rows = diff_sets(&[], &cur, &DiffOptions::default());
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.class == DiffClass::New));
    }

    #[test]
    fn missing_and_cross_area_keys_do_not_collide() {
        let base = [set("a", vec![rec("x", 1.0)]), set("b", vec![rec("x", 1.0)])];
        let cur = [set("a", vec![rec("x", 1.0)])];
        let rows = diff_sets(&base, &cur, &DiffOptions::default());
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().any(|r| r.area == "b" && r.class == DiffClass::Missing));
        assert!(rows.iter().any(|r| r.area == "a" && r.class == DiffClass::Neutral));
    }

    #[test]
    fn render_has_one_line_per_row() {
        let cur = [set("a", vec![rec("x", 1.0)])];
        let rows = diff_sets(&[], &cur, &DiffOptions::default());
        let s = render_diff(&rows, &DiffOptions::default()).render();
        assert!(s.contains("new"));
        assert!(s.contains("x"));
    }
}
