//! On-disk schema for `BENCH_<area>.json`: a [`BenchSet`] is one bench
//! binary's run — an area name, an environment fingerprint, and one
//! [`BenchRecord`] per measured cell. Serialization goes through
//! `util::Json` (the repo is offline; no serde), and the golden tests in
//! `tests/integration_barometer.rs` pin the round trip field-exact so a
//! schema drift breaks loudly instead of skewing every future diff.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::bench::Measurement;
use crate::util::Json;

/// Schema version stamped into every file; bump on incompatible change.
pub const SCHEMA_VERSION: u32 = 1;

/// What the numbers were measured on: enough context to decide whether
/// two recorded sets are comparable at all.
#[derive(Clone, Debug, PartialEq)]
pub struct EnvFingerprint {
    /// Logical cores visible to the process.
    pub cores: usize,
    /// "release" or "debug" — debug numbers must never be diffed against
    /// release baselines.
    pub profile: String,
    /// Every `TQM_*` env var set at record time (the knob settings),
    /// sorted by name.
    pub knobs: BTreeMap<String, String>,
}

impl EnvFingerprint {
    pub fn capture() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let profile = if cfg!(debug_assertions) { "debug" } else { "release" };
        let knobs = std::env::vars().filter(|(k, _)| k.starts_with("TQM_")).collect();
        Self { cores, profile: profile.to_string(), knobs }
    }

    fn to_json(&self) -> Json {
        let knobs =
            self.knobs.iter().map(|(k, v)| (k.clone(), Json::str(v.clone()))).collect();
        Json::obj(vec![
            ("cores", Json::num(self.cores as f64)),
            ("profile", Json::str(self.profile.clone())),
            ("knobs", Json::Obj(knobs)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        let mut knobs = BTreeMap::new();
        match j.get("knobs")? {
            Json::Obj(map) => {
                for (k, v) in map {
                    knobs.insert(k.clone(), v.as_str()?.to_string());
                }
            }
            other => bail!("env.knobs: expected object, got {}", other.to_string()),
        }
        Ok(Self {
            cores: j.get("cores")?.as_usize()?,
            profile: j.get("profile")?.as_str()?.to_string(),
            knobs,
        })
    }
}

/// One measured benchmark cell.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Cell name, unique within the area (e.g. "decompress/freqseq/t4").
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
    /// Optional derived rate in `throughput_units` (e.g. 850.0 "MB/s").
    pub throughput: Option<f64>,
    pub throughput_units: Option<String>,
}

impl BenchRecord {
    pub fn from_measurement(m: &Measurement) -> Self {
        Self {
            name: m.name.clone(),
            iters: m.iters,
            mean_s: m.mean_s,
            p50_s: m.p50_s,
            p95_s: m.p95_s,
            p99_s: m.p99_s,
            min_s: m.min_s,
            throughput: None,
            throughput_units: None,
        }
    }

    pub fn with_throughput(mut self, value: f64, units: &str) -> Self {
        self.throughput = Some(value);
        self.throughput_units = Some(units.to_string());
        self
    }

    /// Record for a bench that only measured one aggregate duration
    /// (`total_s` over `iters` calls) — all quantiles collapse to the
    /// per-iteration mean. Honest for throughput-style loops that don't
    /// keep per-call samples.
    pub fn single(name: &str, iters: usize, total_s: f64) -> Self {
        let per = total_s / iters.max(1) as f64;
        Self {
            name: name.to_string(),
            iters,
            mean_s: per,
            p50_s: per,
            p95_s: per,
            p99_s: per,
            min_s: per,
            throughput: None,
            throughput_units: None,
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_s", Json::num(self.mean_s)),
            ("p50_s", Json::num(self.p50_s)),
            ("p95_s", Json::num(self.p95_s)),
            ("p99_s", Json::num(self.p99_s)),
            ("min_s", Json::num(self.min_s)),
        ];
        if let (Some(v), Some(u)) = (self.throughput, &self.throughput_units) {
            pairs.push(("throughput", Json::num(v)));
            pairs.push(("throughput_units", Json::str(u.clone())));
        }
        Json::obj(pairs)
    }

    fn from_json(j: &Json) -> Result<Self> {
        let throughput = match j.opt("throughput") {
            Some(v) => Some(v.as_f64()?),
            None => None,
        };
        let throughput_units = match j.opt("throughput_units") {
            Some(v) => Some(v.as_str()?.to_string()),
            None => None,
        };
        Ok(Self {
            name: j.get("name")?.as_str()?.to_string(),
            iters: j.get("iters")?.as_usize()?,
            mean_s: j.get("mean_s")?.as_f64()?,
            p50_s: j.get("p50_s")?.as_f64()?,
            p95_s: j.get("p95_s")?.as_f64()?,
            p99_s: j.get("p99_s")?.as_f64()?,
            min_s: j.get("min_s")?.as_f64()?,
            throughput,
            throughput_units,
        })
    }
}

/// One bench binary's recorded run: `BENCH_<area>.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchSet {
    pub area: String,
    pub env: EnvFingerprint,
    pub records: Vec<BenchRecord>,
}

impl BenchSet {
    pub fn new(area: &str) -> Self {
        Self { area: area.to_string(), env: EnvFingerprint::capture(), records: Vec::new() }
    }

    pub fn push(&mut self, r: BenchRecord) {
        self.records.push(r);
    }

    pub fn push_measurement(&mut self, m: &Measurement) {
        self.records.push(BenchRecord::from_measurement(m));
    }

    pub fn file_name(&self) -> String {
        format!("BENCH_{}.json", self.area)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::num(SCHEMA_VERSION as f64)),
            ("area", Json::str(self.area.clone())),
            ("env", self.env.to_json()),
            ("benchmarks", Json::Arr(self.records.iter().map(|r| r.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let ver = j.get("schema_version")?.as_u32()?;
        if ver != SCHEMA_VERSION {
            bail!("unsupported bench schema version {ver} (this build reads {SCHEMA_VERSION})");
        }
        let records = j
            .get("benchmarks")?
            .as_arr()?
            .iter()
            .map(BenchRecord::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            area: j.get("area")?.as_str()?.to_string(),
            env: EnvFingerprint::from_json(j.get("env")?)?,
            records,
        })
    }

    /// Write `BENCH_<area>.json` into `dir`, creating it if needed.
    pub fn write_to(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating bench dir {}", dir.display()))?;
        let path = dir.join(self.file_name());
        std::fs::write(&path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&j).with_context(|| format!("decoding {}", path.display()))
    }
}

/// Load every `BENCH_*.json` in `dir`, sorted by area. A missing
/// directory is an empty set (the first-run / no-baseline case); a
/// malformed file is a hard error — silently skipping a corrupt record
/// would turn a real regression into "missing, probably fine".
pub fn load_dir(dir: &Path) -> Result<Vec<BenchSet>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            out.push(BenchSet::load(&path)?);
        }
    }
    out.sort_by(|a, b| a.area.cmp(&b.area));
    Ok(out)
}

/// Write `set` into `$TQM_BENCH_DIR` if the knob is set; returns the
/// path written, or `None` when recording is off. Bench binaries call
/// this unconditionally after printing their human tables.
pub fn emit(set: &BenchSet) -> Result<Option<PathBuf>> {
    match crate::util::env_parse_opt::<PathBuf>(super::BENCH_DIR_VAR)? {
        Some(dir) => {
            let path = set.write_to(&dir)?;
            eprintln!("[barometer] wrote {}", path.display());
            Ok(Some(path))
        }
        None => Ok(None),
    }
}

/// Write an arbitrary pre-built JSON artifact (e.g. the
/// `METRICS_<run>.json` counter snapshot a serving run emits at
/// shutdown) into `$TQM_BENCH_DIR` if the knob is set. The caller owns
/// the schema versioning inside `j`; this only owns the placement next
/// to the `BENCH_*.json` files so one directory carries both timings and
/// counters.
pub fn emit_named(file_name: &str, j: &Json) -> Result<Option<PathBuf>> {
    match crate::util::env_parse_opt::<PathBuf>(super::BENCH_DIR_VAR)? {
        Some(dir) => {
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating bench dir {}", dir.display()))?;
            let path = dir.join(file_name);
            std::fs::write(&path, j.to_string())
                .with_context(|| format!("writing {}", path.display()))?;
            eprintln!("[barometer] wrote {}", path.display());
            Ok(Some(path))
        }
        None => Ok(None),
    }
}
