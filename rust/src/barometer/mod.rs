//! Perf barometer (rebar-style, after BurntSushi/rebar's methodology):
//! every bench binary serializes its measurements to a machine-readable
//! `BENCH_<area>.json` — name, iteration count, mean/p50/p95/p99,
//! throughput, plus an environment fingerprint (cores, build profile,
//! every `TQM_*` knob in effect) — and `tqm bench-report` diffs two
//! recorded sets into a regression / improvement / neutral table with a
//! configurable noise threshold.
//!
//! The point is trajectory, not absolute truth: any single number from a
//! laptop is noise, but the same bench recorded per PR on the same box
//! turns "should be faster" into a measured row. The env fingerprint is
//! what makes two sets comparable — a diff across different core counts
//! or knob settings is flagged rather than trusted.
//!
//! Recording is opt-in via `TQM_BENCH_DIR`: benches print their tables as
//! always, and additionally write `BENCH_<area>.json` into that directory
//! when it is set.

mod report;
mod schema;

pub use report::{render_diff, diff_sets, DiffClass, DiffOptions, DiffRow};
pub use schema::{emit, emit_named, load_dir, BenchRecord, BenchSet, EnvFingerprint};

/// Env var naming the directory benches write `BENCH_<area>.json` into.
pub const BENCH_DIR_VAR: &str = "TQM_BENCH_DIR";

/// Env var overriding the diff noise threshold (fraction, default 0.10).
pub const BENCH_NOISE_VAR: &str = "TQM_BENCH_NOISE";
