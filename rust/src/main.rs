//! `tqm` — the Tiny-QMoE command line.
//!
//! Subcommands (hand-rolled parser; the vendored crate set has no clap):
//!
//!   tqm quantize  --model e2e [--bits 8] [--per-channel] [--gptq]
//!                 [--codec freqseq-packed] [--out tag]
//!   tqm inspect   --file model.tqm
//!   tqm eval      --model e2e --variant fp32|quant|compressed
//!                 [--task mmlu|arc-challenge|arc-easy] [--limit N]
//!   tqm generate  --model e2e [--prompt-tokens 1,2,3] [--max-new 32]
//!                 [--variant compressed] [--top-k 8] [--temp 0.8]
//!   tqm serve-demo --model e2e [--requests 16] [--batch 4]
//!                 [--threads 0] [--prefetch-depth 1]
//!                 [--expert-residency decoded|packed]
//!   tqm tables    --table 1|2|3|4|bits|codec|network|residency|moe|sched|zipf|faults|envelope|load|all
//!                 [--tokens 512]   (residency/moe/sched/zipf/faults/envelope/load: trace length)
//!                 [--batch 4]      (sched/faults: concurrent sequences)
//!                 [--alpha 1.1]    (zipf: popularity skew)
//!                 [--requests 8]   (envelope: concurrent traces per cell)
//!                 [--clients 8] [--tenants 4] [--seed 0]
//!                                  (load: concurrent clients / zipf tenants)
//!   tqm bench-report --current DIR [--baseline DIR] [--noise 0.10]
//!                 (diff two recorded BENCH_*.json sets; no --baseline =
//!                  first run, everything reports as "new")
//!   tqm trace-report --trace FILE [--baseline FILE] [--noise 0.10]
//!                 [--max-requests 20]
//!                 (reconstruct per-request waterfalls + critical-path
//!                  stage attribution from a recorded TRACE_*.json;
//!                  --baseline diffs two traces like bench-report)
//!
//! `--table faults` replays a seeded chaos matrix (fault rate x retry
//! budget) through the scheduler: completion rate, p99 added latency,
//! retries and quarantine counts per cell.
//!
//! `--table load` is the overload generator: concurrent closed-loop
//! clients with zipfian tenant skew drive a bounded `MoeHost` at
//! 0.5x–4x of calibrated capacity, reporting per-tenant token-latency
//! percentiles, shed/reject/timeout counts, goodput, and the admission
//! identity line per cell (the CI overload-smoke gate greps for `[OK]`).
//!
//! `--table envelope` runs the full MoE serving loop once per simulated
//! device cell — 4/6/8 GB-class byte budgets x 1–8 cores x
//! offline/flaky network — and prints per-step latency percentiles,
//! throughput and cache behaviour for each.
//!
//! `--table residency` prints the host-side expert residency table
//! (decoded vs packed expert cache at equal byte budget) followed by the
//! artifact-dependent E8 layer-residency sweep.
//!
//! Run from anywhere inside the repo (artifacts are auto-discovered) after
//! `make artifacts`.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};
use tiny_qmoe::compress::CodecId;
use tiny_qmoe::config::{
    default_artifacts_root, ExpertResidency, QuantizeOptions, Residency, ServeOptions,
};
use tiny_qmoe::gen::SamplerKind;
use tiny_qmoe::quant::Bits;
use tiny_qmoe::tables;
use tiny_qmoe::util::bench::fmt_bytes;

struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".into());
    let mut flags = HashMap::new();
    while let Some(a) = it.next() {
        let Some(key) = a.strip_prefix("--") else {
            bail!("unexpected positional argument {a:?}");
        };
        const BOOLS: [&str; 4] = ["per-channel", "gptq", "check", "paper-codec"];
        if BOOLS.contains(&key) {
            flags.insert(key.to_string(), "true".into());
        } else {
            let v = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key.to_string(), v);
        }
    }
    Ok(Args { cmd, flags })
}

impl Args {
    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn parse_bits(s: &str) -> Result<Bits> {
    Ok(match s {
        "ternary" | "1.5" => Bits::Ternary,
        "2" => Bits::B2,
        "4" => Bits::B4,
        "6" => Bits::B6,
        "8" => Bits::B8,
        _ => bail!("bad --bits {s:?} (ternary|2|4|6|8)"),
    })
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = parse_args()?;
    // arm the flight recorder once for every subcommand — a malformed
    // TQM_TRACE_* knob should fail the run loudly, not record nothing
    tiny_qmoe::trace::init_from_env()?;
    match args.cmd.as_str() {
        "quantize" => cmd_quantize(&args),
        "inspect" => cmd_inspect(&args),
        "eval" => cmd_eval(&args),
        "generate" => cmd_generate(&args),
        "serve-demo" => cmd_serve_demo(&args),
        "tables" => cmd_tables(&args),
        "bench-report" => cmd_bench_report(&args),
        "trace-report" => cmd_trace_report(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

const HELP: &str = "tqm — Tiny-QMoE reproduction CLI
  quantize | inspect | eval | generate | serve-demo | tables | bench-report | trace-report
  (see rust/src/main.rs header for flags)";

fn cmd_quantize(args: &Args) -> Result<()> {
    let model = args.get("model", "e2e");
    let codec = CodecId::parse(&args.get("codec", "freqseq-packed"))?;
    let opts = QuantizeOptions {
        bits: parse_bits(&args.get("bits", "8"))?,
        per_channel: args.has("per-channel"),
        gptq: args.has("gptq"),
        percdamp: 0.01,
        calib_tokens: args.get_usize("calib-tokens", 4096)?,
    };
    let default_tag = format!(
        "{model}-{}-{}{}",
        opts.bits.label(),
        format!("{codec:?}").to_lowercase(),
        if opts.gptq { "-gptq" } else { "" }
    );
    let tag = args.get("out", &default_tag);
    let t0 = std::time::Instant::now();
    let path = tables::ensure_tqm(&model, &opts, codec, &tag)?;
    let reader = tiny_qmoe::format::TqmReader::open(&path)?;
    println!("wrote {path:?} in {:.1}s", t0.elapsed().as_secs_f64());
    println!(
        "  {} compressed / {} quantized ({:.2}x), dict {}",
        fmt_bytes(reader.file_bytes()),
        fmt_bytes(reader.unpacked_bytes()),
        reader.unpacked_bytes() as f64 / reader.file_bytes() as f64,
        fmt_bytes(reader.dict_bytes()),
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let file = args.get("file", "");
    anyhow::ensure!(!file.is_empty(), "--file required");
    let r = tiny_qmoe::format::TqmReader::open(&file)?;
    println!(
        "model {} | codec {:?} | bits {:?} | quantizer {} | {} tensors",
        r.meta.model_name,
        r.codec_id,
        r.meta.bits,
        r.meta.quantizer,
        r.records().len()
    );
    println!(
        "file {} | expanded {} | dict {}",
        fmt_bytes(r.file_bytes()),
        fmt_bytes(r.unpacked_bytes()),
        fmt_bytes(r.dict_bytes())
    );
    for rec in r.records() {
        println!(
            "  {:32} {:?} {:?} raw {} payload {} ({:.2}x)",
            rec.name,
            rec.kind,
            rec.shape,
            fmt_bytes(rec.raw_len),
            fmt_bytes(rec.payload_len),
            rec.raw_len as f64 / rec.payload_len.max(1) as f64
        );
    }
    Ok(())
}

fn parse_variant(s: &str) -> Result<tables::Variant> {
    Ok(match s {
        "fp32" => tables::Variant::Fp32,
        "quant" | "quantized" => tables::Variant::Quantized,
        "compressed" => tables::Variant::Compressed,
        _ => bail!("bad --variant {s:?} (fp32|quant|compressed)"),
    })
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get("model", "e2e");
    let task = args.get("task", "arc-easy");
    let limit = args.get_usize("limit", 200)?;
    let variant = parse_variant(&args.get("variant", "compressed"))?;
    let codec = CodecId::parse(&args.get("codec", "freqseq-packed"))?;
    let reps = tables::eval_table(&model, &task, &[variant], codec, limit)?;
    tables::render_eval_table(&format!("{task} — {model}"), &reps).print();
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let model = args.get("model", "e2e");
    let variant = parse_variant(&args.get("variant", "compressed"))?;
    let codec = CodecId::parse(&args.get("codec", "freqseq-packed"))?;
    let max_new = args.get_usize("max-new", 24)?;
    let engine = tables::build_engine(&model, variant, codec)?;
    let root = default_artifacts_root();
    let data = tiny_qmoe::data::DataDir::open_for_vocab(&root, engine.cfg().vocab)?;

    let prompt: Vec<u32> = match args.flags.get("prompt-tokens") {
        Some(s) => s.split(',').map(|t| t.parse::<u32>()).collect::<Result<_, _>>()?,
        None => {
            // a natural SynthLang prompt: BOS Q k7 A  (model should answer)
            let sp = &data.lang.special;
            vec![sp.bos, sp.q, data.lang.key_base + 7, sp.a]
        }
    };
    let mut sampler = if args.has("top-k") {
        tiny_qmoe::gen::Sampler::top_k(
            args.get_usize("top-k", 8)?,
            args.get("temp", "0.8").parse()?,
            42,
        )
    } else {
        tiny_qmoe::gen::Sampler::greedy()
    };
    let g = tiny_qmoe::gen::generate(&engine, &prompt, max_new, &mut sampler, None)?;
    println!("variant: {}", engine.variant());
    println!("prompt : {}", data.detok(&prompt));
    println!("output : {}", data.detok(&g.tokens));
    println!(
        "prefill {:.1} ms | decode {:.1} ms | {:.1} tok/s",
        g.prefill_s * 1e3,
        g.decode_s * 1e3,
        g.tokens_per_s
    );
    println!("pipeline: {}", engine.metrics.summary());
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    let model = args.get("model", "e2e");
    let n_requests = args.get_usize("requests", 16)?;
    let batch = args.get_usize("batch", 4)?;
    let codec = CodecId::parse(&args.get("codec", "freqseq-packed"))?;
    let root = default_artifacts_root();
    let tag = format!("{model}-b8-{codec:?}").to_lowercase();
    let tqm = tables::ensure_tqm(&model, &QuantizeOptions::default(), codec, &tag)?;

    let mut coord = tiny_qmoe::coordinator::Coordinator::new();
    coord.register(tiny_qmoe::coordinator::ModelSpec {
        name: model.clone(),
        artifacts_root: root.clone(),
        manifest_model: model.clone(),
        tqm_path: tqm,
        serve: ServeOptions {
            residency: Residency::StreamPerLayer,
            prefetch_depth: args.get_usize("prefetch-depth", 1)?,
            n_threads: args.get_usize("threads", 0)?,
            max_batch: batch,
            max_wait_ms: 4,
            max_new_tokens: 16,
            expert_residency: ExpertResidency::parse(
                &args.get("expert-residency", "decoded"),
            )?,
            ..Default::default()
        },
    })?;
    let data = tiny_qmoe::data::DataDir::open_for_vocab(
        &root,
        tiny_qmoe::config::Manifest::load(&root, &model)?.config.vocab,
    )?;
    let sp = data.lang.special.clone();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            coord
                .submit(
                    &model,
                    tiny_qmoe::coordinator::GenRequest {
                        prompt: vec![sp.bos, sp.q, data.lang.key_base + (i as u32 % 16), sp.a],
                        max_new: 8,
                        sampler: SamplerKind::Greedy,
                        seed: i as u64,
                        stop_token: Some(sp.sep),
                    },
                )
                .unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().unwrap()?;
        println!(
            "req {i:2}: {:28} queue {:5.1} ms prefill {:6.1} ms decode {:6.1} ms",
            data.detok(&r.tokens),
            r.queue_s * 1e3,
            r.prefill_s * 1e3,
            r.decode_s * 1e3
        );
    }
    let snap = coord.metrics(&model).unwrap().snapshot();
    println!(
        "\n{} requests, {} tokens | mean batch {:.2} | decode p50 {:.1} ms p95 {:.1} ms | {:.1} tok/s",
        snap.requests,
        snap.tokens_out,
        snap.mean_batch_size,
        snap.decode.p50 * 1e3,
        snap.decode.p95 * 1e3,
        snap.tokens_per_s
    );
    if let Some(pm) = coord.pipeline_metrics(&model) {
        println!("pipeline: {}", pm.summary());
    }
    coord.shutdown();
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let which = args.get("table", "all");
    let limit = args.get_usize("limit", tables::eval_limit()?)?;
    let model = args.get("model", "e2e");
    let codec = if args.has("paper-codec") {
        tables::paper_codec()
    } else {
        tables::default_codec()
    };
    let t1 = || -> Result<()> {
        let rows = tables::table1(&["e2e", "proxy-1b", "proxy-3b"], codec)?;
        tables::render_table1(&rows, codec).print();
        let crows = tables::table1_clustered(codec)?;
        let mut ct = tiny_qmoe::util::bench::Table::new(
            "Table 1 companion — codec ratio vs weight-stream entropy regime",
            &["regime", "entropy (bits/B)", "ratio vs quantized"],
        );
        for r in &crows {
            ct.row(vec![
                r.regime.clone(),
                format!("{:.2}", r.entropy_bits),
                format!("{:.2}x", r.ratio_quant),
            ]);
        }
        ct.print();
        Ok(())
    };
    let eval_t = |family: &str, paper: &str| -> Result<()> {
        let reps = tables::eval_table(&model, family, &tables::Variant::ALL, codec, limit)?;
        tables::render_eval_table(&format!("{family} ({paper}) — {model}"), &reps).print();
        Ok(())
    };
    match which.as_str() {
        "1" => t1()?,
        "2" => eval_t("mmlu", "paper Table 2")?,
        "3" => eval_t("arc-challenge", "paper Table 3")?,
        "4" => eval_t("arc-easy", "paper Table 4")?,
        "bits" => {
            let rows = tables::ablation_bits(&model, true, limit)?;
            tables::render_bits(&rows).print();
        }
        "codec" => {
            let rows = tables::ablation_codec(&model)?;
            tables::render_codec(&rows).print();
        }
        "network" => tables::network_table(&model, codec, limit)?.print(),
        "residency" => {
            // host-side expert residency table first (runs anywhere),
            // then the artifact-dependent E8 layer-residency sweep
            let rows = tables::expert_residency_table(args.get_usize("tokens", 512)?)?;
            tables::render_expert_residency(&rows).print();
            let rows = tables::residency_table(&model, codec, limit.min(10))?;
            tables::render_residency(&rows).print();
        }
        "moe" => {
            let rows = tables::moe_table(args.get_usize("tokens", 512)?)?;
            tables::render_moe(&rows).print();
        }
        "sched" => {
            let rows = tables::sched_table(
                args.get_usize("tokens", 256)?,
                args.get_usize("batch", 4)?,
            )?;
            tables::render_sched(&rows).print();
        }
        "zipf" => {
            let alpha: f64 = args.get("alpha", "1.1").parse()?;
            let rows = tables::zipf_table(alpha, args.get_usize("tokens", 2000)?)?;
            tables::render_zipf(&rows, alpha).print();
        }
        "faults" => {
            let rows = tables::faults_table(
                args.get_usize("tokens", 64)?,
                args.get_usize("batch", 4)?,
            )?;
            tables::render_faults(&rows).print();
        }
        "envelope" => {
            let rows = tables::envelope_table(
                args.get_usize("tokens", 24)?,
                args.get_usize("requests", 8)?,
            )?;
            tables::render_envelope(&rows).print();
        }
        "load" => {
            let seed: u64 = args.get("seed", "0").parse()?;
            let (rows, identities) = tables::load_table(
                args.get_usize("clients", 8)?,
                args.get_usize("tenants", 4)?,
                args.get_usize("tokens", 8)?,
                seed,
            )?;
            tables::render_load(&rows).print();
            for line in &identities {
                println!("{line}");
            }
        }
        "all" => {
            t1()?;
            eval_t("mmlu", "paper Table 2")?;
            eval_t("arc-challenge", "paper Table 3")?;
            eval_t("arc-easy", "paper Table 4")?;
            let rows = tables::ablation_bits(&model, false, limit)?;
            tables::render_bits(&rows).print();
            let rows = tables::ablation_codec(&model)?;
            tables::render_codec(&rows).print();
            tables::network_table(&model, codec, limit)?.print();
            let rows = tables::residency_table(&model, codec, limit.min(10))?;
            tables::render_residency(&rows).print();
            let rows = tables::expert_residency_table(512)?;
            tables::render_expert_residency(&rows).print();
            let rows = tables::moe_table(512)?;
            tables::render_moe(&rows).print();
            let rows = tables::sched_table(256, 4)?;
            tables::render_sched(&rows).print();
            let rows = tables::zipf_table(1.1, 2000)?;
            tables::render_zipf(&rows, 1.1).print();
            let rows = tables::faults_table(64, 4)?;
            tables::render_faults(&rows).print();
            let rows = tables::envelope_table(24, 4)?;
            tables::render_envelope(&rows).print();
            let (rows, identities) = tables::load_table(4, 2, 4, 0)?;
            tables::render_load(&rows).print();
            for line in &identities {
                println!("{line}");
            }
        }
        other => bail!("unknown table {other:?}"),
    }
    Ok(())
}

fn cmd_bench_report(args: &Args) -> Result<()> {
    use tiny_qmoe::barometer;

    let current_dir = args.get("current", "");
    anyhow::ensure!(
        !current_dir.is_empty(),
        "--current <dir> required (a directory of BENCH_*.json files)"
    );
    let noise = match args.flags.get("noise") {
        Some(v) => v.parse::<f64>().with_context(|| format!("bad --noise {v:?}"))?,
        None => tiny_qmoe::util::env_parse(barometer::BENCH_NOISE_VAR, 0.10)?,
    };
    let opts = tiny_qmoe::barometer::DiffOptions { noise_frac: noise, ..Default::default() };
    let current = barometer::load_dir(std::path::Path::new(&current_dir))?;
    anyhow::ensure!(!current.is_empty(), "no BENCH_*.json files found in {current_dir:?}");
    let baseline_dir = args.get("baseline", "");
    let baseline = if baseline_dir.is_empty() {
        Vec::new()
    } else {
        barometer::load_dir(std::path::Path::new(&baseline_dir))?
    };
    if baseline.is_empty() {
        println!("(no baseline set — first run, every benchmark reports as \"new\")");
    }
    // a diff across different machines/knobs is a trap, not a regression:
    // flag fingerprint mismatches up front
    for cur in &current {
        if let Some(base) = baseline.iter().find(|b| b.area == cur.area) {
            if base.env != cur.env {
                eprintln!(
                    "warning: area {:?} recorded under a different environment \
                     (baseline: {} cores/{}, current: {} cores/{}) — treat the diff \
                     with suspicion",
                    cur.area, base.env.cores, base.env.profile, cur.env.cores, cur.env.profile
                );
            }
        }
    }
    let rows = barometer::diff_sets(&baseline, &current, &opts);
    barometer::render_diff(&rows, &opts).print();
    use tiny_qmoe::barometer::DiffClass;
    let count = |c: DiffClass| rows.iter().filter(|r| r.class == c).count();
    println!(
        "\n{} regression(s), {} improvement(s), {} neutral, {} new, {} missing",
        count(DiffClass::Regression),
        count(DiffClass::Improvement),
        count(DiffClass::Neutral),
        count(DiffClass::New),
        count(DiffClass::Missing),
    );
    Ok(())
}

fn cmd_trace_report(args: &Args) -> Result<()> {
    use tiny_qmoe::trace::{chrome, report};

    let trace_path = args.get("trace", "");
    anyhow::ensure!(
        !trace_path.is_empty(),
        "--trace <file> required (a recorded TRACE_<run>.json)"
    );
    let max_requests = args.get_usize("max-requests", 20)?;
    let loaded = chrome::load(std::path::Path::new(&trace_path))?;
    let current = report::from_loaded(&loaded);
    let baseline_path = args.get("baseline", "");
    if baseline_path.is_empty() {
        print!("{}", report::render(&current, max_requests));
        return Ok(());
    }
    let noise = match args.flags.get("noise") {
        Some(v) => v.parse::<f64>().with_context(|| format!("bad --noise {v:?}"))?,
        None => tiny_qmoe::util::env_parse(tiny_qmoe::barometer::BENCH_NOISE_VAR, 0.10)?,
    };
    let base = report::from_loaded(&chrome::load(std::path::Path::new(&baseline_path))?);
    let (rendered, _regressions) = report::diff(&base, &current, noise);
    print!("{rendered}");
    Ok(())
}
