//! TQM writer: quantized model -> container file.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use super::{bits_to_u8, gran_to_u8, TensorKind, TqmMeta, CONTAINER_VERSION, MAGIC};
use crate::compress::codec;
use crate::compress::stream::{parse_chunk_index, Chunked, DEFAULT_CHUNK};
use crate::quant::QuantizedTensor;
use crate::tensor::Tensor;

/// In-memory staging of a model about to be written.
pub struct TqmWriter {
    meta: TqmMeta,
    // (name, kind, bits, shape, scale, zero, raw bytes)
    tensors: Vec<StagedTensor>,
    /// Chunk granularity for quantized payloads (v2 framing). Chunks are
    /// independently decodable, so smaller chunks mean more decode
    /// parallelism but more per-chunk index/codec overhead.
    chunk_len: usize,
    /// Emit the legacy v1 container (flat payloads, no chunk framing) —
    /// kept for compatibility tests and byte-size comparisons.
    flat: bool,
}

struct StagedTensor {
    name: String,
    kind: TensorKind,
    bits: crate::quant::Bits,
    gran: crate::quant::Granularity,
    shape: Vec<usize>,
    scale: Vec<f32>,
    zero: Vec<f32>,
    raw: Vec<u8>,
}

impl TqmWriter {
    pub fn new(meta: TqmMeta) -> Self {
        Self { meta, tensors: Vec::new(), chunk_len: DEFAULT_CHUNK, flat: false }
    }

    /// Override the chunk granularity of quantized payloads.
    pub fn with_chunk_len(mut self, n: usize) -> Self {
        assert!(n > 0);
        self.chunk_len = n;
        self
    }

    /// Emit the legacy v1 container (flat payloads).
    pub fn with_flat_payloads(mut self) -> Self {
        self.flat = true;
        self
    }

    /// Stage a quantized matrix (codes go through the container codec).
    pub fn add_quantized(&mut self, name: &str, q: &QuantizedTensor) {
        self.tensors.push(StagedTensor {
            name: name.to_string(),
            kind: TensorKind::QuantU8,
            bits: q.bits,
            gran: q.granularity,
            shape: q.codes.shape.clone(),
            scale: q.scale.clone(),
            zero: q.zero.clone(),
            raw: q.codes.data.clone(),
        });
    }

    /// Stage one expert matrix under the canonical expert record name
    /// (`layers.{l}.experts.{e}.{mat}`), so the reader's expert index
    /// picks it up. Each expert matrix is its own record — and in a v2
    /// container its own chunked stream — so one expert decodes without
    /// touching its siblings.
    pub fn add_expert_quantized(
        &mut self,
        layer: usize,
        expert: usize,
        mat: &str,
        q: &QuantizedTensor,
    ) {
        self.add_quantized(&super::expert_record_name(layer, expert, mat), q);
    }

    /// Stage a layer's router matrix (raw f32 under the canonical name).
    pub fn add_router(&mut self, layer: usize, w: &Tensor) {
        self.add_f32(&super::router_record_name(layer), w);
    }

    /// Stage a raw f32 tensor (norm vectors — stored uncompressed).
    pub fn add_f32(&mut self, name: &str, t: &Tensor) {
        let mut raw = Vec::with_capacity(t.data.len() * 4);
        for v in &t.data {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        self.tensors.push(StagedTensor {
            name: name.to_string(),
            kind: TensorKind::F32Raw,
            bits: crate::quant::Bits::B8,
            gran: crate::quant::Granularity::PerTensor,
            shape: t.shape.clone(),
            scale: Vec::new(),
            zero: Vec::new(),
            raw,
        });
    }

    /// Train the model-global dictionary, compress every staged tensor,
    /// and write the container. Returns (file_bytes, dict_bytes).
    pub fn write(self, path: impl AsRef<Path>) -> Result<(usize, usize)> {
        let c = codec(self.meta.codec);
        // dictionary trained on the quantized code streams only
        let packed_cache: Vec<Option<Vec<u8>>> = self
            .tensors
            .iter()
            .map(|t| match t.kind {
                TensorKind::QuantU8 if t.bits.storage_bits() < 8 => {
                    Some(crate::quant::packing::pack(&t.raw, t.bits.storage_bits()))
                }
                _ => None,
            })
            .collect();
        let samples: Vec<&[u8]> = self
            .tensors
            .iter()
            .zip(&packed_cache)
            .filter(|(t, _)| t.kind == TensorKind::QuantU8)
            .map(|(t, p)| p.as_deref().unwrap_or(t.raw.as_slice()))
            .collect();
        let dict = c.train(&samples);

        let version: u32 = if self.flat { 1 } else { CONTAINER_VERSION };
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&(self.meta.codec as u32).to_le_bytes());
        let meta_json = self.meta.to_json().to_string().into_bytes();
        out.extend_from_slice(&(meta_json.len() as u32).to_le_bytes());
        out.extend_from_slice(&meta_json);
        out.extend_from_slice(&(dict.len() as u64).to_le_bytes());
        out.extend_from_slice(&dict);
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());

        for t in &self.tensors {
            // sub-8-bit codes are bit-packed BEFORE entropy/dictionary
            // coding (packed streams are denser and the codec sees the
            // format the device stores); 8-bit passes through unchanged
            let storage;
            let raw_for_codec: &[u8] = match t.kind {
                TensorKind::QuantU8 if t.bits.storage_bits() < 8 => {
                    storage = crate::quant::packing::pack(&t.raw, t.bits.storage_bits());
                    &storage
                }
                _ => &t.raw,
            };
            // quantized payloads are chunk-framed in v2 so readers can
            // decode them range-by-range and in parallel across chunks
            let payload = match t.kind {
                TensorKind::QuantU8 if self.flat => c.compress(&dict, raw_for_codec)?,
                TensorKind::QuantU8 => Chunked::new(c.as_ref())
                    .with_chunk_len(self.chunk_len)
                    .compress(&dict, raw_for_codec)?,
                TensorKind::F32Raw => raw_for_codec.to_vec(),
            };
            let nb = t.name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            out.push(t.kind.to_u8());
            out.push(bits_to_u8(t.bits));
            if version >= 2 {
                // explicit quantization granularity (v1 readers inferred
                // per-channel as axis 1, which is ambiguous for square
                // per-row tensors)
                out.push(gran_to_u8(t.gran));
            }
            out.push(t.shape.len() as u8);
            for d in &t.shape {
                out.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            if t.kind == TensorKind::QuantU8 {
                out.extend_from_slice(&(t.scale.len() as u32).to_le_bytes());
                for s in &t.scale {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                for z in &t.zero {
                    out.extend_from_slice(&z.to_le_bytes());
                }
            }
            out.extend_from_slice(&(raw_for_codec.len() as u64).to_le_bytes());
            out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            out.extend_from_slice(&crc32fast::hash(&payload).to_le_bytes());
            if version >= 3 {
                // per-chunk crc32s (v3): hash each compressed chunk slice
                // of the just-built chunked payload so a reader can point
                // a whole-payload CRC failure at the first bad chunk
                let chunk_crcs: Vec<u32> = match t.kind {
                    TensorKind::QuantU8 => {
                        let idx = parse_chunk_index(&payload)?;
                        let body = idx.body(&payload);
                        idx.entries
                            .iter()
                            .enumerate()
                            .map(|(i, &(off, _))| {
                                crc32fast::hash(&body[off..idx.chunk_end(i, body.len())])
                            })
                            .collect()
                    }
                    TensorKind::F32Raw => Vec::new(),
                };
                out.extend_from_slice(&(chunk_crcs.len() as u32).to_le_bytes());
                for crc in &chunk_crcs {
                    out.extend_from_slice(&crc.to_le_bytes());
                }
            }
            out.extend_from_slice(&payload);
        }

        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(&out)?;
        f.flush()?;
        Ok((out.len(), dict.len()))
    }
}
