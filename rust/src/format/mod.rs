//! TQM container (S7): the on-device model file the paper's system ships.
//!
//! One file carries everything inference needs: the model-global
//! compression dictionary, and per-tensor records holding quantization
//! parameters plus the compressed code stream. The reader is *lazy*: it
//! parses the index up front and decompresses tensors on demand, which is
//! what makes the coordinator's per-layer streaming possible.
//!
//! ```text
//! magic   b"TQM1"
//! u32     container version (see CONTAINER_VERSION)
//! u32     codec id
//! u32     model config json length | bytes (name, dims, ...)
//! u64     dict length | bytes
//! u32     n_tensors
//! repeated (index, fixed walk order):
//!   u16   name_len | name utf-8
//!   u8    kind      (0 = f32 raw, 1 = quantized-u8)
//!   u8    bits      (storage bits; 8 for f32-raw, ignored)
//!   u8    gran      (v2+ only: 0 = per-tensor, 1 = per-channel axis 0,
//!                    2 = per-channel axis 1; absent in v1, where the
//!                    reader infers per-channel as axis 1)
//!   u8    ndim | u32*ndim dims
//!   u32   n_channels | f32*n scales | f32*n zeros   (kind 1 only)
//!   u64   raw_len  (uncompressed code/byte count)
//!   u64   payload_len
//!   u32   crc32 of payload
//!   u32   n_chunk_crcs | u32*n per-chunk crc32s   (v3+ only; 0 for
//!         flat/f32 payloads)
//!   bytes payload
//! ```
//!
//! All integers little-endian. CRCs guard against torn writes — the paper
//! targets phones, where that is not hypothetical.
//!
//! **Container versions.** v1 stores each quantized payload as one flat
//! codec stream. v2 wraps quantized payloads in the
//! [`crate::compress::stream::Chunked`] framing, so a reader can
//! decompress a tensor chunk-by-chunk — bounding decode memory and,
//! crucially, letting the serving pipeline fan a layer's decode out
//! across cores (chunks are independent streams). v3 (current) adds a
//! per-chunk crc32 list to each chunked record's header, so a
//! whole-payload CRC mismatch can be localized to the first bad chunk
//! (the error names the record *and* the chunk — a fault-diagnosis
//! primitive for flaky-storage deployments). f32 payloads (norm vectors)
//! stay raw in every version. The reader accepts all three.

pub mod reader;
pub mod writer;

pub use reader::TqmReader;
pub use writer::TqmWriter;

use anyhow::Result;

use crate::compress::CodecId;
use crate::quant::Bits;
use crate::util::Json;

pub const MAGIC: &[u8; 4] = b"TQM1";

/// Current TQM container version (the `u32` after the magic).
///
/// Independent of [`crate::FORMAT_VERSION`] (the AOT-manifest / stage
/// contract version): bumping how payload bytes are framed must not
/// invalidate lowered HLO artifacts, and vice versa.
pub const CONTAINER_VERSION: u32 = 3;

/// Oldest container version the reader still understands.
pub const MIN_CONTAINER_VERSION: u32 = 1;

/// What kind of tensor a record holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TensorKind {
    /// Raw f32 little-endian bytes (norm vectors).
    F32Raw,
    /// Quantized u8 codes, compressed by the container codec.
    QuantU8,
}

impl TensorKind {
    pub fn to_u8(self) -> u8 {
        match self {
            TensorKind::F32Raw => 0,
            TensorKind::QuantU8 => 1,
        }
    }

    pub fn from_u8(v: u8) -> anyhow::Result<Self> {
        Ok(match v {
            0 => TensorKind::F32Raw,
            1 => TensorKind::QuantU8,
            _ => anyhow::bail!("bad tensor kind {v}"),
        })
    }
}

/// Model-level metadata embedded in the container.
#[derive(Clone, Debug)]
pub struct TqmMeta {
    pub model_name: String,
    pub codec: CodecId,
    pub bits: Bits,
    /// Per-channel or per-tensor quantization.
    pub per_channel: bool,
    /// Quantizer used ("naive" | "gptq").
    pub quantizer: String,
    pub source_checkpoint: String,
}

impl TqmMeta {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model_name", Json::str(self.model_name.clone())),
            ("codec", Json::num(self.codec as u32 as f64)),
            ("bits", Json::num(bits_to_u8(self.bits) as f64)),
            ("per_channel", Json::Bool(self.per_channel)),
            ("quantizer", Json::str(self.quantizer.clone())),
            ("source_checkpoint", Json::str(self.source_checkpoint.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            model_name: j.get("model_name")?.as_str()?.to_string(),
            codec: CodecId::from_u32(j.get("codec")?.as_u32()?)?,
            bits: bits_from_u8(j.get("bits")?.as_usize()? as u8)?,
            per_channel: j.get("per_channel")?.as_bool()?,
            quantizer: j.get("quantizer")?.as_str()?.to_string(),
            source_checkpoint: j.get("source_checkpoint")?.as_str()?.to_string(),
        })
    }
}

/// Index entry for one tensor (offsets resolved by the reader).
#[derive(Clone, Debug)]
pub struct TensorRecord {
    pub name: String,
    pub kind: TensorKind,
    pub bits: Bits,
    /// Quantization granularity. Stored explicitly in v2 containers; for
    /// v1 files the reader infers per-channel parameters as axis 1 (the
    /// historical assumption, ambiguous for square per-row tensors).
    pub granularity: crate::quant::Granularity,
    pub shape: Vec<usize>,
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
    pub raw_len: usize,
    pub payload_offset: usize,
    pub payload_len: usize,
    pub crc32: u32,
    /// Per-chunk crc32s of the chunk-framed payload (v3+ containers,
    /// chunked quantized records only — empty otherwise). Lets the reader
    /// localize a whole-payload CRC mismatch to the first bad chunk.
    pub chunk_crcs: Vec<u32>,
}

impl TensorRecord {
    /// Stored size of this record's payload + parameters (Table 1 input).
    pub fn stored_bytes(&self) -> usize {
        self.payload_len + 4 * (self.scale.len() + self.zero.len())
    }
}

/// Canonical record name of one expert matrix: every MoE producer and
/// consumer (writer, reader index, expert cache) goes through this, so
/// the container layout is the single contract.
pub fn expert_record_name(layer: usize, expert: usize, mat: &str) -> String {
    format!("layers.{layer}.experts.{expert}.{mat}")
}

/// Canonical record name of a layer's router matrix (`[d_model, n_experts]`
/// f32 — routers are tiny and precision-sensitive, so they ship raw).
pub fn router_record_name(layer: usize) -> String {
    format!("layers.{layer}.router")
}

/// Parse `layers.{l}.experts.{e}.{mat}` back into (layer, expert, mat).
/// Returns `None` for non-expert records (dense layers, routers, heads).
pub fn parse_expert_record_name(name: &str) -> Option<(usize, usize, &str)> {
    let rest = name.strip_prefix("layers.")?;
    let (layer, rest) = rest.split_once(".experts.")?;
    let (expert, mat) = rest.split_once('.')?;
    Some((layer.parse().ok()?, expert.parse().ok()?, mat))
}

/// One expert's slice of the container index: every record belonging to
/// `(layer, expert)`, grouped at open time so a single expert can be
/// located and decoded without touching its siblings (each record's
/// payload is an independently-decodable chunked stream).
#[derive(Clone, Debug)]
pub struct ExpertEntry {
    pub layer: usize,
    pub expert: usize,
    /// Record indices of this expert's tensors, in container walk order.
    pub records: Vec<usize>,
    /// Decoded f32 bytes of the expert's quantized tensors — what one
    /// cache slot costs, known before any decode happens (the expert
    /// cache evicts *ahead* of a miss using this).
    pub decoded_f32_bytes: usize,
    /// What one *packed-resident* cache slot costs: the bit-packed code
    /// streams plus quant params plus the per-column dequant LUTs the
    /// qGEMV path stores when profitable
    /// ([`crate::quant::packing::col_lut_bytes`]) — also known before
    /// any decode, so the packed residency mode evicts ahead the same
    /// way the decoded mode does.
    pub packed_resident_bytes: usize,
    /// Compressed bytes on disk across the expert's payloads.
    pub stored_bytes: usize,
}

pub(crate) fn gran_to_u8(g: crate::quant::Granularity) -> u8 {
    use crate::quant::Granularity;
    match g {
        Granularity::PerTensor => 0,
        Granularity::PerChannel { axis: 0 } => 1,
        Granularity::PerChannel { axis: 1 } => 2,
        Granularity::PerChannel { axis } => panic!("unencodable channel axis {axis}"),
    }
}

pub(crate) fn gran_from_u8(v: u8) -> Result<crate::quant::Granularity> {
    use crate::quant::Granularity;
    Ok(match v {
        0 => Granularity::PerTensor,
        1 => Granularity::PerChannel { axis: 0 },
        2 => Granularity::PerChannel { axis: 1 },
        _ => anyhow::bail!("bad granularity tag {v}"),
    })
}

pub(crate) fn bits_to_u8(b: Bits) -> u8 {
    match b {
        Bits::Ternary => 255, // sentinel: 2 storage bits but ternary grid
        Bits::B2 => 2,
        Bits::B4 => 4,
        Bits::B6 => 6,
        Bits::B8 => 8,
    }
}

pub(crate) fn bits_from_u8(v: u8) -> anyhow::Result<Bits> {
    Ok(match v {
        255 => Bits::Ternary,
        2 => Bits::B2,
        4 => Bits::B4,
        6 => Bits::B6,
        8 => Bits::B8,
        _ => anyhow::bail!("bad bits tag {v}"),
    })
}
