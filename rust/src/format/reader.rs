//! TQM reader: lazy, per-tensor decompression — the primitive under the
//! coordinator's layer streaming. The whole (compressed) file is held in
//! memory (that is the paper's deployment model: compressed weights are
//! what fits), the index is parsed once, and `load_*` decompresses a
//! single tensor on demand into a caller-supplied buffer.

use std::borrow::Cow;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::{
    bits_from_u8, gran_from_u8, parse_expert_record_name, ExpertEntry, TensorKind, TensorRecord,
    TqmMeta, CONTAINER_VERSION, MAGIC, MIN_CONTAINER_VERSION,
};
use crate::compress::stream::parse_chunk_index;
use crate::compress::{codec, Codec, CodecId};
use crate::faults::{FaultPlan, Passthrough, RecordSource};
use crate::quant::{packing, Bits, Granularity, QuantizedTensor};
use crate::tensor::{Tensor, U8Tensor};

pub struct TqmReader {
    pub meta: TqmMeta,
    pub codec_id: CodecId,
    /// Container version this file was written with (1 = flat payloads,
    /// 2 = chunk-framed quantized payloads, 3 = + per-chunk CRCs).
    pub container_version: u32,
    data: Vec<u8>,
    dict_range: (usize, usize),
    records: Vec<TensorRecord>,
    /// name -> records index (layer streaming resolves 9 tensors per
    /// layer per pass; a linear scan was measurable on deep models).
    by_name: HashMap<String, usize>,
    /// Expert-indexed view of the records: `layers.{l}.experts.{e}.*`
    /// grouped per (layer, expert) at open time, so the expert cache can
    /// locate and size one expert without scanning or decoding siblings.
    experts: Vec<ExpertEntry>,
    /// (layer, expert) -> index into `experts`.
    expert_lookup: HashMap<(usize, usize), usize>,
    codec: Box<dyn Codec>,
    /// §Perf: the freqseq dictionary parsed once per container (the parse
    /// builds a 64k-entry hash map; doing it per tensor per layer pass
    /// dominated streaming decompression time).
    prepared_freq: Option<crate::compress::freqseq::Table>,
    /// Payload source seam (fault injection, remote tiers): every
    /// quantized/expert payload access on the `load_*` paths routes
    /// through this before CRC checking. [`Passthrough`] by default —
    /// zero-cost, bit-exact with the sourceless reader.
    source: Arc<dyn RecordSource>,
    /// Typed handle kept when the source is a [`FaultPlan`], so the host
    /// can bind metrics / read injection stats without downcasting.
    faults: Option<Arc<FaultPlan>>,
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            bail!("tqm: truncated at offset {}", self.pos);
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

impl TqmReader {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let data =
            std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_bytes(data)
    }

    pub fn from_bytes(data: Vec<u8>) -> Result<Self> {
        let mut c = Cursor { data: &data, pos: 0 };
        if c.take(4)? != MAGIC {
            bail!("tqm: bad magic");
        }
        let version = c.u32()?;
        if !(MIN_CONTAINER_VERSION..=CONTAINER_VERSION).contains(&version) {
            bail!(
                "tqm: container version {version} outside supported {MIN_CONTAINER_VERSION}..={CONTAINER_VERSION}"
            );
        }
        let codec_id = CodecId::from_u32(c.u32()?)?;
        let meta_len = c.u32()? as usize;
        let meta_text = std::str::from_utf8(c.take(meta_len)?)?;
        let meta = TqmMeta::from_json(&crate::util::Json::parse(meta_text)?)?;
        let dict_len = c.u64()? as usize;
        let dict_start = c.pos;
        c.take(dict_len)?;
        let dict_range = (dict_start, dict_start + dict_len);
        let n_tensors = c.u32()? as usize;

        let mut records = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let name_len = c.u16()? as usize;
            let name = String::from_utf8(c.take(name_len)?.to_vec())?;
            let kind = TensorKind::from_u8(c.u8()?)?;
            let bits = if kind == TensorKind::QuantU8 {
                bits_from_u8(c.u8()?)?
            } else {
                c.u8()?;
                Bits::B8
            };
            // v2 records carry the quantization granularity explicitly;
            // v1 readers had to infer it from the scale-vector length
            let gran_tag = if version >= 2 { Some(c.u8()?) } else { None };
            let ndim = c.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u32()? as usize);
            }
            let (scale, zero) = if kind == TensorKind::QuantU8 {
                let n_ch = c.u32()? as usize;
                let mut scale = Vec::with_capacity(n_ch);
                for _ in 0..n_ch {
                    scale.push(c.f32()?);
                }
                let mut zero = Vec::with_capacity(n_ch);
                for _ in 0..n_ch {
                    zero.push(c.f32()?);
                }
                (scale, zero)
            } else {
                (Vec::new(), Vec::new())
            };
            let granularity = match gran_tag {
                Some(t) => gran_from_u8(t)?,
                // v1 files carry no tag: infer by matching the param count
                // against the shape (out-channel axis first, then rows —
                // the embedding table is per-row with vocab params)
                None if scale.len() <= 1 => Granularity::PerTensor,
                None if shape.len() == 2 && scale.len() == shape[1] => {
                    Granularity::PerChannel { axis: 1 }
                }
                None if shape.len() == 2 && scale.len() == shape[0] => {
                    Granularity::PerChannel { axis: 0 }
                }
                None => Granularity::PerChannel { axis: 1 },
            };
            if kind == TensorKind::QuantU8 {
                if let Granularity::PerChannel { axis } = granularity {
                    anyhow::ensure!(
                        axis < shape.len() && scale.len() == shape[axis],
                        "tqm: {name:?} has {} channel params for axis {axis} of {shape:?}",
                        scale.len()
                    );
                }
            }
            let raw_len = c.u64()? as usize;
            // the payload CRC does not cover header fields; cross-check
            // raw_len against shape×bits so a torn-write header cannot
            // drive the decode arenas into a length-mismatch panic
            match kind {
                TensorKind::QuantU8 => {
                    let n_codes = crate::tensor::numel(&shape);
                    let expect = (n_codes * bits.storage_bits() as usize + 7) / 8;
                    anyhow::ensure!(
                        raw_len == expect,
                        "tqm: {name:?} raw_len {raw_len} inconsistent with shape {shape:?} at {:?}",
                        bits
                    );
                }
                TensorKind::F32Raw => {
                    anyhow::ensure!(
                        raw_len == crate::tensor::numel(&shape) * 4,
                        "tqm: {name:?} raw_len {raw_len} inconsistent with f32 shape {shape:?}"
                    );
                }
            }
            let payload_len = c.u64()? as usize;
            let crc32 = c.u32()?;
            // v3 records carry per-chunk crc32s for localization; no
            // with_capacity on the declared count — a torn header could
            // claim billions, and push + bounds-checked take fail fast
            let mut chunk_crcs = Vec::new();
            if version >= 3 {
                let n_chunk_crcs = c.u32()? as usize;
                for _ in 0..n_chunk_crcs {
                    chunk_crcs.push(c.u32()?);
                }
            }
            let payload_offset = c.pos;
            c.take(payload_len)?;
            records.push(TensorRecord {
                name,
                kind,
                bits,
                granularity,
                shape,
                scale,
                zero,
                raw_len,
                payload_offset,
                payload_len,
                crc32,
                chunk_crcs,
            });
        }
        let prepared_freq = match codec_id {
            CodecId::FreqSeq | CodecId::FreqSeqPacked => Some(
                crate::compress::freqseq::Table::parse(&data[dict_range.0..dict_range.1])?,
            ),
            _ => None,
        };
        let by_name =
            records.iter().enumerate().map(|(i, r)| (r.name.clone(), i)).collect();

        // expert-indexed table: group expert records by (layer, expert),
        // ordered by key so `expert_entries` walks layers then experts
        let mut grouped: std::collections::BTreeMap<(usize, usize), ExpertEntry> =
            std::collections::BTreeMap::new();
        for (i, r) in records.iter().enumerate() {
            if let Some((layer, expert, _)) = parse_expert_record_name(&r.name) {
                let e = grouped.entry((layer, expert)).or_insert_with(|| ExpertEntry {
                    layer,
                    expert,
                    records: Vec::new(),
                    decoded_f32_bytes: 0,
                    packed_resident_bytes: 0,
                    stored_bytes: 0,
                });
                e.records.push(i);
                let numel = crate::tensor::numel(&r.shape);
                e.decoded_f32_bytes += numel * 4;
                // packed residency: the one shared size rule with
                // PackedMatrix::new (`packing::packed_resident_bytes`),
                // so the bytes the index promises here are exactly the
                // bytes a packed decode allocates
                e.packed_resident_bytes += match r.kind {
                    TensorKind::QuantU8 => packing::packed_resident_bytes(
                        r.bits.storage_bits(),
                        r.granularity,
                        r.shape[1],
                        r.raw_len,
                        r.scale.len(),
                        r.zero.len(),
                    ),
                    TensorKind::F32Raw => numel * 4,
                };
                e.stored_bytes += r.stored_bytes();
            }
        }
        let experts: Vec<ExpertEntry> = grouped.into_values().collect();
        let expert_lookup = experts
            .iter()
            .enumerate()
            .map(|(i, e)| ((e.layer, e.expert), i))
            .collect();

        Ok(Self {
            meta,
            codec_id,
            container_version: version,
            dict_range,
            records,
            by_name,
            experts,
            expert_lookup,
            codec: codec(codec_id),
            prepared_freq,
            data,
            source: Arc::new(Passthrough),
            faults: None,
        })
    }

    /// Route every quantized payload access through `source` (fault
    /// injection, remote tiers). The CRC check runs on what the source
    /// returns, so injected corruption is caught like real corruption.
    pub fn with_record_source(mut self, source: Arc<dyn RecordSource>) -> Self {
        self.source = source;
        self.faults = None;
        self
    }

    /// Install a seeded [`FaultPlan`] as the record source, keeping the
    /// typed handle so callers can bind metrics / read injection stats.
    pub fn with_fault_plan(mut self, plan: Arc<FaultPlan>) -> Self {
        self.faults = Some(plan.clone());
        self.source = plan;
        self
    }

    /// The installed fault plan, if any.
    pub fn fault_plan(&self) -> Option<&Arc<FaultPlan>> {
        self.faults.as_ref()
    }

    pub fn records(&self) -> &[TensorRecord] {
        &self.records
    }

    pub fn record(&self, name: &str) -> Result<&TensorRecord> {
        Ok(&self.records[self.record_index(name)?])
    }

    /// Index of a tensor's record (stable for this reader's lifetime) —
    /// lets hot paths resolve names once instead of per pass.
    pub fn record_index(&self, name: &str) -> Result<usize> {
        self.by_name
            .get(name)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("tqm: no tensor {name:?}"))
    }

    pub fn record_at(&self, idx: usize) -> &TensorRecord {
        &self.records[idx]
    }

    /// Whether quantized payloads carry the chunk framing (v2 containers).
    pub fn is_chunked(&self) -> bool {
        self.container_version >= 2
    }

    /// All expert index entries, ordered by (layer, expert). Empty for
    /// dense containers.
    pub fn expert_entries(&self) -> &[ExpertEntry] {
        &self.experts
    }

    /// Index entry of one expert (its record set, decoded size and stored
    /// size) — errors if the container has no such expert.
    pub fn expert_entry(&self, layer: usize, expert: usize) -> Result<&ExpertEntry> {
        self.expert_lookup
            .get(&(layer, expert))
            .map(|&i| &self.experts[i])
            .ok_or_else(|| anyhow::anyhow!("tqm: no expert ({layer}, {expert}) in container"))
    }

    /// Experts recorded for `layer` (0 for dense containers/layers).
    pub fn n_experts(&self, layer: usize) -> usize {
        self.experts.iter().filter(|e| e.layer == layer).count()
    }

    fn dict(&self) -> &[u8] {
        &self.data[self.dict_range.0..self.dict_range.1]
    }

    /// Whole container bytes — the layer decoder precomputes absolute
    /// chunk ranges into this buffer so its hot loop can slice without
    /// re-walking the index.
    pub(crate) fn bytes(&self) -> &[u8] {
        &self.data
    }

    /// CRC-checked payload bytes of a record, straight from the container
    /// (bypasses the record source — the dense-layer streaming path,
    /// which has no retry/quarantine story, reads here).
    pub fn payload_bytes(&self, r: &TensorRecord) -> Result<&[u8]> {
        let p = &self.data[r.payload_offset..r.payload_offset + r.payload_len];
        self.check_crc(r, p)?;
        Ok(p)
    }

    /// CRC check with v3 chunk localization: a whole-payload mismatch is
    /// attributed to the first chunk whose stored per-chunk crc32 fails
    /// (or whose compressed slice is out of range — truncation), so the
    /// error names both the record and the chunk.
    fn check_crc(&self, r: &TensorRecord, p: &[u8]) -> Result<()> {
        let crc = crc32fast::hash(p);
        if crc == r.crc32 {
            return Ok(());
        }
        match locate_bad_chunk(r, p) {
            Some(chunk) => bail!(
                "tqm: crc mismatch on {:?} ({:08x} != {:08x}), first bad chunk {chunk} of {}",
                r.name,
                crc,
                r.crc32,
                r.chunk_crcs.len()
            ),
            None => bail!("tqm: crc mismatch on {:?} ({:08x} != {:08x})", r.name, crc, r.crc32),
        }
    }

    /// Payload bytes routed through the record source (the expert/router
    /// load path — where fault injection and retry/quarantine apply),
    /// then CRC-checked. Borrowed when the source passes through, owned
    /// when it substitutes bytes.
    fn payload<'a>(&'a self, r: &TensorRecord) -> Result<Cow<'a, [u8]>> {
        let raw = &self.data[r.payload_offset..r.payload_offset + r.payload_len];
        let fetched = self.source.fetch(&r.name, raw)?;
        self.check_crc(r, &fetched)?;
        Ok(fetched)
    }

    /// Decode one flat codec stream (a whole v1 payload, or a single v2
    /// chunk) of known uncompressed length into `out`. Takes `&self` and
    /// is thread-safe, which is what the parallel layer decode fans out
    /// over.
    pub(crate) fn decode_unit_into(
        &self,
        unit: &[u8],
        raw_len: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        if let Some(table) = &self.prepared_freq {
            crate::compress::freqseq::decode_with_table(
                table,
                self.codec_id == CodecId::FreqSeqPacked,
                unit,
                raw_len,
                out,
            )
        } else {
            self.codec.decompress(self.dict(), unit, raw_len, out)
        }
    }

    /// Decode a quantized record's full payload (still bit-packed for
    /// sub-8-bit tensors) into `out`, transparently handling both flat v1
    /// payloads and chunk-framed v2 payloads.
    fn decode_payload_into(
        &self,
        r: &TensorRecord,
        payload: &[u8],
        out: &mut Vec<u8>,
    ) -> Result<()> {
        if self.is_chunked() && r.kind == TensorKind::QuantU8 {
            let idx = parse_chunk_index(payload)?;
            let body = idx.body(payload);
            out.clear();
            out.reserve(r.raw_len);
            let mut chunk = Vec::new();
            for (i, &(off, raw_len)) in idx.entries.iter().enumerate() {
                let end = idx.chunk_end(i, body.len());
                self.decode_unit_into(&body[off..end], raw_len, &mut chunk)?;
                out.extend_from_slice(&chunk);
            }
            anyhow::ensure!(
                out.len() == r.raw_len,
                "tqm: {:?} chunked payload decoded {} bytes, expected {}",
                r.name,
                out.len(),
                r.raw_len
            );
            Ok(())
        } else {
            self.decode_unit_into(payload, r.raw_len, out)
        }
    }

    /// Decompress a quantized tensor's codes into `scratch` and return the
    /// full QuantizedTensor view. `scratch` is reused across calls by the
    /// pipeline to avoid per-layer allocation.
    pub fn load_quantized_into(
        &self,
        name: &str,
        scratch: &mut Vec<u8>,
    ) -> Result<QuantizedTensor> {
        let r = self.record(name)?;
        if r.kind != TensorKind::QuantU8 {
            bail!("tqm: {name:?} is not quantized");
        }
        let payload = self.payload(r)?;
        self.decode_payload_into(r, &payload, scratch)?;
        // sub-8-bit codes were bit-packed before coding; expand back to
        // one-code-per-byte (what the stage HLOs take)
        if r.bits.storage_bits() < 8 {
            let n_codes = crate::tensor::numel(&r.shape);
            let unpacked =
                crate::quant::packing::unpack(scratch, r.bits.storage_bits(), n_codes);
            *scratch = unpacked;
        }
        Ok(QuantizedTensor {
            codes: U8Tensor::new(r.shape.clone(), scratch.clone())?,
            scale: r.scale.clone(),
            zero: r.zero.clone(),
            bits: r.bits,
            granularity: r.granularity,
        })
    }

    pub fn load_quantized(&self, name: &str) -> Result<QuantizedTensor> {
        let mut scratch = Vec::new();
        self.load_quantized_into(name, &mut scratch)
    }

    /// Decompress + dequantize a quantized tensor straight to f32 via the
    /// fused [`packing::unpack_dequant_into`] kernel, never materializing
    /// the one-byte-per-code expansion. `packed_scratch` holds the
    /// intermediate decompressed (still bit-packed) stream and is reused
    /// across calls; `out` is resized to the tensor's element count.
    pub fn load_dequantized_into(
        &self,
        name: &str,
        packed_scratch: &mut Vec<u8>,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let r = self.record(name)?;
        if r.kind != TensorKind::QuantU8 {
            bail!("tqm: {name:?} is not quantized");
        }
        let payload = self.payload(r)?;
        self.decode_payload_into(r, &payload, packed_scratch)?;
        let n = crate::tensor::numel(&r.shape);
        out.resize(n, 0.0);
        let bits = r.bits.storage_bits();
        match r.granularity {
            Granularity::PerTensor => {
                packing::unpack_dequant_into(packed_scratch, bits, r.scale[0], r.zero[0], out);
            }
            Granularity::PerChannel { axis } if r.shape.len() == 2 => {
                // record validation guarantees scale.len() == shape[axis]
                if axis == 1 {
                    packing::unpack_dequant_cols_into(
                        packed_scratch,
                        bits,
                        r.shape[1],
                        &r.scale,
                        &r.zero,
                        out,
                    );
                } else {
                    packing::unpack_dequant_rows_into(
                        packed_scratch,
                        bits,
                        r.shape[1],
                        &r.scale,
                        &r.zero,
                        out,
                    );
                }
            }
            Granularity::PerChannel { .. } => {
                bail!("tqm: {name:?} per-channel params need a 2-D shape, got {:?}", r.shape)
            }
        }
        Ok(())
    }

    /// Decompress a quantized tensor's payload into `out` **leaving the
    /// codes bit-packed** — the raw little-endian code stream the qGEMV
    /// kernels consume directly. Quantization parameters live on the
    /// record ([`TqmReader::record`]); `out` ends up exactly
    /// `raw_len` bytes. This is the packed-residency decode: no unpack,
    /// no dequantize, no f32 arena.
    pub fn load_packed_into(&self, name: &str, out: &mut Vec<u8>) -> Result<()> {
        let r = self.record(name)?;
        if r.kind != TensorKind::QuantU8 {
            bail!("tqm: {name:?} is not quantized");
        }
        let payload = self.payload(r)?;
        self.decode_payload_into(r, &payload, out)?;
        anyhow::ensure!(
            out.len() == r.raw_len,
            "tqm: {name:?} packed decode produced {} bytes, expected {}",
            out.len(),
            r.raw_len
        );
        Ok(())
    }

    /// Load a raw f32 tensor (norm vectors).
    pub fn load_f32(&self, name: &str) -> Result<Tensor> {
        let r = self.record(name)?;
        if r.kind != TensorKind::F32Raw {
            bail!("tqm: {name:?} is not f32");
        }
        let payload = self.payload(r)?;
        let data: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor::new(r.shape.clone(), data)?)
    }

    /// Total container size (the Table 1 "Quantized+Compressed" number).
    pub fn file_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn dict_bytes(&self) -> usize {
        self.dict_range.1 - self.dict_range.0
    }

    /// Sum of decompressed code bytes (the Table 1 "Quantized" number).
    pub fn unpacked_bytes(&self) -> usize {
        self.records.iter().map(|r| r.raw_len + 4 * (r.scale.len() + r.zero.len())).sum()
    }
}

/// Find the first chunk a failed whole-payload CRC can be pinned on:
/// a chunk whose compressed slice is out of range (truncation) or whose
/// stored per-chunk crc32 mismatches. `None` when the record carries no
/// chunk CRCs (v1/v2, f32) or when no single chunk is implicated (e.g.
/// corruption confined to the chunk index itself is blamed on chunk 0).
fn locate_bad_chunk(r: &TensorRecord, payload: &[u8]) -> Option<usize> {
    if r.chunk_crcs.is_empty() {
        return None;
    }
    let idx = match parse_chunk_index(payload) {
        Ok(idx) => idx,
        // the index region itself is mangled — earliest attributable chunk
        Err(_) => return Some(0),
    };
    if idx.entries.len() != r.chunk_crcs.len() {
        return Some(0);
    }
    let body = idx.body(payload);
    for (i, &(off, _)) in idx.entries.iter().enumerate() {
        let end = idx.chunk_end(i, body.len());
        match body.get(off..end) {
            Some(slice) if crc32fast::hash(slice) == r.chunk_crcs[i] => {}
            _ => return Some(i),
        }
    }
    None
}

/// Shareable handle used by the pipeline's prefetch thread.
pub type SharedReader = Arc<TqmReader>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::TqmWriter;
    use crate::quant::{uniform, Bits, Granularity};
    
    fn meta(codec: CodecId) -> TqmMeta {
        TqmMeta {
            model_name: "test".into(),
            codec,
            bits: Bits::B8,
            per_channel: true,
            quantizer: "naive".into(),
            source_checkpoint: "unit".into(),
        }
    }

    fn sample_quantized(rows: usize, cols: usize, seed: u64) -> QuantizedTensor {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let t = Tensor::new(
            vec![rows, cols],
            (0..rows * cols).map(|_| rng.uniform(-1.0 as f64, 1.0 as f64) as f32).collect(),
        )
        .unwrap();
        uniform::quantize(&t, Bits::B8, Granularity::PerChannel { axis: 1 }).unwrap()
    }

    #[test]
    fn roundtrip_all_codecs() {
        for codec_id in crate::compress::all_codec_ids() {
            let dir = crate::util::TempDir::new().unwrap();
            let p = dir.path().join("m.tqm");
            let q1 = sample_quantized(32, 16, 1);
            let q2 = sample_quantized(16, 8, 2);
            let norm = Tensor::new(vec![16], vec![1.0; 16]).unwrap();
            let mut w = TqmWriter::new(meta(codec_id));
            w.add_quantized("layers.0.wq", &q1);
            w.add_quantized("layers.0.wk", &q2);
            w.add_f32("layers.0.ln1", &norm);
            w.write(&p).unwrap();

            let r = TqmReader::open(&p).unwrap();
            assert_eq!(r.codec_id, codec_id);
            assert_eq!(r.records().len(), 3);
            let g1 = r.load_quantized("layers.0.wq").unwrap();
            assert_eq!(g1.codes, q1.codes);
            assert_eq!(g1.scale, q1.scale);
            assert_eq!(g1.zero, q1.zero);
            let gn = r.load_f32("layers.0.ln1").unwrap();
            assert_eq!(gn, norm);
        }
    }

    #[test]
    fn crc_detects_corruption() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let q = sample_quantized(64, 32, 3);
        let mut w = TqmWriter::new(meta(CodecId::Lzw));
        w.add_quantized("w", &q);
        w.write(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 5] ^= 0xFF; // flip a payload byte
        let r = TqmReader::from_bytes(bytes).unwrap();
        assert!(r.load_quantized("w").is_err());
    }

    #[test]
    fn missing_tensor_error() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let w = TqmWriter::new(meta(CodecId::Raw));
        w.write(&p).unwrap();
        let r = TqmReader::open(&p).unwrap();
        assert!(r.load_quantized("nope").is_err());
    }

    #[test]
    fn sizes_reported() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let q = sample_quantized(128, 64, 4);
        let mut w = TqmWriter::new(meta(CodecId::Huffman));
        w.add_quantized("w", &q);
        let (file_bytes, _) = w.write(&p).unwrap();
        let r = TqmReader::open(&p).unwrap();
        assert_eq!(r.file_bytes(), file_bytes);
        assert_eq!(r.unpacked_bytes(), 128 * 64 + 4 * (64 + 64));
    }

    #[test]
    fn sub8bit_codes_roundtrip_packed() {
        // 4-bit codes are bit-packed in the container (half the payload)
        // and must come back exactly
        for bits in [Bits::Ternary, crate::quant::Bits::B2, crate::quant::Bits::B4, crate::quant::Bits::B6] {
            let dir = crate::util::TempDir::new().unwrap();
            let p = dir.path().join("m.tqm");
            let mut rng = crate::util::Rng::seed_from_u64(9);
            let t = Tensor::new(
                vec![64, 32],
                (0..64 * 32).map(|_| rng.normal_f32()).collect(),
            )
            .unwrap();
            let q = uniform::quantize(&t, bits, Granularity::PerTensor).unwrap();
            // flat v1 payloads so the packed length is directly visible
            let mut w = TqmWriter::new(TqmMeta {
                model_name: "pack".into(),
                codec: CodecId::Raw,
                bits,
                per_channel: false,
                quantizer: "naive".into(),
                source_checkpoint: "unit".into(),
            })
            .with_flat_payloads();
            w.add_quantized("w", &q);
            w.write(&p).unwrap();
            let r = TqmReader::open(&p).unwrap();
            assert_eq!(r.container_version, 1, "{bits:?}");
            let got = r.load_quantized("w").unwrap();
            assert_eq!(got.codes, q.codes, "{bits:?}");
            // the stored payload really is packed (Raw codec => payload len
            // equals packed length)
            let rec = r.record("w").unwrap();
            let expect = (64 * 32 * bits.storage_bits() as usize + 7) / 8;
            assert_eq!(rec.payload_len, expect, "{bits:?}");
        }
    }

    #[test]
    fn chunked_v2_roundtrip_all_codecs() {
        // v2 containers frame quantized payloads in chunks; a chunk_len
        // far below the tensor size forces multi-chunk payloads and the
        // chunk-reassembly decode path for every codec.
        for codec_id in crate::compress::all_codec_ids() {
            let dir = crate::util::TempDir::new().unwrap();
            let p = dir.path().join("m.tqm");
            let q = sample_quantized(64, 48, 7);
            let mut w = TqmWriter::new(meta(codec_id)).with_chunk_len(257);
            w.add_quantized("w", &q);
            w.write(&p).unwrap();
            let r = TqmReader::open(&p).unwrap();
            assert_eq!(r.container_version, crate::format::CONTAINER_VERSION);
            assert!(r.is_chunked());
            let got = r.load_quantized("w").unwrap();
            assert_eq!(got.codes, q.codes, "{codec_id:?}");
            assert_eq!(got.scale, q.scale, "{codec_id:?}");
        }
    }

    #[test]
    fn flat_v1_and_chunked_v2_decode_identically() {
        let q = sample_quantized(32, 32, 8);
        let dir = crate::util::TempDir::new().unwrap();
        let (p1, p2) = (dir.path().join("v1.tqm"), dir.path().join("v2.tqm"));
        let mut w1 = TqmWriter::new(meta(CodecId::Huffman)).with_flat_payloads();
        w1.add_quantized("w", &q);
        w1.write(&p1).unwrap();
        let mut w2 = TqmWriter::new(meta(CodecId::Huffman)).with_chunk_len(100);
        w2.add_quantized("w", &q);
        w2.write(&p2).unwrap();
        let r1 = TqmReader::open(&p1).unwrap();
        let r2 = TqmReader::open(&p2).unwrap();
        assert_eq!(r1.container_version, 1);
        assert_eq!(r2.container_version, crate::format::CONTAINER_VERSION);
        let a = r1.load_quantized("w").unwrap();
        let b = r2.load_quantized("w").unwrap();
        assert_eq!(a.codes, b.codes);
    }

    #[test]
    fn fused_dequant_matches_two_step() {
        // per-channel (axis 1), per-row (embed-style axis 0) and
        // per-tensor records, sub-8 and 8-bit, flat and chunked
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let mut rng = crate::util::Rng::seed_from_u64(21);
        let t = Tensor::new(vec![48, 24], (0..48 * 24).map(|_| rng.normal_f32()).collect())
            .unwrap();
        let q_cols = uniform::quantize(&t, Bits::B8, Granularity::PerChannel { axis: 1 }).unwrap();
        let q_rows = uniform::quantize(&t, Bits::B4, Granularity::PerChannel { axis: 0 }).unwrap();
        let q_scalar = uniform::quantize(&t, Bits::B6, Granularity::PerTensor).unwrap();
        let mut w = TqmWriter::new(meta(CodecId::FreqSeqPacked)).with_chunk_len(333);
        w.add_quantized("cols", &q_cols);
        w.add_quantized("rows", &q_rows);
        w.add_quantized("scalar", &q_scalar);
        w.write(&p).unwrap();
        let r = TqmReader::open(&p).unwrap();
        let mut packed = Vec::new();
        let mut out = Vec::new();
        for (name, q) in [("cols", &q_cols), ("rows", &q_rows), ("scalar", &q_scalar)] {
            r.load_dequantized_into(name, &mut packed, &mut out).unwrap();
            let reference = q.dequantize();
            assert_eq!(out, reference.data, "{name}: fused != unpack+dequantize");
        }
    }

    #[test]
    fn load_packed_returns_the_bit_packed_stream() {
        // the packed read path must hand back exactly pack(codes, bits)
        // for every width, flat and chunked framing alike
        for bits in [Bits::B2, Bits::B4, Bits::B6, Bits::B8] {
            let mut rng = crate::util::Rng::seed_from_u64(31);
            let t = Tensor::new(vec![48, 16], (0..48 * 16).map(|_| rng.normal_f32()).collect())
                .unwrap();
            let q = uniform::quantize(&t, bits, Granularity::PerChannel { axis: 1 }).unwrap();
            let want = packing::pack(&q.codes.data, bits.storage_bits());
            for chunked in [false, true] {
                let dir = crate::util::TempDir::new().unwrap();
                let p = dir.path().join("m.tqm");
                let mut w = if chunked {
                    TqmWriter::new(meta(CodecId::FreqSeqPacked)).with_chunk_len(129)
                } else {
                    TqmWriter::new(meta(CodecId::FreqSeqPacked)).with_flat_payloads()
                };
                w.add_quantized("w", &q);
                w.write(&p).unwrap();
                let r = TqmReader::open(&p).unwrap();
                let mut got = Vec::new();
                r.load_packed_into("w", &mut got).unwrap();
                assert_eq!(got, want, "{bits:?} chunked={chunked}");
                let rec = r.record("w").unwrap();
                assert_eq!(rec.raw_len, want.len());
                assert_eq!(rec.scale, q.scale);
            }
        }
        // f32 records reject the packed read path
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let mut w = TqmWriter::new(meta(CodecId::Raw));
        w.add_f32("norm", &Tensor::new(vec![4], vec![1.0; 4]).unwrap());
        w.write(&p).unwrap();
        let r = TqmReader::open(&p).unwrap();
        assert!(r.load_packed_into("norm", &mut Vec::new()).is_err());
    }

    #[test]
    fn expert_index_groups_records() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let mut w = TqmWriter::new(meta(CodecId::Lzw)).with_chunk_len(128);
        let router = Tensor::new(vec![8, 4], vec![0.5; 32]).unwrap();
        for layer in 0..2 {
            w.add_router(layer, &router);
            for expert in 0..3 {
                for (mi, mat) in ["w1", "w3", "w2"].iter().enumerate() {
                    let q = sample_quantized(16, 8, (layer * 10 + expert * 3 + mi) as u64);
                    w.add_expert_quantized(layer, expert, mat, &q);
                }
            }
        }
        w.write(&p).unwrap();
        let r = TqmReader::open(&p).unwrap();
        assert_eq!(r.expert_entries().len(), 6);
        assert_eq!(r.n_experts(0), 3);
        assert_eq!(r.n_experts(1), 3);
        assert_eq!(r.n_experts(2), 0);
        let e = r.expert_entry(1, 2).unwrap();
        assert_eq!((e.layer, e.expert), (1, 2));
        assert_eq!(e.records.len(), 3);
        // decoded f32 size is known without decoding: 3 matrices of 16x8
        assert_eq!(e.decoded_f32_bytes, 3 * 16 * 8 * 4);
        // packed-resident size too: 8-bit codes + per-col params, and at
        // this tiny geometry the col LUT (8*256*4 B > 128 B of codes) is
        // skipped by the profitability rule
        assert_eq!(e.packed_resident_bytes, 3 * (16 * 8 + 4 * (8 + 8)));
        assert!(e.packed_resident_bytes < e.decoded_f32_bytes);
        for &ri in &e.records {
            let rec = r.record_at(ri);
            let parsed = crate::format::parse_expert_record_name(&rec.name).unwrap();
            assert_eq!((parsed.0, parsed.1), (1, 2));
        }
        // routers are not expert records
        assert!(crate::format::parse_expert_record_name("layers.0.router").is_none());
        assert!(r.expert_entry(0, 9).is_err());
    }

    #[test]
    fn corrupt_expert_does_not_poison_siblings() {
        // one expert decodes without touching its siblings: corrupting
        // expert (0,1)'s payload must leave (0,0) loadable and make (0,1)
        // fail with a CRC error
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let mut w = TqmWriter::new(meta(CodecId::Huffman)).with_chunk_len(64);
        let mut originals = Vec::new();
        for expert in 0..2 {
            for (mi, mat) in ["w1", "w3", "w2"].iter().enumerate() {
                let q = sample_quantized(16, 8, (expert * 3 + mi + 40) as u64);
                w.add_expert_quantized(0, expert, mat, &q);
                originals.push((crate::format::expert_record_name(0, expert, mat), q));
            }
        }
        w.write(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let clean = TqmReader::from_bytes(bytes.clone()).unwrap();
        let victim = clean.record(&crate::format::expert_record_name(0, 1, "w3")).unwrap();
        let poison_at = victim.payload_offset + victim.payload_len / 2;
        drop(clean);
        bytes[poison_at] ^= 0x5A;
        let r = TqmReader::from_bytes(bytes).unwrap();
        for (name, q) in &originals {
            let (_, expert, _) = crate::format::parse_expert_record_name(name).unwrap();
            if expert == 0 {
                let got = r.load_quantized(name).unwrap();
                assert_eq!(got.codes, q.codes, "{name}");
            }
        }
        assert!(r.load_quantized(&crate::format::expert_record_name(0, 1, "w3")).is_err());
    }

    #[test]
    fn chunk_crcs_localize_corruption() {
        // v3: a payload bit-flip is pinned on the chunk it landed in —
        // the error names the record and the chunk index, never panics
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let q = sample_quantized(64, 32, 17);
        let mut w = TqmWriter::new(meta(CodecId::Huffman)).with_chunk_len(100);
        w.add_quantized("w", &q);
        w.write(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let clean = TqmReader::from_bytes(bytes.clone()).unwrap();
        let rec = clean.record("w").unwrap().clone();
        assert!(rec.chunk_crcs.len() > 1, "fixture must be multi-chunk");
        // verify the stored chunk CRCs actually cover the payload
        let payload = clean.payload_bytes(&rec).unwrap();
        let idx = parse_chunk_index(payload).unwrap();
        assert_eq!(idx.entries.len(), rec.chunk_crcs.len());
        let body = idx.body(payload);
        for (i, &(off, _)) in idx.entries.iter().enumerate() {
            let slice = &body[off..idx.chunk_end(i, body.len())];
            assert_eq!(crc32fast::hash(slice), rec.chunk_crcs[i], "chunk {i}");
        }
        // flip one byte in the middle of chunk 1's compressed slice
        let victim_chunk = 1usize;
        let (off, _) = idx.entries[victim_chunk];
        let end = idx.chunk_end(victim_chunk, body.len());
        let body_start = payload.len() - body.len();
        let flip_at = rec.payload_offset + body_start + (off + end) / 2;
        drop(clean);
        let mut bad = bytes;
        bad[flip_at] ^= 0x40;
        let r = TqmReader::from_bytes(bad).unwrap();
        let err = r.load_quantized("w").unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
        assert!(err.contains("\"w\""), "error must name the record: {err}");
        assert!(
            err.contains(&format!("first bad chunk {victim_chunk} of")),
            "error must name the chunk: {err}"
        );
    }

    #[test]
    fn truncated_fetch_blames_a_chunk_not_a_panic() {
        // localization under truncation: checked slicing flags the first
        // chunk whose compressed bytes run past the truncated payload
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let q = sample_quantized(64, 32, 19);
        let mut w = TqmWriter::new(meta(CodecId::Lzw)).with_chunk_len(128);
        w.add_quantized("layers.0.experts.0.w1", &q);
        w.write(&p).unwrap();
        let plan = Arc::new(crate::faults::FaultPlan::new(crate::faults::FaultConfig {
            seed: 4,
            truncate_p: 1.0,
            ..crate::faults::FaultConfig::default()
        }));
        let r = TqmReader::open(&p).unwrap().with_fault_plan(plan);
        let err = r.load_quantized("layers.0.experts.0.w1").unwrap_err().to_string();
        assert!(err.contains("crc mismatch"), "{err}");
    }

    #[test]
    fn fault_plan_seam_injects_then_clears() {
        // transient injection surfaces as a load error; the next access
        // (per-record access index advanced) can succeed and is bit-exact
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let q = sample_quantized(32, 16, 23);
        let mut w = TqmWriter::new(meta(CodecId::Huffman)).with_chunk_len(64);
        w.add_expert_quantized(0, 0, "w1", &q);
        w.write(&p).unwrap();
        let name = crate::format::expert_record_name(0, 0, "w1");
        // find a seed whose first access faults and a later one passes
        let mut hit = false;
        for seed in 0..64u64 {
            let plan = Arc::new(crate::faults::FaultPlan::new(crate::faults::FaultConfig {
                seed,
                transient_p: 0.5,
                ..crate::faults::FaultConfig::default()
            }));
            let r = TqmReader::open(&p).unwrap().with_fault_plan(plan.clone());
            let first = r.load_quantized(&name);
            if first.is_err() {
                assert!(
                    first.unwrap_err().to_string().contains("injected transient"),
                    "seed {seed}"
                );
                // retries eventually pass and decode bit-exact
                let ok = (0..20).find_map(|_| r.load_quantized(&name).ok());
                let got = ok.expect("transient fault never cleared in 20 retries");
                assert_eq!(got.codes, q.codes);
                assert!(plan.transient_injected() >= 1);
                assert!(r.fault_plan().is_some());
                hit = true;
                break;
            }
        }
        assert!(hit, "no seed produced a first-access transient at p=0.5");
    }

    #[test]
    fn kind_mismatch_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let q = sample_quantized(8, 8, 5);
        let mut w = TqmWriter::new(meta(CodecId::Raw));
        w.add_quantized("w", &q);
        w.write(&p).unwrap();
        let r = TqmReader::open(&p).unwrap();
        assert!(r.load_f32("w").is_err());
    }
}
