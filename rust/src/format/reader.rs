//! TQM reader: lazy, per-tensor decompression — the primitive under the
//! coordinator's layer streaming. The whole (compressed) file is held in
//! memory (that is the paper's deployment model: compressed weights are
//! what fits), the index is parsed once, and `load_*` decompresses a
//! single tensor on demand into a caller-supplied buffer.

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::{bits_from_u8, TensorKind, TensorRecord, TqmMeta, MAGIC};
use crate::compress::{codec, Codec, CodecId};
use crate::quant::{Bits, Granularity, QuantizedTensor};
use crate::tensor::{Tensor, U8Tensor};

pub struct TqmReader {
    pub meta: TqmMeta,
    pub codec_id: CodecId,
    data: Vec<u8>,
    dict_range: (usize, usize),
    records: Vec<TensorRecord>,
    codec: Box<dyn Codec>,
    /// §Perf: the freqseq dictionary parsed once per container (the parse
    /// builds a 64k-entry hash map; doing it per tensor per layer pass
    /// dominated streaming decompression time).
    prepared_freq: Option<crate::compress::freqseq::Table>,
}

struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            bail!("tqm: truncated at offset {}", self.pos);
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

impl TqmReader {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let data =
            std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_bytes(data)
    }

    pub fn from_bytes(data: Vec<u8>) -> Result<Self> {
        let mut c = Cursor { data: &data, pos: 0 };
        if c.take(4)? != MAGIC {
            bail!("tqm: bad magic");
        }
        let version = c.u32()?;
        if version != crate::FORMAT_VERSION {
            bail!("tqm: format version {version} != {}", crate::FORMAT_VERSION);
        }
        let codec_id = CodecId::from_u32(c.u32()?)?;
        let meta_len = c.u32()? as usize;
        let meta_text = std::str::from_utf8(c.take(meta_len)?)?;
        let meta = TqmMeta::from_json(&crate::util::Json::parse(meta_text)?)?;
        let dict_len = c.u64()? as usize;
        let dict_start = c.pos;
        c.take(dict_len)?;
        let dict_range = (dict_start, dict_start + dict_len);
        let n_tensors = c.u32()? as usize;

        let mut records = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            let name_len = c.u16()? as usize;
            let name = String::from_utf8(c.take(name_len)?.to_vec())?;
            let kind = TensorKind::from_u8(c.u8()?)?;
            let bits = if kind == TensorKind::QuantU8 {
                bits_from_u8(c.u8()?)?
            } else {
                c.u8()?;
                Bits::B8
            };
            let ndim = c.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(c.u32()? as usize);
            }
            let (scale, zero) = if kind == TensorKind::QuantU8 {
                let n_ch = c.u32()? as usize;
                let mut scale = Vec::with_capacity(n_ch);
                for _ in 0..n_ch {
                    scale.push(c.f32()?);
                }
                let mut zero = Vec::with_capacity(n_ch);
                for _ in 0..n_ch {
                    zero.push(c.f32()?);
                }
                (scale, zero)
            } else {
                (Vec::new(), Vec::new())
            };
            let raw_len = c.u64()? as usize;
            let payload_len = c.u64()? as usize;
            let crc32 = c.u32()?;
            let payload_offset = c.pos;
            c.take(payload_len)?;
            records.push(TensorRecord {
                name,
                kind,
                bits,
                shape,
                scale,
                zero,
                raw_len,
                payload_offset,
                payload_len,
                crc32,
            });
        }
        let prepared_freq = match codec_id {
            CodecId::FreqSeq | CodecId::FreqSeqPacked => Some(
                crate::compress::freqseq::Table::parse(&data[dict_range.0..dict_range.1])?,
            ),
            _ => None,
        };
        Ok(Self { meta, codec_id, dict_range, records, codec: codec(codec_id), prepared_freq, data })
    }

    pub fn records(&self) -> &[TensorRecord] {
        &self.records
    }

    pub fn record(&self, name: &str) -> Result<&TensorRecord> {
        self.records
            .iter()
            .find(|r| r.name == name)
            .ok_or_else(|| anyhow::anyhow!("tqm: no tensor {name:?}"))
    }

    fn dict(&self) -> &[u8] {
        &self.data[self.dict_range.0..self.dict_range.1]
    }

    fn payload(&self, r: &TensorRecord) -> Result<&[u8]> {
        let p = &self.data[r.payload_offset..r.payload_offset + r.payload_len];
        let crc = crc32fast::hash(p);
        if crc != r.crc32 {
            bail!("tqm: crc mismatch on {:?} ({:08x} != {:08x})", r.name, crc, r.crc32);
        }
        Ok(p)
    }

    /// Decompress a quantized tensor's codes into `scratch` and return the
    /// full QuantizedTensor view. `scratch` is reused across calls by the
    /// pipeline to avoid per-layer allocation.
    pub fn load_quantized_into(
        &self,
        name: &str,
        scratch: &mut Vec<u8>,
    ) -> Result<QuantizedTensor> {
        let r = self.record(name)?;
        if r.kind != TensorKind::QuantU8 {
            bail!("tqm: {name:?} is not quantized");
        }
        let payload = self.payload(r)?;
        if let Some(table) = &self.prepared_freq {
            crate::compress::freqseq::decode_with_table(
                table,
                self.codec_id == CodecId::FreqSeqPacked,
                payload,
                r.raw_len,
                scratch,
            )?;
        } else {
            self.codec.decompress(self.dict(), payload, r.raw_len, scratch)?;
        }
        // sub-8-bit codes were bit-packed before coding; expand back to
        // one-code-per-byte (what the stage HLOs take)
        if r.bits.storage_bits() < 8 {
            let n_codes = crate::tensor::numel(&r.shape);
            let unpacked =
                crate::quant::packing::unpack(scratch, r.bits.storage_bits(), n_codes);
            *scratch = unpacked;
        }
        let gran = if r.scale.len() == 1 {
            Granularity::PerTensor
        } else {
            Granularity::PerChannel { axis: 1 }
        };
        Ok(QuantizedTensor {
            codes: U8Tensor::new(r.shape.clone(), scratch.clone())?,
            scale: r.scale.clone(),
            zero: r.zero.clone(),
            bits: r.bits,
            granularity: gran,
        })
    }

    pub fn load_quantized(&self, name: &str) -> Result<QuantizedTensor> {
        let mut scratch = Vec::new();
        self.load_quantized_into(name, &mut scratch)
    }

    /// Load a raw f32 tensor (norm vectors).
    pub fn load_f32(&self, name: &str) -> Result<Tensor> {
        let r = self.record(name)?;
        if r.kind != TensorKind::F32Raw {
            bail!("tqm: {name:?} is not f32");
        }
        let payload = self.payload(r)?;
        let data: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor::new(r.shape.clone(), data)?)
    }

    /// Total container size (the Table 1 "Quantized+Compressed" number).
    pub fn file_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn dict_bytes(&self) -> usize {
        self.dict_range.1 - self.dict_range.0
    }

    /// Sum of decompressed code bytes (the Table 1 "Quantized" number).
    pub fn unpacked_bytes(&self) -> usize {
        self.records.iter().map(|r| r.raw_len + 4 * (r.scale.len() + r.zero.len())).sum()
    }
}

/// Shareable handle used by the pipeline's prefetch thread.
pub type SharedReader = Arc<TqmReader>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::TqmWriter;
    use crate::quant::{uniform, Bits, Granularity};
    
    fn meta(codec: CodecId) -> TqmMeta {
        TqmMeta {
            model_name: "test".into(),
            codec,
            bits: Bits::B8,
            per_channel: true,
            quantizer: "naive".into(),
            source_checkpoint: "unit".into(),
        }
    }

    fn sample_quantized(rows: usize, cols: usize, seed: u64) -> QuantizedTensor {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let t = Tensor::new(
            vec![rows, cols],
            (0..rows * cols).map(|_| rng.uniform(-1.0 as f64, 1.0 as f64) as f32).collect(),
        )
        .unwrap();
        uniform::quantize(&t, Bits::B8, Granularity::PerChannel { axis: 1 }).unwrap()
    }

    #[test]
    fn roundtrip_all_codecs() {
        for codec_id in crate::compress::all_codec_ids() {
            let dir = crate::util::TempDir::new().unwrap();
            let p = dir.path().join("m.tqm");
            let q1 = sample_quantized(32, 16, 1);
            let q2 = sample_quantized(16, 8, 2);
            let norm = Tensor::new(vec![16], vec![1.0; 16]).unwrap();
            let mut w = TqmWriter::new(meta(codec_id));
            w.add_quantized("layers.0.wq", &q1);
            w.add_quantized("layers.0.wk", &q2);
            w.add_f32("layers.0.ln1", &norm);
            w.write(&p).unwrap();

            let r = TqmReader::open(&p).unwrap();
            assert_eq!(r.codec_id, codec_id);
            assert_eq!(r.records().len(), 3);
            let g1 = r.load_quantized("layers.0.wq").unwrap();
            assert_eq!(g1.codes, q1.codes);
            assert_eq!(g1.scale, q1.scale);
            assert_eq!(g1.zero, q1.zero);
            let gn = r.load_f32("layers.0.ln1").unwrap();
            assert_eq!(gn, norm);
        }
    }

    #[test]
    fn crc_detects_corruption() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let q = sample_quantized(64, 32, 3);
        let mut w = TqmWriter::new(meta(CodecId::Lzw));
        w.add_quantized("w", &q);
        w.write(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 5] ^= 0xFF; // flip a payload byte
        let r = TqmReader::from_bytes(bytes).unwrap();
        assert!(r.load_quantized("w").is_err());
    }

    #[test]
    fn missing_tensor_error() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let w = TqmWriter::new(meta(CodecId::Raw));
        w.write(&p).unwrap();
        let r = TqmReader::open(&p).unwrap();
        assert!(r.load_quantized("nope").is_err());
    }

    #[test]
    fn sizes_reported() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let q = sample_quantized(128, 64, 4);
        let mut w = TqmWriter::new(meta(CodecId::Huffman));
        w.add_quantized("w", &q);
        let (file_bytes, _) = w.write(&p).unwrap();
        let r = TqmReader::open(&p).unwrap();
        assert_eq!(r.file_bytes(), file_bytes);
        assert_eq!(r.unpacked_bytes(), 128 * 64 + 4 * (64 + 64));
    }

    #[test]
    fn sub8bit_codes_roundtrip_packed() {
        // 4-bit codes are bit-packed in the container (half the payload)
        // and must come back exactly
        for bits in [Bits::Ternary, crate::quant::Bits::B2, crate::quant::Bits::B4, crate::quant::Bits::B6] {
            let dir = crate::util::TempDir::new().unwrap();
            let p = dir.path().join("m.tqm");
            let mut rng = crate::util::Rng::seed_from_u64(9);
            let t = Tensor::new(
                vec![64, 32],
                (0..64 * 32).map(|_| rng.normal_f32()).collect(),
            )
            .unwrap();
            let q = uniform::quantize(&t, bits, Granularity::PerTensor).unwrap();
            let mut w = TqmWriter::new(TqmMeta {
                model_name: "pack".into(),
                codec: CodecId::Raw,
                bits,
                per_channel: false,
                quantizer: "naive".into(),
                source_checkpoint: "unit".into(),
            });
            w.add_quantized("w", &q);
            w.write(&p).unwrap();
            let r = TqmReader::open(&p).unwrap();
            let got = r.load_quantized("w").unwrap();
            assert_eq!(got.codes, q.codes, "{bits:?}");
            // the stored payload really is packed (Raw codec => payload len
            // equals packed length)
            let rec = r.record("w").unwrap();
            let expect = (64 * 32 * bits.storage_bits() as usize + 7) / 8;
            assert_eq!(rec.payload_len, expect, "{bits:?}");
        }
    }

    #[test]
    fn kind_mismatch_rejected() {
        let dir = crate::util::TempDir::new().unwrap();
        let p = dir.path().join("m.tqm");
        let q = sample_quantized(8, 8, 5);
        let mut w = TqmWriter::new(meta(CodecId::Raw));
        w.add_quantized("w", &q);
        w.write(&p).unwrap();
        let r = TqmReader::open(&p).unwrap();
        assert!(r.load_f32("w").is_err());
    }
}
