//! Poison-recovering lock accessors for the serving hot path.
//!
//! `Mutex::lock().unwrap()` turns one panicked thread into a brick: every
//! later locker panics on the poison error, so a single bad decode takes
//! the whole host down. For the state these locks guard (caches, counters,
//! pending-sets), the invariants are re-checked by the code that holds the
//! guard — recovering the inner value is strictly better than cascading
//! the panic.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// `Condvar::wait` that recovers from poison instead of panicking.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_after_poison() {
        let m = Arc::new(Mutex::new(41));
        let m2 = m.clone();
        let _ = catch_unwind(AssertUnwindSafe(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        }));
        assert!(m.lock().is_err(), "lock must actually be poisoned");
        let mut g = lock_recover(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn plain_lock_still_works() {
        let m = Mutex::new(String::from("ok"));
        assert_eq!(&*lock_recover(&m), "ok");
    }
}
