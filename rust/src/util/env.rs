//! Loud environment-knob parsing. Every `TQM_*` tuning variable is read
//! through here: an unset (or empty) variable falls back to its default,
//! but a *malformed* value is a hard error naming the variable and the
//! bad text. The previous `.ok().and_then(|v| v.parse().ok())` idiom
//! silently ran a whole bench sweep at the default after a typo like
//! `TQM_EVAL_LIMIT=6O` — the worst possible failure mode for a knob
//! whose entire job is making runs comparable.

use std::fmt::Display;
use std::str::FromStr;

use anyhow::{bail, Result};

/// Read and parse `key`, falling back to `default` only when the
/// variable is unset or empty. A present-but-unparsable value fails
/// loudly with the variable name and the offending text.
pub fn env_parse<T>(key: &str, default: T) -> Result<T>
where
    T: FromStr,
    T::Err: Display,
{
    match env_parse_opt(key)? {
        Some(v) => Ok(v),
        None => Ok(default),
    }
}

/// Like [`env_parse`] but with no default: `Ok(None)` when unset/empty.
pub fn env_parse_opt<T>(key: &str) -> Result<Option<T>>
where
    T: FromStr,
    T::Err: Display,
{
    match std::env::var(key) {
        Err(_) => Ok(None),
        Ok(raw) if raw.trim().is_empty() => Ok(None),
        Ok(raw) => match raw.trim().parse::<T>() {
            Ok(v) => Ok(Some(v)),
            Err(e) => bail!(
                "invalid {key}={raw:?}: {e} (unset the variable to use the default)"
            ),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    // process env is global state; serialize the tests that touch it
    static ENV_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn unset_yields_default() {
        let _g = crate::util::lock_recover(&ENV_LOCK);
        std::env::remove_var("TQM_TEST_UNSET_KNOB");
        assert_eq!(env_parse("TQM_TEST_UNSET_KNOB", 42usize).unwrap(), 42);
        assert_eq!(env_parse_opt::<usize>("TQM_TEST_UNSET_KNOB").unwrap(), None);
    }

    #[test]
    fn set_value_parses_and_empty_counts_as_unset() {
        let _g = crate::util::lock_recover(&ENV_LOCK);
        std::env::set_var("TQM_TEST_SET_KNOB", "17");
        assert_eq!(env_parse("TQM_TEST_SET_KNOB", 42usize).unwrap(), 17);
        std::env::set_var("TQM_TEST_SET_KNOB", "  0.25 ");
        assert_eq!(env_parse("TQM_TEST_SET_KNOB", 0.0f64).unwrap(), 0.25);
        std::env::set_var("TQM_TEST_SET_KNOB", "");
        assert_eq!(env_parse("TQM_TEST_SET_KNOB", 42usize).unwrap(), 42);
        std::env::remove_var("TQM_TEST_SET_KNOB");
    }

    #[test]
    fn malformed_value_fails_loudly_naming_key_and_value() {
        let _g = crate::util::lock_recover(&ENV_LOCK);
        std::env::set_var("TQM_TEST_BAD_KNOB", "6O");
        let err = env_parse("TQM_TEST_BAD_KNOB", 60usize).unwrap_err().to_string();
        assert!(err.contains("TQM_TEST_BAD_KNOB"), "{err}");
        assert!(err.contains("6O"), "{err}");
        std::env::remove_var("TQM_TEST_BAD_KNOB");
    }
}
