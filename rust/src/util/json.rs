//! Minimal JSON parser + writer (the vendored crate set has no serde_json;
//! manifests, eval sets and reports are all JSON, so we build the substrate).
//!
//! Supports the full JSON grammar including unicode escapes; numbers are
//! held as f64 (fine for every file we exchange — token ids, dims and
//! metrics all fit in 53 bits).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors -----------------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow::anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("not a non-negative integer: {f}");
        }
        Ok(f as usize)
    }

    pub fn as_u32(&self) -> Result<u32> {
        Ok(self.as_usize()? as u32)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn u32_arr(&self) -> Result<Vec<u32>> {
        self.as_arr()?.iter().map(|v| v.as_u32()).collect()
    }

    pub fn str_arr(&self) -> Result<Vec<String>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_str()?.to_string())).collect()
    }

    // -- construction helpers -------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -- parsing --------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at offset {}", p.pos);
        }
        Ok(v)
    }

    // -- serialization ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at offset {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at offset {}", c as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("bad surrogate pair");
                                }
                                let c =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(
                                    char::from_u32(c)
                                        .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?,
                                );
                            } else {
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?,
                                );
                            }
                        }
                        e => bail!("bad escape {:?}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte utf-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => bail!("bad utf-8 lead byte"),
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])?;
        self.pos += 4;
        Ok(u32::from_str_radix(hex, 16)?)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' got {:?} at {}", c as char, self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' got {:?} at {}", c as char, self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":-7}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let root = crate::config::default_artifacts_root();
        let p = root.join("tiny/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let j = Json::parse(&text).unwrap();
            assert_eq!(j.get("config").unwrap().get("name").unwrap().as_str().unwrap(), "tiny");
        }
    }

    #[test]
    fn escaped_output_reparses() {
        let j = Json::Str("quote\" slash\\ ctrl\u{1} nl\n".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
