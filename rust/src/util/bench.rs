//! Mini benchmark harness (no criterion offline): warmup, timed
//! iterations, robust stats, aligned table printing. All `rust/benches/*`
//! binaries (harness = false) are built on this.

use std::time::Instant;

use crate::util::stats;

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl Measurement {
    pub fn throughput(&self, units: f64) -> f64 {
        units / self.mean_s.max(1e-12)
    }
}

/// Time `f` adaptively: warm up, then run until `budget_s` of wall clock
/// or `max_iters`, whichever first (at least 3 iterations).
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> Measurement {
    // Warm up with three calls and calibrate from their median: the first
    // call routinely pays page-cache misses and lazy init, and sizing the
    // whole sample count from that one outlier used to under-iterate fast
    // benchmarks by an order of magnitude.
    let mut warm = [0.0f64; 3];
    for w in warm.iter_mut() {
        let w0 = Instant::now();
        f();
        *w = w0.elapsed().as_secs_f64();
    }
    stats::sort_samples(&mut warm);
    let per_iter = warm[1];
    let target_iters = ((budget_s / per_iter.max(1e-9)) as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(target_iters);
    let start = Instant::now();
    for _ in 0..target_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() > budget_s * 2.0 {
            break;
        }
    }
    let s = stats::summarize(&mut samples);
    Measurement {
        name: name.to_string(),
        iters: s.n,
        mean_s: s.mean,
        p50_s: s.p50,
        p95_s: s.p95,
        p99_s: s.p99,
        min_s: s.min,
    }
}

/// Pretty-print a table with aligned columns.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for i in 0..ncols {
                line.push_str(&format!("{:width$} | ", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let sep: String = widths
            .iter()
            .map(|w| format!("|{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "|";
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helpers shared by bench binaries.
pub fn fmt_bytes(b: usize) -> String {
    if b >= 10 * 1024 * 1024 {
        format!("{:.1} MB", b as f64 / (1024.0 * 1024.0))
    } else if b >= 10 * 1024 {
        format!("{:.1} kB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("spin", 0.05, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(m.iters >= 3);
        assert!(m.mean_s > 0.0);
        assert!(m.p50_s <= m.p95_s);
        assert!(m.p95_s <= m.p99_s);
        assert!(m.min_s <= m.mean_s * 1.5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row(vec!["xxxxx".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("xxxxx"));
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(100), "100 B");
        assert!(fmt_bytes(100 * 1024 * 1024).contains("MB"));
        assert!(fmt_secs(0.002).contains("ms"));
        assert!(fmt_secs(2.0).contains("s"));
    }
}
