//! Deterministic PRNG (SplitMix64 core) — the vendored crate set has no
//! `rand`, and everything stochastic in this repo (tests, workload
//! generators, network simulator) must be reproducible anyway.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — hi exclusive, lo < hi.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with rate `lambda` (inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Random byte vector.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| (self.next_u64() & 0xFF) as u8).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Random normal f32 vector (weight-like data for tests/benches).
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(3, 13) as usize - 3;
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exponential_positive_mean() {
        let mut r = Rng::seed_from_u64(5);
        let xs: Vec<f64> = (0..20_000).map(|_| r.exponential(2.0)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }
}
