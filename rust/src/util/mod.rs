//! Hand-rolled utility substrates. The build is fully offline against a
//! small vendored crate set (see Cargo.toml), so JSON, RNG, temp dirs, a
//! mini property-test driver and a mini benchmark harness live here
//! instead of serde_json / rand / tempfile / proptest / criterion.

pub mod bench;
pub mod env;
pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod tempdir;

pub use env::{env_parse, env_parse_opt};
pub use json::Json;
pub use rng::Rng;
pub use sync::{lock_recover, wait_recover};
pub use tempdir::TempDir;
