//! Shared order-statistics helpers. Every percentile the repo reports —
//! netlat summaries, the bench harness, serving metrics, eval reports,
//! the fault/envelope tables — goes through [`percentile`], so the index
//! convention (nearest-rank via floor, clamped to the last element) is
//! defined exactly once. Before this module existed the same math was
//! hand-rolled in four places with three different clamping behaviours.

/// Nearest-rank percentile of an ascending-sorted slice.
///
/// Index convention: `floor(n * pct / 100)`, clamped to `n - 1`. The
/// clamp matters at `pct = 100` (and guards any future caller passing
/// pct > 100); for `pct < 100` the floor alone stays in bounds, which is
/// why the old unclamped sites never actually panicked — they were just
/// one refactor away from it.
///
/// An empty slice yields 0.0 rather than panicking: all callers feed
/// measured samples, and "no samples" should render as a zero row, not
/// take down a serving thread.
pub fn percentile(sorted: &[f64], pct: u32) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return 0.0;
    }
    sorted[(n * pct as usize / 100).min(n - 1)]
}

/// Sort in place with `total_cmp` so NaN samples (a bug upstream, but
/// latency math divides) produce a garbage summary instead of a panic.
pub fn sort_samples(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

/// One-call summary over a set of samples: sorts (total_cmp) and pulls
/// the standard latency quantiles via [`percentile`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn summarize(xs: &mut [f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    sort_samples(xs);
    let n = xs.len();
    Summary {
        n,
        mean: xs.iter().sum::<f64>() / n as f64,
        min: xs[0],
        p50: percentile(xs, 50),
        p95: percentile(xs, 95),
        p99: percentile(xs, 99),
        max: xs[n - 1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn empty_slice_is_zero_not_panic() {
        assert_eq!(percentile(&[], 50), 0.0);
        assert_eq!(summarize(&mut []).n, 0);
    }

    #[test]
    fn singleton_returns_the_element_for_every_pct() {
        for pct in [0, 1, 50, 95, 99, 100] {
            assert_eq!(percentile(&[7.5], pct), 7.5);
        }
    }

    #[test]
    fn matches_legacy_index_convention() {
        // The pre-unification sites computed xs[n/2], xs[n*95/100] and
        // xs[(n*99/100).min(n-1)]; the shared helper must be bit-identical
        // on those so seeds and golden numbers carry over.
        for n in 1..=257usize {
            let xs: Vec<f64> = (0..n).map(|i| i as f64).collect();
            assert_eq!(percentile(&xs, 50), xs[n / 2], "n={n}");
            assert_eq!(percentile(&xs, 95), xs[(n * 95 / 100).min(n - 1)], "n={n}");
            assert_eq!(percentile(&xs, 99), xs[(n * 99 / 100).min(n - 1)], "n={n}");
        }
    }

    #[test]
    fn property_monotone_and_clamped_n_1_to_1000() {
        // For every sample count 1..=1000 over seeded random data:
        // percentiles are monotone in pct, bounded by min/max, and
        // pct=100 hits the max (the clamp working) instead of panicking.
        let mut rng = Rng::seed_from_u64(0xBA20_0E7E);
        for n in 1..=1000usize {
            let mut xs: Vec<f64> = (0..n).map(|_| rng.f64() * 100.0).collect();
            let s = summarize(&mut xs);
            assert_eq!(s.n, n);
            let mut prev = f64::NEG_INFINITY;
            for pct in 0..=100u32 {
                let v = percentile(&xs, pct);
                assert!(v >= prev, "n={n} pct={pct}: {v} < {prev}");
                assert!(v >= s.min && v <= s.max, "n={n} pct={pct}");
                prev = v;
            }
            assert_eq!(percentile(&xs, 100), s.max, "n={n}");
            assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99, "n={n}");
            assert!(s.mean >= s.min && s.mean <= s.max, "n={n}");
        }
    }

    #[test]
    fn nan_samples_do_not_panic() {
        let mut xs = vec![1.0, f64::NAN, 0.5];
        let s = summarize(&mut xs);
        assert_eq!(s.n, 3);
        // total_cmp orders NaN last; quantiles below it stay finite
        assert!(s.p50.is_finite());
    }
}
