//! Self-deleting temporary directories (no `tempfile` crate offline).

use std::path::{Path, PathBuf};

/// A directory under the system temp root, removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<Self> {
        // entropy: pid + monotonic counter + coarse time
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let nonce = COUNTER.fetch_add(1, Ordering::Relaxed);
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!(
            "tqm-{}-{}-{:x}",
            std::process::id(),
            nonce,
            t
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn join(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TempDir::new().unwrap();
            p = d.path().to_path_buf();
            std::fs::write(d.join("x.txt"), b"hello").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
